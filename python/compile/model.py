"""L2: JAX transformer language model (fwd/bwd + fused AdamW), calling the
L1 Pallas kernels, AOT-lowered by aot.py and executed from rust via PJRT.

The model is a pre-LN GPT-style decoder:

    tok_embed + pos_embed
    N x [ LN -> flash_attention (Pallas) -> residual
          LN -> MLP (GELU)               -> residual ]
    LN_f -> lm_head

Layer weights are *stacked* along a leading axis and the block is applied
with ``jax.lax.scan`` — one HLO body regardless of depth, which keeps the
lowered artifact small and lets XLA pipeline the layer loop.

The train step is ``loss, grads = value_and_grad(loss_fn)`` followed by the
fused Pallas AdamW on every leaf. Its flat I/O convention (see
``flatten_state`` / manifest) is the contract with the rust runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.adamw import adamw_update
from .kernels.matmul import matmul as pallas_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer hyperparameters for one AOT preset."""

    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    seq: int
    batch: int
    mlp_mult: int = 4
    lr: float = 3e-4
    weight_decay: float = 0.01
    # Which matmuls route through the Pallas tiled-matmul kernel. The flash
    # attention + fused AdamW kernels are always on; the lm_head projection
    # through the Pallas matmul is exercised in the tiny preset (and tests)
    # but kept on jnp/XLA dot for the big presets, where the lowered
    # interpret-mode tile loop would dominate CPU step time.
    pallas_lm_head: bool = False

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def mlp_hidden(self) -> int:
        return self.hidden * self.mlp_mult

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Name/shape of every parameter, in the flat I/O order used by the
        rust runtime (this order is the ABI — append only)."""
        h, l, v, s, m = self.hidden, self.layers, self.vocab, self.seq, self.mlp_hidden
        return [
            ("tok_embed", (v, h)),
            ("pos_embed", (s, h)),
            ("ln1_g", (l, h)),
            ("ln1_b", (l, h)),
            ("wqkv", (l, h, 3 * h)),
            ("bqkv", (l, 3 * h)),
            ("wo", (l, h, h)),
            ("bo", (l, h)),
            ("ln2_g", (l, h)),
            ("ln2_b", (l, h)),
            ("w1", (l, h, m)),
            ("b1", (l, m)),
            ("w2", (l, m, h)),
            ("b2", (l, h)),
            ("lnf_g", (h,)),
            ("lnf_b", (h,)),
            ("lm_head", (h, v)),
        ]

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.asarray(s))) for _, s in self.param_specs())


PRESETS: Dict[str, ModelConfig] = {
    # tests + fast CI: exercises every kernel including the Pallas lm_head
    "tiny": ModelConfig(
        "tiny", vocab=256, hidden=64, layers=2, heads=2, seq=64, batch=2,
        pallas_lm_head=True,
    ),
    # ~25M params — quick end-to-end runs
    "small25m": ModelConfig(
        "small25m", vocab=8192, hidden=384, layers=6, heads=6, seq=128, batch=2,
    ),
    # ~110M params — the paper-scale end-to-end validation model
    "base100m": ModelConfig(
        "base100m", vocab=16384, hidden=768, layers=12, heads=12, seq=128, batch=2,
    ),
}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: jax.Array) -> Dict[str, jax.Array]:
    """Initialize parameters (0.02-scaled normals; ones/zeros for LN)."""
    key = jax.random.key(seed.astype(jnp.uint32))
    params: Dict[str, jax.Array] = {}
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)) or name in ("ln1_g", "ln2_g", "lnf_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.startswith(("b", "ln")) or name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)) * g + b


def _block(x, layer_params, cfg: ModelConfig):
    """One transformer block over x: [B, S, H]."""
    ln1_g, ln1_b, wqkv, bqkv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2 = layer_params
    B, S, H = x.shape
    nh, hd = cfg.heads, cfg.head_dim

    y = _layernorm(x, ln1_g, ln1_b)
    qkv = jnp.einsum("bsh,hk->bsk", y, wqkv) + bqkv  # [B, S, 3H]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B, S, H] -> [B, nh, S, hd]
        return t.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    # vmap the Pallas flash-attention kernel over the batch; the kernel grid
    # already covers (heads, q_blocks).
    att = jax.vmap(lambda qq, kk, vv: flash_attention(qq, kk, vv, True))(q, k, v)
    att = att.transpose(0, 2, 1, 3).reshape(B, S, H)
    x = x + jnp.einsum("bsh,hk->bsk", att, wo) + bo

    y = _layernorm(x, ln2_g, ln2_b)
    hdn = jax.nn.gelu(jnp.einsum("bsh,hm->bsm", y, w1) + b1)
    x = x + jnp.einsum("bsm,mh->bsh", hdn, w2) + b2
    return x


def forward(params: Dict[str, jax.Array], tokens: jax.Array, cfg: ModelConfig):
    """Logits for token ids ``[B, S]`` -> ``[B, S, V]``."""
    B, S = tokens.shape
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :S, :]

    layer_keys = (
        "ln1_g", "ln1_b", "wqkv", "bqkv", "wo", "bo",
        "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
    )
    stacked = tuple(params[k] for k in layer_keys)

    def body(carry, layer):
        return _block(carry, layer, cfg), None

    x, _ = jax.lax.scan(body, x, stacked)
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])

    if cfg.pallas_lm_head:
        logits = pallas_matmul(x.reshape(B * S, cfg.hidden), params["lm_head"])
        logits = logits.reshape(B, S, cfg.vocab)
    else:
        logits = jnp.einsum("bsh,hv->bsv", x, params["lm_head"])
    return logits


def loss_fn(params, tokens, targets, cfg: ModelConfig) -> jax.Array:
    """Mean next-token cross-entropy (f32)."""
    logits = forward(params, tokens, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def train_step(params, m, v, step, tokens, targets, cfg: ModelConfig):
    """One optimizer step. Returns (params', m', v', step+1, loss).

    grads via value_and_grad over the scanned model (flash-attention custom
    VJP kernels inside); update via the fused Pallas AdamW on every leaf.
    """
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets, cfg)
    new_step = step + 1

    def upd(p, g, mm, vv):
        return adamw_update(
            p, g, mm, vv, new_step, lr=cfg.lr, weight_decay=cfg.weight_decay
        )

    out = {k: upd(params[k], grads[k], m[k], v[k]) for k in params}
    new_p = {k: t[0] for k, t in out.items()}
    new_m = {k: t[1] for k, t in out.items()}
    new_v = {k: t[2] for k, t in out.items()}
    return new_p, new_m, new_v, new_step, loss


def eval_loss(params, tokens, targets, cfg: ModelConfig):
    return loss_fn(params, tokens, targets, cfg)


# ---------------------------------------------------------------------------
# flat I/O (the ABI with the rust runtime)
# ---------------------------------------------------------------------------


def flatten_params(cfg: ModelConfig, params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[name] for name, _ in cfg.param_specs()]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    names = [n for n, _ in cfg.param_specs()]
    return dict(zip(names, flat))


def train_step_flat(cfg: ModelConfig):
    """Returns fn(*flat) with flat = params + m + v + [step, tokens, targets],
    producing params' + m' + v' + [step', loss] — the AOT entry point."""
    n = len(cfg.param_specs())

    def fn(*flat):
        params = unflatten_params(cfg, flat[:n])
        m = unflatten_params(cfg, flat[n : 2 * n])
        v = unflatten_params(cfg, flat[2 * n : 3 * n])
        step, tokens, targets = flat[3 * n : 3 * n + 3]
        new_p, new_m, new_v, new_step, loss = train_step(
            params, m, v, step, tokens, targets, cfg
        )
        return tuple(
            flatten_params(cfg, new_p)
            + flatten_params(cfg, new_m)
            + flatten_params(cfg, new_v)
            + [new_step, loss]
        )

    return fn


def init_flat(cfg: ModelConfig):
    """Returns fn(seed) -> params + m + v + [step] (all zeros moments)."""

    def fn(seed):
        params = init_params(cfg, seed)
        flat_p = flatten_params(cfg, params)
        m = [jnp.zeros_like(x) for x in flat_p]
        v = [jnp.zeros_like(x) for x in flat_p]
        step = jnp.asarray(0, jnp.int32)
        return tuple(flat_p + m + v + [step])

    return fn


def eval_flat(cfg: ModelConfig):
    """Returns fn(*params, tokens, targets) -> (loss,)."""
    n = len(cfg.param_specs())

    def fn(*flat):
        params = unflatten_params(cfg, flat[:n])
        tokens, targets = flat[n], flat[n + 1]
        return (eval_loss(params, tokens, targets, cfg),)

    return fn
