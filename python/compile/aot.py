"""AOT compile path: lower L2/L1 JAX+Pallas programs to HLO *text* artifacts.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per preset P in artifacts/:
    P.train_step.hlo.txt   params+m+v+[step,tokens,targets] -> params'+m'+v'+[step',loss]
    P.init.hlo.txt         [seed] -> params+m+v+[step]
    P.eval.hlo.txt         params+[tokens,targets] -> [loss]
    P.manifest.json        flat-I/O ABI: names/shapes/dtypes in order
plus smoke.hlo.txt (2x2 Pallas matmul + 2, the runtime smoke test) and
manifest.json (preset index). Python runs ONLY here — never at runtime.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_preset(cfg: M.ModelConfig, out_dir: str) -> dict:
    """Lower train/init/eval for one preset; return its manifest dict."""
    specs = cfg.param_specs()
    n = len(specs)
    B, S = cfg.batch, cfg.seq

    params_abs = [_abstract(s) for _, s in specs]
    step_abs = _abstract((), jnp.int32)
    tok_abs = _abstract((B, S), jnp.int32)

    names = [name for name, _ in specs]
    io_params = [{"name": nm, **_spec(s)} for nm, s in specs]

    artifacts = {}

    # --- train step -------------------------------------------------------
    train_inputs = params_abs * 3 + [step_abs, tok_abs, tok_abs]
    lowered = jax.jit(M.train_step_flat(cfg)).lower(*train_inputs)
    path = f"{cfg.name}.train_step.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts["train_step"] = {
        "artifact": path,
        "inputs": (
            [{"name": nm, **_spec(s)} for nm, s in specs]
            + [{"name": f"m.{nm}", **_spec(s)} for nm, s in specs]
            + [{"name": f"v.{nm}", **_spec(s)} for nm, s in specs]
            + [
                {"name": "step", "shape": [], "dtype": "s32"},
                {"name": "tokens", "shape": [B, S], "dtype": "s32"},
                {"name": "targets", "shape": [B, S], "dtype": "s32"},
            ]
        ),
        "outputs": (
            [{"name": nm, **_spec(s)} for nm, s in specs]
            + [{"name": f"m.{nm}", **_spec(s)} for nm, s in specs]
            + [{"name": f"v.{nm}", **_spec(s)} for nm, s in specs]
            + [
                {"name": "step", "shape": [], "dtype": "s32"},
                {"name": "loss", "shape": [], "dtype": "f32"},
            ]
        ),
    }

    # --- init --------------------------------------------------------------
    lowered = jax.jit(M.init_flat(cfg)).lower(_abstract((), jnp.int32))
    path = f"{cfg.name}.init.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts["init"] = {
        "artifact": path,
        "inputs": [{"name": "seed", "shape": [], "dtype": "s32"}],
        "outputs": artifacts["train_step"]["inputs"][: 3 * n]
        + [{"name": "step", "shape": [], "dtype": "s32"}],
    }

    # --- eval ----------------------------------------------------------------
    lowered = jax.jit(M.eval_flat(cfg)).lower(*(params_abs + [tok_abs, tok_abs]))
    path = f"{cfg.name}.eval.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts["eval"] = {
        "artifact": path,
        "inputs": io_params
        + [
            {"name": "tokens", "shape": [B, S], "dtype": "s32"},
            {"name": "targets", "shape": [B, S], "dtype": "s32"},
        ],
        "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
    }

    manifest = {
        "preset": cfg.name,
        "hyperparams": {
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "mlp_mult": cfg.mlp_mult,
            "lr": cfg.lr,
            "weight_decay": cfg.weight_decay,
        },
        "param_count": int(sum(int(jnp.prod(jnp.asarray(s))) for _, s in specs)),
        "n_params": n,
        "params": names,
        **artifacts,
    }
    with open(os.path.join(out_dir, f"{cfg.name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def lower_smoke(out_dir: str) -> None:
    """fn(x, y) = (pallas_matmul(x, y) + 2,) over f32[2,2] — the runtime
    smoke artifact (rust asserts the [5,5,9,9] result, as in the reference)."""
    from .kernels.matmul import matmul

    def fn(x, y):
        return (matmul(x, y, 2) + 2.0,)

    spec = _abstract((2, 2))
    lowered = jax.jit(fn).lower(spec, spec)
    with open(os.path.join(out_dir, "smoke.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,small25m,base100m",
        help="comma-separated preset names (see compile.model.PRESETS)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    lower_smoke(args.out_dir)
    print("lowered smoke.hlo.txt")

    index = {"presets": []}
    for name in [p for p in args.presets.split(",") if p]:
        cfg = M.PRESETS[name]
        man = lower_preset(cfg, args.out_dir)
        index["presets"].append(name)
        print(
            f"lowered preset {name}: {man['param_count']/1e6:.1f}M params, "
            f"artifacts={list(k for k in ('train_step','init','eval'))}"
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(index, f, indent=1)


if __name__ == "__main__":
    main()
