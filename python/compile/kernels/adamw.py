"""L1: fused AdamW update as an elementwise Pallas VPU kernel.

One kernel invocation updates one (8*128-aligned) block of the flattened
parameter/moment tensors: moment updates, bias correction, decoupled weight
decay, and the parameter step are fused into a single VMEM-resident pass —
the GPU original would be a grid-stride elementwise CUDA kernel; on a
TPU-shaped machine this is a VPU loop over (8, 128) registers.

Bias corrections ``1 - beta^t`` depend on the (traced) step counter, so they
are computed outside and passed in as a length-2 scalar vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128  # one (8, 128) VPU tile of f32
LARGE_BLOCK = 64 * 1024  # for multi-million-element leaves: amortize the
# per-grid-step slicing overhead of the lowered (interpret-mode) loop


def _adamw_kernel(c_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref, *, lr, b1, b2, eps, weight_decay):
    c1 = c_ref[0]  # 1 - b1**step
    c2 = c_ref[1]  # 1 - b2**step
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / c1
    v_hat = v_new / c2
    update = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p

    po_ref[...] = (p - lr * update).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


def adamw_update(
    p: jax.Array,
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    step: jax.Array,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    block: int | None = None,
):
    """Fused AdamW on a tensor of any shape. Returns (new_p, new_m, new_v).

    The tensor is flattened and zero-padded to a block multiple; padding
    lanes carry zeros through every moment update, so un-padding is exact.
    """
    shape, dtype = p.shape, p.dtype
    n = p.size
    if block is None:
        # Interpret-mode lowering materializes a full-buffer
        # dynamic-update-slice per grid step, so on CPU the whole padded
        # array is processed as ONE grid step (block = n_pad). On a real
        # TPU you would pick a VMEM-sized block (see BLOCK/LARGE_BLOCK and
        # DESIGN.md §Perf) — the kernel body is identical either way.
        block = (n + BLOCK - 1) // BLOCK * BLOCK
    n_pad = (n + block - 1) // block * block

    def flat(x):
        x = jnp.ravel(x).astype(jnp.float32)
        return jnp.pad(x, (0, n_pad - n))

    pf, gf, mf, vf = flat(p), flat(g), flat(m), flat(v)
    step_f = step.astype(jnp.float32)
    c = jnp.stack([1.0 - b1**step_f, 1.0 - b2**step_f])

    kernel = functools.partial(
        _adamw_kernel, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay
    )
    grid = (n_pad // block,)
    blk = pl.BlockSpec((block,), lambda i: (i,))
    cspec = pl.BlockSpec((2,), lambda i: (0,))
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[cspec, blk, blk, blk, blk],
        out_specs=[blk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.float32)] * 3,
        interpret=True,
    )(c, pf, gf, mf, vf)

    unflat = lambda x: jnp.reshape(x[:n], shape).astype(dtype)
    return unflat(po), unflat(mo), unflat(vo)
