"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here, written in straight-line jax.numpy with no tiling or
scratch management. pytest (python/tests/) and hypothesis sweeps assert
`assert_allclose(kernel(...), ref(...))` across shapes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain matmul with f32 accumulation (the MXU contract)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """Multi-head attention oracle.

    Shapes: q, k, v are [heads, seq, head_dim]; output matches q.
    Softmax is computed in f32 regardless of input dtype.
    """
    h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = (
        jnp.einsum("hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32) * scale
    )
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "hqk,hkd->hqd", probs.astype(jnp.float32), v.astype(jnp.float32)
    )
    return out.astype(q.dtype)


def adamw_ref(
    p: jax.Array,
    g: jax.Array,
    m: jax.Array,
    v: jax.Array,
    step: jax.Array,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    """AdamW update oracle. Returns (new_p, new_m, new_v)."""
    step_f = step.astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**step_f)
    v_hat = v_new / (1.0 - b2**step_f)
    update = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p
    return p - lr * update, m_new, v_new


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    """LayerNorm over the last axis, f32 statistics."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)
