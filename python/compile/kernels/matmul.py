"""L1: tiled matmul Pallas kernel with MXU-shaped blocks.

The grid is ``(M/bm, N/bn, K/bk)``; each invocation multiplies one
[bm, bk] x [bk, bn] tile pair and accumulates into the f32 output tile —
the classic systolic-array schedule (BlockSpec expresses the HBM<->VMEM
movement the GPU original would do with threadblock tiling).

Carries a custom_vjp built from the kernel itself (dx = dy @ y^T,
dy = x^T @ dy), so it is usable inside differentiated L2 code.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _pick_block(n: int, requested: int) -> int:
    b = min(requested, n)
    while n % b != 0:
        b -= 1
    return b


def _mm_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j].

    The output index map ignores the k grid dimension, so the [bm, bn] tile
    stays resident across the (sequential) k iterations and serves as the
    accumulator; it is zeroed on the first k step.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def _matmul_raw(x: jax.Array, y: jax.Array, block: int) -> jax.Array:
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    bk = _pick_block(k, block)
    n_k = k // bk
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(x: jax.Array, y: jax.Array, block: int = DEFAULT_BLOCK) -> jax.Array:
    """``x @ y`` with f32 accumulation, as a tiled Pallas kernel."""
    return _matmul_raw(x, y, block)


def _matmul_fwd(x, y, block):
    return _matmul_raw(x, y, block), (x, y)


def _matmul_bwd(block, res, g):
    x, y = res
    dx = _matmul_raw(g, y.T, block).astype(x.dtype)
    dy = _matmul_raw(x.T, g, block).astype(y.dtype)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)
