# L1: Pallas kernels for the paper's compute hot-spot (+ ref oracles).
from . import ref  # noqa: F401
from .attention import flash_attention  # noqa: F401
from .matmul import matmul  # noqa: F401
from .adamw import adamw_update  # noqa: F401
from .layernorm import layernorm  # noqa: F401
