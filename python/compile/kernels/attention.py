"""L1: flash-attention Pallas kernel (fwd + bwd), the model's compute hot-spot.

Hardware adaptation (paper GPUs -> TPU-style Pallas, DESIGN.md
section Hardware-Adaptation): the GPU flash-attention formulation
(threadblock tiles in shared memory, warp reductions) is restated for a
scratchpad machine:

* the grid iterates ``(head, q_block)``; each invocation holds one q tile
  in VMEM via BlockSpec and streams K/V tiles with ``pl.dynamic_slice``
  loads — the HBM<->VMEM schedule the paper's substrate would express with
  cp.async pipelines;
* the online-softmax accumulator (m, l, acc) is carried through a
  ``fori_loop`` instead of warp-shuffled registers;
* all contractions are f32-accumulated, MXU-shaped (tiles are multiples of
  the 128-lane register width whenever the sequence allows).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO (see /opt/xla-example/README).

The public entry point :func:`flash_attention` carries a ``custom_vjp``
whose backward pass is itself two Pallas kernels (dq and dk/dv), using the
standard recompute-from-LSE formulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30  # avoids exp(-inf - -inf) = nan in the online softmax


def _pick_block(seq_len: int, requested: int) -> int:
    """Largest divisor of seq_len that is <= requested (kernels assume the
    sequence is an exact multiple of the block)."""
    b = min(requested, seq_len)
    while seq_len % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, causal, block_k, seq_len, scale):
    q = q_ref[...].astype(jnp.float32) * scale  # [bq, d]
    bq = q.shape[0]
    qi = pl.program_id(1)
    q_ids = qi * bq + jax.lax.iota(jnp.int32, bq)

    nk_total = seq_len // block_k
    if causal:
        # only K blocks that intersect the lower triangle of this q tile
        nk = ((qi + 1) * bq + block_k - 1) // block_k
    else:
        nk = nk_total

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(
            jnp.float32
        )
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(
            jnp.float32
        )
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        if causal:
            k_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    d = q.shape[1]
    init = (
        jnp.full((bq,), _NEG_INF, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, d), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, nk, body, init)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(l)


def _fwd(q, k, v, causal, block_q, block_k):
    h, s, d = q.shape
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    scale = float(1.0 / (d**0.5))
    kernel = functools.partial(
        _fwd_kernel, causal=causal, block_k=bk, seq_len=s, scale=scale
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, bq), lambda hi, qi: (hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, d), q.dtype),
            jax.ShapeDtypeStruct((h, s), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, causal, block_k, seq_len, scale):
    q = q_ref[...].astype(jnp.float32)  # [bq, d]
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]  # [bq]
    delta = delta_ref[...]  # [bq]
    bq, d = q.shape
    qi = pl.program_id(1)
    q_ids = qi * bq + jax.lax.iota(jnp.int32, bq)
    nk = (
        ((qi + 1) * bq + block_k - 1) // block_k if causal else seq_len // block_k
    )

    def body(j, dq):
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(
            jnp.float32
        )
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(
            jnp.float32
        )
        s = scale * jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        if causal:
            k_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_ids[:, None] >= k_ids[None, :], s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])  # [bq, bk]
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None])
        return dq + scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    dq = jax.lax.fori_loop(0, nk, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, causal, block_q, seq_len, scale):
    k = k_ref[...].astype(jnp.float32)  # [bk, d]
    v = v_ref[...].astype(jnp.float32)
    bk, d = k.shape
    ki = pl.program_id(1)
    k_ids = ki * bk + jax.lax.iota(jnp.int32, bk)
    nq_total = seq_len // block_q
    # causal: q blocks strictly before this k block contribute nothing
    j0 = (ki * bk) // block_q if causal else 0

    def body(j, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.dslice(j * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        do = pl.load(do_ref, (pl.dslice(j * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        lse = pl.load(lse_ref, (pl.dslice(j * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(j * block_q, block_q),))
        s = scale * jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        if causal:
            q_ids = j * block_q + jax.lax.iota(jnp.int32, block_q)
            s = jnp.where(q_ids[:, None] >= k_ids[None, :], s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv_new = dv + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None])
        dk_new = dk + scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return dk_new, dv_new

    init = (jnp.zeros((bk, d), jnp.float32), jnp.zeros((bk, d), jnp.float32))
    dk, dv = jax.lax.fori_loop(j0, nq_total, body, init)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd(causal, block_q, block_k, res, do):
    q, k, v, out, lse = res
    h, s, d = q.shape
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    scale = float(1.0 / (d**0.5))
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, causal=causal, block_k=bk, seq_len=s, scale=scale
        ),
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, bq), lambda hi, qi: (hi, qi)),
            pl.BlockSpec((None, bq), lambda hi, qi: (hi, qi)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, causal=causal, block_q=bq, seq_len=s, scale=scale
        ),
        grid=(h, s // bk),
        in_specs=[
            pl.BlockSpec((None, s, d), lambda hi, ki: (hi, 0, 0)),
            pl.BlockSpec((None, bk, d), lambda hi, ki: (hi, ki, 0)),
            pl.BlockSpec((None, bk, d), lambda hi, ki: (hi, ki, 0)),
            pl.BlockSpec((None, s, d), lambda hi, ki: (hi, 0, 0)),
            pl.BlockSpec((None, s), lambda hi, ki: (hi, 0)),
            pl.BlockSpec((None, s), lambda hi, ki: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bk, d), lambda hi, ki: (hi, ki, 0)),
            pl.BlockSpec((None, bk, d), lambda hi, ki: (hi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, d), k.dtype),
            jax.ShapeDtypeStruct((h, s, d), v.dtype),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Flash attention over ``[heads, seq, head_dim]`` tensors.

    Matches :func:`..ref.attention_ref` to float tolerance; O(seq) memory in
    the forward (only the LSE row statistics are saved for the backward).
    """
    out, _ = _fwd(q, k, v, causal, block_q, block_k)
    return out


def _vjp_fwd(q, k, v, causal, block_q, block_k):
    out, lse = _fwd(q, k, v, causal, block_q, block_k)
    return out, (q, k, v, out, lse)


flash_attention.defvjp(_vjp_fwd, _bwd)
