"""L1: LayerNorm Pallas kernel (row-blocked, f32 statistics).

Standalone member of the kernel portfolio (the L2 model keeps its LayerNorm
in jnp for free autodiff); exercised by pytest/hypothesis against
``ref.layernorm_ref`` and by the kernel micro-benches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [rows, d]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """LayerNorm over the last axis of a 2-D ``[rows, d]`` tensor."""
    rows, d = x.shape
    br = min(block_rows, rows)
    while rows % br != 0:
        br -= 1
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
