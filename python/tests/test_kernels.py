"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
with hypothesis sweeping shapes and dtypes (the session's core
correctness signal for the compute layer)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.adamw import adamw_update
from compile.kernels.attention import flash_attention
from compile.kernels.layernorm import layernorm
from compile.kernels.matmul import matmul

SETTINGS = dict(max_examples=12, deadline=None, derandomize=True)


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    block=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, block, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (m, k), jnp.float32)
    y = rand(rng, (k, n), jnp.float32)
    got = matmul(x, y, block)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_matmul_dtypes(dtype, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (32, 48), dtype)
    y = rand(rng, (48, 16), dtype)
    got = matmul(x, y, 16)
    want = ref.matmul_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_matmul_gradients():
    rng = np.random.default_rng(0)
    x = rand(rng, (40, 24), jnp.float32)
    y = rand(rng, (24, 56), jnp.float32)
    f = lambda a, b: jnp.sum(jnp.sin(matmul(a, b, 16)))
    g = lambda a, b: jnp.sum(jnp.sin(ref.matmul_ref(a, b)))
    ga = jax.grad(f, argnums=(0, 1))(x, y)
    gb = jax.grad(g, argnums=(0, 1))(x, y)
    for u, w in zip(ga, gb):
        np.testing.assert_allclose(u, w, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        matmul(jnp.zeros((4, 5)), jnp.zeros((6, 7)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    heads=st.integers(1, 4),
    seq=st.sampled_from([16, 32, 64, 96]),
    hd=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    block=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(heads, seq, hd, causal, block, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, (heads, seq, hd), jnp.float32)
    k = rand(rng, (heads, seq, hd), jnp.float32)
    v = rand(rng, (heads, seq, hd), jnp.float32)
    got = flash_attention(q, k, v, causal, block, block)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    causal=st.booleans(),
    block=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**12),
)
def test_attention_gradients_match_ref(causal, block, seed):
    rng = np.random.default_rng(seed)
    shape = (2, 32, 16)
    q, k, v = (rand(rng, shape, jnp.float32) for _ in range(3))

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal, block, block) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=causal) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3, err_msg=f"d{name}")


def test_attention_block_size_invariance():
    rng = np.random.default_rng(1)
    q, k, v = (rand(rng, (2, 64, 16), jnp.float32) for _ in range(3))
    a = flash_attention(q, k, v, True, 16, 16)
    b = flash_attention(q, k, v, True, 64, 32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_attention_causality():
    """Perturbing a future key must not change earlier outputs."""
    rng = np.random.default_rng(2)
    q, k, v = (rand(rng, (1, 32, 8), jnp.float32) for _ in range(3))
    base = flash_attention(q, k, v, True, 8, 8)
    k2 = k.at[0, 20].add(100.0)
    v2 = v.at[0, 20].add(-50.0)
    pert = flash_attention(q, k2, v2, True, 8, 8)
    np.testing.assert_allclose(base[0, :20], pert[0, :20], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[0, 20:], pert[0, 20:])


def test_attention_lse_numerics_with_large_logits():
    """The online softmax must survive large logit magnitudes."""
    rng = np.random.default_rng(3)
    q = 30.0 * rand(rng, (1, 32, 8), jnp.float32)
    k = 30.0 * rand(rng, (1, 32, 8), jnp.float32)
    v = rand(rng, (1, 32, 8), jnp.float32)
    got = flash_attention(q, k, v, True, 8, 8)
    want = ref.attention_ref(q, k, v, causal=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# adamw
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    n=st.integers(1, 5000),
    step=st.integers(1, 1000),
    seed=st.integers(0, 2**16),
)
def test_adamw_matches_ref(n, step, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, (n,), jnp.float32)
    g = rand(rng, (n,), jnp.float32)
    m = 0.1 * rand(rng, (n,), jnp.float32)
    v = jnp.abs(0.1 * rand(rng, (n,), jnp.float32))
    s = jnp.asarray(step, jnp.int32)
    got = adamw_update(p, g, m, v, s)
    want = ref.adamw_ref(p, g, m, v, s)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adamw_nd_shapes_and_padding():
    rng = np.random.default_rng(5)
    for shape in [(3,), (7, 11), (2, 3, 5), (1025,), (8 * 128,)]:
        p = rand(rng, shape, jnp.float32)
        g = rand(rng, shape, jnp.float32)
        m = jnp.zeros(shape)
        v = jnp.zeros(shape)
        s = jnp.asarray(1, jnp.int32)
        got = adamw_update(p, g, m, v, s)
        want = ref.adamw_ref(p, g, m, v, s)
        for a, b in zip(got, want):
            assert a.shape == shape
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adamw_weight_decay_acts():
    p = jnp.ones((64,))
    z = jnp.zeros((64,))
    s = jnp.asarray(1, jnp.int32)
    no_wd, _, _ = adamw_update(p, z, z, z, s, weight_decay=0.0)
    wd, _, _ = adamw_update(p, z, z, z, s, weight_decay=0.1)
    np.testing.assert_allclose(no_wd, p)
    assert np.all(np.asarray(wd) < np.asarray(p))


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    rows=st.integers(1, 100),
    d=st.sampled_from([8, 32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (rows, d), jnp.float32)
    g = rand(rng, (d,), jnp.float32)
    b = rand(rng, (d,), jnp.float32)
    got = layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_layernorm_output_statistics():
    rng = np.random.default_rng(6)
    x = 5.0 + 3.0 * rand(rng, (64, 128), jnp.float32)
    y = layernorm(x, jnp.ones((128,)), jnp.zeros((128,)))
    np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.std(np.asarray(y), -1), 1.0, atol=1e-2)
