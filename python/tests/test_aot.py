"""AOT path: HLO text generation, manifest integrity, and smoke-artifact
round trip through XLA (compile + execute from the text form, the same
path the rust runtime takes)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrips_through_xla():
    """Lower a tiny jitted function to HLO text and re-execute it via the
    xla_client text parser (the rust side's exact ingestion path)."""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text and "f32[2,2]" in text

    # re-parse and run
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_smoke_artifact_exists_and_mentions_pallas_shape(tmp_path):
    aot.lower_smoke(str(tmp_path))
    text = (tmp_path / "smoke.hlo.txt").read_text()
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_lower_tiny_preset_manifest(tmp_path):
    man = aot.lower_preset(M.PRESETS["tiny"], str(tmp_path))
    n = man["n_params"]
    assert n == len(M.PRESETS["tiny"].param_specs())
    assert len(man["train_step"]["inputs"]) == 3 * n + 3
    assert len(man["train_step"]["outputs"]) == 3 * n + 2
    assert len(man["init"]["outputs"]) == 3 * n + 1
    assert man["eval"]["outputs"][0]["name"] == "loss"
    # files exist and parse as json
    with open(tmp_path / "tiny.manifest.json") as f:
        loaded = json.load(f)
    assert loaded["preset"] == "tiny"
    for entry in ("train_step", "init", "eval"):
        path = tmp_path / loaded[entry]["artifact"]
        assert path.exists(), entry
        assert path.stat().st_size > 1000


def test_built_artifacts_match_current_model():
    """If artifacts/ is built, its manifest must match the live config —
    catching ABI drift between python and rust."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    path = os.path.join(art, "tiny.manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        man = json.load(f)
    cfg = M.PRESETS["tiny"]
    assert man["n_params"] == len(cfg.param_specs())
    assert man["hyperparams"]["vocab"] == cfg.vocab
    assert man["hyperparams"]["seq"] == cfg.seq
    specs = {name: list(shape) for name, shape in cfg.param_specs()}
    for t in man["train_step"]["inputs"][: man["n_params"]]:
        assert specs[t["name"]] == t["shape"], t["name"]
