"""L2 model correctness: shapes, loss semantics, gradient flow, training
dynamics, and the flat-I/O ABI the rust runtime depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jnp.asarray(0, jnp.int32))


def batch(seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq)).astype(np.int32)
    tgts = np.roll(toks, -1, axis=1).astype(np.int32)
    return jnp.asarray(toks), jnp.asarray(tgts)


def test_param_specs_cover_all_params(params):
    names = [n for n, _ in CFG.param_specs()]
    assert set(names) == set(params.keys())
    for name, shape in CFG.param_specs():
        assert params[name].shape == shape, name


def test_param_count_tiny():
    # tiny: small but real (> 100k params)
    n = sum(int(np.prod(s)) for _, s in CFG.param_specs())
    assert 1e5 < n < 1e6


def test_forward_shapes(params):
    toks, _ = batch()
    logits = M.forward(params, toks, CFG)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert jnp.isfinite(logits).all()


def test_initial_loss_near_uniform(params):
    toks, tgts = batch()
    loss = M.loss_fn(params, toks, tgts, CFG)
    expected = np.log(CFG.vocab)
    assert abs(float(loss) - expected) < 0.5, f"{float(loss)} vs ln(V)={expected:.2f}"


def test_gradients_flow_to_every_parameter(params):
    toks, tgts = batch()
    grads = jax.grad(M.loss_fn)(params, toks, tgts, CFG)
    for name, g in grads.items():
        assert jnp.isfinite(g).all(), name
        # pos_embed rows beyond seq never receive gradient; all used
        # parameters must
        if name != "pos_embed":
            assert float(jnp.max(jnp.abs(g))) > 0.0, f"dead gradient: {name}"


def test_causality_of_model(params):
    """Changing a later input token must not change earlier logits."""
    toks, _ = batch()
    logits = M.forward(params, toks, CFG)
    toks2 = toks.at[0, CFG.seq - 1].set((int(toks[0, CFG.seq - 1]) + 1) % CFG.vocab)
    logits2 = M.forward(params, toks2, CFG)
    np.testing.assert_allclose(
        logits[0, : CFG.seq - 1], logits2[0, : CFG.seq - 1], rtol=1e-5, atol=1e-5
    )


def test_train_step_decreases_loss(params):
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    step = jnp.asarray(0, jnp.int32)
    toks, tgts = batch()
    jit_step = jax.jit(lambda p, m_, v_, s: M.train_step(p, m_, v_, s, toks, tgts, CFG))
    p = params
    losses = []
    for _ in range(20):
        p, m, v, step, loss = jit_step(p, m, v, step)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses
    assert int(step) == 20


def test_train_step_flat_roundtrip(params):
    """The flat entry point computes the same result as the dict API."""
    n = len(CFG.param_specs())
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    step = jnp.asarray(0, jnp.int32)
    toks, tgts = batch()

    ref_out = M.train_step(params, m, v, step, toks, tgts, CFG)
    flat_in = (
        M.flatten_params(CFG, params)
        + M.flatten_params(CFG, m)
        + M.flatten_params(CFG, v)
        + [step, toks, tgts]
    )
    flat_out = M.train_step_flat(CFG)(*flat_in)
    assert len(flat_out) == 3 * n + 2
    # params
    ref_flat = M.flatten_params(CFG, ref_out[0])
    for a, b in zip(flat_out[:n], ref_flat):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    # loss
    np.testing.assert_allclose(flat_out[-1], ref_out[-1], rtol=1e-6)


def test_init_flat_layout():
    n = len(CFG.param_specs())
    out = M.init_flat(CFG)(jnp.asarray(0, jnp.int32))
    assert len(out) == 3 * n + 1
    # moments start at zero
    for x in out[n : 3 * n]:
        assert float(jnp.max(jnp.abs(x))) == 0.0
    assert int(out[-1]) == 0
    # params match shapes
    for x, (_, shape) in zip(out[:n], CFG.param_specs()):
        assert x.shape == shape


def test_eval_flat_matches_loss(params):
    toks, tgts = batch()
    want = M.loss_fn(params, toks, tgts, CFG)
    (got,) = M.eval_flat(CFG)(*(M.flatten_params(CFG, params) + [toks, tgts]))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_determinism_of_init():
    a = M.init_params(CFG, jnp.asarray(7, jnp.int32))
    b = M.init_params(CFG, jnp.asarray(7, jnp.int32))
    c = M.init_params(CFG, jnp.asarray(8, jnp.int32))
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_presets_are_consistent():
    for name, cfg in M.PRESETS.items():
        assert cfg.name == name
        assert cfg.hidden % cfg.heads == 0, name
        n = sum(int(np.prod(s)) for _, s in cfg.param_specs())
        if name == "base100m":
            assert 9e7 < n < 1.5e8, f"{name}: {n}"
        if name == "small25m":
            assert 1e7 < n < 4e7, f"{name}: {n}"
