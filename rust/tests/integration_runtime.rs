//! Integration: the PJRT runtime over real AOT artifacts — the three-layer
//! contract. These tests skip (with a message) when `make artifacts` has
//! not run; the Makefile runs it before `cargo test`. The whole suite
//! needs the `pjrt` feature (the xla crate is not in the offline vendor
//! set).
#![cfg(feature = "pjrt")]

use scalepool::calculon::Parallelism;
use scalepool::coordinator::{EmulatedCluster, TrainJobScheduler};
use scalepool::runtime::{self, ArtifactManifest, SyntheticCorpus, Trainer};

fn artifacts() -> Option<std::path::PathBuf> {
    if runtime::artifacts_available("tiny") {
        Some(runtime::default_artifacts_dir())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// init -> N steps -> eval, loss decreasing, deterministic across runs.
#[test]
fn train_loop_deterministic() {
    let Some(dir) = artifacts() else { return };
    let run = |seed: i32| -> Vec<f32> {
        let mut t = Trainer::load(&dir, "tiny").unwrap();
        t.init(seed).unwrap();
        let m = t.manifest().clone();
        let mut corpus = SyntheticCorpus::new(m.vocab, 9);
        (0..8)
            .map(|_| {
                let (toks, tgts) = corpus.batch(m.batch, m.seq);
                t.step(&toks, &tgts).unwrap().loss
            })
            .collect()
    };
    let a = run(0);
    let b = run(0);
    assert_eq!(a, b, "same seed, same losses");
    let c = run(1);
    assert_ne!(a, c, "different init seed changes the trajectory");
}

/// The eval artifact agrees with the train artifact's loss on identical
/// parameters and batch (two independently lowered programs).
#[test]
fn eval_matches_train_loss() {
    let Some(dir) = artifacts() else { return };
    let mut t = Trainer::load(&dir, "tiny").unwrap();
    t.init(3).unwrap();
    let m = t.manifest().clone();
    let mut corpus = SyntheticCorpus::new(m.vocab, 5);
    let (toks, tgts) = corpus.batch(m.batch, m.seq);
    // eval before the step sees the same params the step starts from
    let ev = t.eval(&toks, &tgts).unwrap();
    let st = t.step(&toks, &tgts).unwrap();
    let rel = (ev - st.loss).abs() / st.loss;
    assert!(rel < 1e-4, "eval {ev} vs train-step loss {} (rel {rel})", st.loss);
}

/// Manifest ABI matches what the executables actually accept (wrong-shape
/// inputs must be rejected, right-shape accepted).
#[test]
fn abi_shape_enforcement() {
    let Some(dir) = artifacts() else { return };
    let mut t = Trainer::load(&dir, "tiny").unwrap();
    t.init(0).unwrap();
    let m = t.manifest().clone();
    let good = vec![0i32; m.batch * m.seq];
    assert!(t.step(&good, &good).is_ok());
    let bad = vec![0i32; m.batch * m.seq + 1];
    assert!(t.step(&bad, &good).is_err(), "oversized batch must be rejected");
}

/// Scheduler end-to-end on the real runtime: loss decreases, emulated
/// clocks advance, ScalePool beats baseline.
#[test]
fn scheduler_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let trainer = Trainer::load(&dir, "tiny").unwrap();
    let m = trainer.manifest().clone();
    let cluster = EmulatedCluster::for_preset(
        m.vocab,
        64,
        2,
        2,
        m.seq,
        256,
        Parallelism { tp: 4, pp: 2, dp: 8, microbatch: 1 },
    );
    let mut sched = TrainJobScheduler::new(trainer, cluster, 1);
    sched.init(0).unwrap();
    sched.run(20).unwrap();
    let log = sched.log();
    assert_eq!(log.len(), 20);
    let first: f32 = log[..5].iter().map(|l| l.loss).sum::<f32>() / 5.0;
    let last: f32 = log[15..].iter().map(|l| l.loss).sum::<f32>() / 5.0;
    assert!(last < first, "avg loss must decrease: {first} -> {last}");
    assert!(sched.emulated_speedup() > 1.0);
}

/// All generated presets have consistent manifests.
#[test]
fn all_built_presets_manifest_consistency() {
    let Some(dir) = artifacts() else { return };
    for preset in ["tiny", "small25m", "base100m"] {
        if !runtime::artifacts_available(preset) {
            continue;
        }
        let m = ArtifactManifest::load(&dir, preset).unwrap();
        assert_eq!(m.preset, preset);
        assert_eq!(m.train_step.inputs.len(), 3 * m.n_params + 3, "{preset}");
        // param count equals the sum of parameter tensor elements
        let total: usize = m.train_step.inputs[..m.n_params].iter().map(|t| t.elements()).sum();
        assert_eq!(total as u64, m.param_count, "{preset}");
        assert!(m.train_step.artifact.exists() && m.init.artifact.exists() && m.eval.artifact.exists());
    }
}
