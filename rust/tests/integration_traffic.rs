//! Integration: the unified traffic layer — event-driven collective
//! schedules validated against the analytic model on an uncontended
//! fabric (the acceptance bar: within 5%), cross-traffic interference
//! visible in the mixed experiment, and streamed injection behaving like
//! the batch path.

use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::collective::{Algorithm, CollectiveModel, EventDrivenCollective, Transport};
use scalepool::experiments::{run_mixed, MixedConfig};
use scalepool::fabric::{Fabric, LinkKind, NodeKind, Topology, TopologyKind};
use scalepool::sim::{MemSim, TrafficClass, TrafficSource};
use scalepool::workloads::SyntheticTraffic;

fn rack(n: usize) -> (Fabric, Vec<usize>) {
    let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
    let accs = t.nodes_of(NodeKind::Accelerator);
    (Fabric::new(t), accs)
}

fn run_collective(c: &mut EventDrivenCollective, f: &Fabric) -> scalepool::sim::StreamReport {
    let mut sim = MemSim::new(f);
    let mut sources: [&mut dyn TrafficSource; 1] = [c];
    sim.run_streamed(&mut sources)
}

/// Acceptance: the event-driven ring all-reduce matches the analytic
/// `CollectiveModel` within 5% on an uncontended fabric, across rank
/// counts and buffer sizes.
#[test]
fn event_driven_ring_matches_analytic_within_5pct() {
    for n in [4usize, 8, 16] {
        for bytes_per_rank in [256.0 * 1024.0, 8.0 * 1024.0 * 1024.0] {
            let (f, accs) = rack(n);
            let chunk = bytes_per_rank / n as f64;
            // the analytic counterpart: a transport calibrated to the
            // simulator's store-and-forward walk of one ring hop
            let t = Transport::from_sim_path(&f, accs[0], accs[1], chunk).unwrap();
            let analytic = CollectiveModel::flat(t).all_reduce(n, bytes_per_rank, Algorithm::Ring);
            let mut c = EventDrivenCollective::ring(accs, bytes_per_rank, 1);
            let rep = run_collective(&mut c, &f);
            let event = rep.total.makespan_ns;
            let err = (event - analytic).abs() / analytic;
            assert!(
                err < 0.05,
                "n={n} bytes={bytes_per_rank}: event {event} vs analytic {analytic} ({:.1}% off)",
                100.0 * err
            );
        }
    }
}

/// The hierarchical schedule has the same three-phase structure as the
/// analytic model; on a real multi-rack system (where leader traffic can
/// share spine links) it must stay within a loose band of the analytic
/// estimate built from per-phase calibrated transports.
#[test]
fn event_driven_hierarchical_tracks_analytic() {
    let sys = ScalePoolBuilder::new()
        .racks((0..4).map(|i| Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 8).unwrap()))
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 2,
            ..Default::default()
        })
        .build();
    let groups = sys.rack_groups();
    let g = groups[0].len();
    let l = groups.len();
    let bytes = 16.0 * 1024.0 * 1024.0;
    let inner_chunk = bytes / g as f64;
    let outer_chunk = bytes / (g * l) as f64;
    let inner = Transport::from_sim_path(&sys.fabric, groups[0][0], groups[0][1], inner_chunk).unwrap();
    let outer = Transport::from_sim_path(&sys.fabric, groups[0][0], groups[1][0], outer_chunk).unwrap();
    let analytic =
        CollectiveModel::hierarchical(outer, inner, g).all_reduce(g * l, bytes, Algorithm::Hierarchical);
    let mut c = EventDrivenCollective::hierarchical(groups, bytes, 1);
    let rep = run_collective(&mut c, &sys.fabric);
    let event = rep.total.makespan_ns;
    let ratio = event / analytic;
    assert!(
        (0.7..3.0).contains(&ratio),
        "hierarchical event {event} vs analytic {analytic} (ratio {ratio:.2})"
    );
    // structure: every phase transfer completed
    assert_eq!(c.transfers() as usize, l * g * (g - 1) * 2 + l * 2 * (l - 1));
}

/// Background traffic on the same links must slow a collective down —
/// interference between classes, the effect the closed-form silo models
/// could not produce.
#[test]
fn background_traffic_inflates_collective() {
    let (f, accs) = rack(8);
    let bytes = 8.0 * 1024.0 * 1024.0;
    let solo = {
        let mut c = EventDrivenCollective::ring(accs.clone(), bytes, 1);
        run_collective(&mut c, &f).class(TrafficClass::Collective).latency.mean()
    };
    let mixed = {
        let mut c = EventDrivenCollective::ring(accs.clone(), bytes, 1);
        // heavy synthetic load across the same endpoints
        let mut bg = SyntheticTraffic::new(accs, vec![], 5_000, 65_536.0, 50.0, 3);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 2] = [&mut c, &mut bg];
            sim.run_streamed(&mut sources)
        };
        rep.class(TrafficClass::Collective).latency.mean()
    };
    assert!(
        mixed > 1.05 * solo,
        "background load must queue the collective: mixed {mixed} vs solo {solo}"
    );
}

/// The mixed experiment end-to-end: all classes move traffic and at
/// least one shows measurable inflation under interference.
#[test]
fn mixed_experiment_reports_interference() {
    let cfg = MixedConfig {
        coherence_ops: 600,
        tiering_ops: 150,
        collective_bytes: 8.0 * 1024.0 * 1024.0,
        ..Default::default()
    };
    let r = run_mixed(&cfg);
    for row in &r.rows {
        assert!(row.completed > 0, "{} idle", row.class.name());
    }
    assert!(r.max_tx_inflation() > 1.02, "max inflation {:.3}", r.max_tx_inflation());
    assert!(r.mixed_peak_utilization > 0.0 && r.mixed_peak_utilization <= 1.0);
}
