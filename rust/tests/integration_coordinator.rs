//! Integration: the coordinator (manager + router + tiering) operating a
//! whole ScalePool system through realistic job churn and data movement.

use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::coordinator::{
    DataMovementRouter, JobSpec, RouteClass, ScalePoolManager, TieringEngine, TieringPolicy,
};
use scalepool::fabric::TopologyKind;
use scalepool::memory::pool::MemoryPool;
use scalepool::memory::Tier;
use scalepool::util::Rng;

fn system() -> scalepool::cluster::ScalePoolSystem {
    ScalePoolBuilder::new()
        .racks((0..4).map(|i| Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 8).unwrap()))
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 4,
            mem_node_capacity: 4e12,
            ..Default::default()
        })
        .build()
}

/// A multi-tenant day in the life: admissions, routing for each job's
/// traffic, tiering churn, releases — all invariants hold throughout.
#[test]
fn multi_tenant_lifecycle() {
    let sys = system();
    let mut mgr = ScalePoolManager::new(&sys);
    let router = DataMovementRouter::new(&sys);

    let mut t1 = MemoryPool::new();
    for (i, r) in sys.racks.iter().enumerate() {
        t1.add_region(r.acc_ids[0], Tier::Tier1Local, sys.rack_hbm_capacity(i));
    }
    let mut t2 = MemoryPool::new();
    for &m in &sys.mem_nodes {
        t2.add_region(m, Tier::Tier2Pool, sys.config.mem_node_capacity);
    }
    let mut tiering = TieringEngine::new(t1, t2, TieringPolicy::default());

    let mut rng = Rng::new(31);
    let mut jobs = Vec::new();
    let mut objects = Vec::new();
    for round in 0..300 {
        match rng.below(4) {
            0 => {
                let accs = 1 + rng.below(12) as usize;
                if let Ok(g) = mgr.admit(&JobSpec {
                    name: format!("job{round}"),
                    accelerators: accs,
                    pool_bytes: rng.f64_range(0.0, 1e12),
                }) {
                    jobs.push(g.job);
                }
            }
            1 => {
                if let Some(&job) = jobs.first() {
                    if rng.f64() < 0.5 {
                        mgr.release(job);
                        jobs.remove(0);
                    }
                }
            }
            2 => {
                if let Ok(id) = tiering.alloc(rng.f64_range(1e9, 5e11)) {
                    objects.push(id);
                }
            }
            _ => {
                if !objects.is_empty() {
                    let id = objects[rng.below(objects.len() as u64) as usize];
                    tiering.touch(id);
                    if rng.f64() < 0.2 {
                        let idx = objects.iter().position(|&o| o == id).unwrap();
                        objects.swap_remove(idx);
                        tiering.free(id).unwrap();
                    }
                }
            }
        }
        // route a random transfer and check the class is sane
        let src = sys.racks[rng.below(4) as usize].acc_ids[rng.below(8) as usize];
        let d = router.route(src, sys.mem_nodes[rng.below(4) as usize], 16384.0);
        assert_eq!(d.class, RouteClass::CxlTier2);
        assert!(d.est_latency_ns > 0.0);

        mgr.check_invariants().unwrap();
        tiering.check_invariants().unwrap();
    }
    assert!(mgr.metrics.counter("jobs_admitted") > 20);
}

/// Admission is work-conserving: a job that fits always lands, and the
/// manager never grants the same accelerator twice.
#[test]
fn admission_never_double_books() {
    let sys = system();
    let mut mgr = ScalePoolManager::new(&sys);
    let mut granted = std::collections::HashSet::new();
    let mut rng = Rng::new(7);
    loop {
        let want = 1 + rng.below(6) as usize;
        match mgr.admit(&JobSpec { name: "x".into(), accelerators: want, pool_bytes: 0.0 }) {
            Ok(g) => {
                for (rack, accs) in &g.accelerators {
                    for &a in accs {
                        assert!(granted.insert((*rack, a)), "double-booked ({rack},{a})");
                    }
                }
            }
            Err(_) => break,
        }
    }
    assert_eq!(granted.len(), 32, "all 32 accelerators eventually granted");
    assert_eq!(mgr.free_accelerators(), 0);
}

/// Tiering under sustained pressure: demotions free tier-1, hot objects
/// come back, accounting stays exact.
#[test]
fn tiering_pressure_cycle() {
    let mut t1 = MemoryPool::new();
    t1.add_region(0, Tier::Tier1Local, 100.0);
    let mut t2 = MemoryPool::new();
    t2.add_region(1, Tier::Tier2Pool, 10_000.0);
    let mut e = TieringEngine::new(t1, t2, TieringPolicy { t1_high_watermark: 0.95, promote_heat: 4 });

    // fill tier-1
    let residents: Vec<u64> = (0..9).map(|_| e.alloc(10.0).unwrap()).collect();
    for &r in &residents {
        assert_eq!(e.tier_of(r), Some(Tier::Tier1Local));
    }
    // next allocations spill
    let spilled: Vec<u64> = (0..5).map(|_| e.alloc(10.0).unwrap()).collect();
    for &s in &spilled {
        assert_eq!(e.tier_of(s), Some(Tier::Tier2Pool));
    }
    // make room, heat a spilled object, watch it promote
    e.demote_coldest().unwrap();
    e.demote_coldest().unwrap();
    for _ in 0..4 {
        e.touch(spilled[0]);
    }
    assert_eq!(e.tier_of(spilled[0]), Some(Tier::Tier1Local));
    let st = e.stats();
    assert!(st.promotions >= 1 && st.demotions >= 2 && st.tier2_spills >= 5);
    e.check_invariants().unwrap();
}
