//! Property-based tests (via the in-tree `util::prop` harness) on the
//! coordinator and substrate invariants: routing, allocation, tiering,
//! coherence, collectives and the latency models — randomized inputs,
//! seed-reported failures.

use scalepool::coherence::{CoherenceConfig, CoherenceTraffic, Directory, MsgKind, ProtocolMsg};
use scalepool::collective::{Algorithm, CollectiveModel, EventDrivenCollective, Transport};
use scalepool::coordinator::{TieringEngine, TieringPolicy};
use scalepool::fabric::{Fabric, LinkKind, NodeKind, Topology};
use scalepool::memory::pool::{MemoryPool, Placement};
use scalepool::memory::tier::{waterfall_placement, TierSpec};
use scalepool::memory::Tier;
use scalepool::sim::{
    ArbPolicy, BatchSource, MemSim, QosPolicy, RailSelector, RoutingPolicy, ShardMode,
    TrafficClass, TrafficSource, Transaction,
};
use scalepool::util::prop::{forall_res, Config};
use scalepool::util::Rng;

/// One of the four Figure-4a fabric shapes, randomized — the generator
/// family shared by the routing-parity and multipath properties.
fn random_fabric_shape(rng: &mut Rng) -> Topology {
    match rng.below(4) {
        0 => Topology::single_hop(2 + rng.below(30) as usize, LinkKind::NvLink5, "r"),
        1 => {
            let (mut t, leaves) = Topology::clos(
                2 + rng.below(6) as usize,
                1 + rng.below(4) as usize,
                LinkKind::CxlCoherent,
                "c",
            );
            let eps = 1 + rng.below(3) as usize;
            for (i, &l) in leaves.iter().enumerate() {
                for e in 0..eps {
                    let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                    t.connect(n, l, LinkKind::CxlCoherent);
                }
            }
            t
        }
        2 => Topology::torus3d(
            (1 + rng.below(4) as usize, 1 + rng.below(4) as usize, 1 + rng.below(4) as usize),
            LinkKind::CxlCoherent,
            "t",
        )
        .0,
        _ => Topology::dragonfly(
            2 + rng.below(4) as usize,
            2 + rng.below(4) as usize,
            LinkKind::CxlCoherent,
            "d",
        )
        .0,
    }
}

/// Routing: on random connected topologies, every pair has a path, the
/// path is loop-free, and PBR walks reproduce it.
#[test]
fn prop_routing_sound_on_random_graphs() {
    forall_res(
        Config { cases: 60, seed: 0xA11CE },
        |rng: &mut Rng| {
            // random connected graph: a tree plus extra chords
            let n = 4 + rng.below(20) as usize;
            let mut t = Topology::new();
            for i in 0..n {
                t.add_switch(
                    scalepool::fabric::SwitchParams::for_link(LinkKind::CxlCoherent),
                    format!("s{i}"),
                );
            }
            for i in 1..n {
                let parent = rng.below(i as u64) as usize;
                t.connect(parent, i, LinkKind::CxlCoherent);
            }
            for _ in 0..rng.below(n as u64) {
                let a = rng.below(n as u64) as usize;
                let b = rng.below(n as u64) as usize;
                if a != b {
                    t.connect(a, b, LinkKind::CxlCoherent);
                }
            }
            let probes: Vec<(usize, usize)> = (0..10)
                .map(|_| (rng.below(n as u64) as usize, rng.below(n as u64) as usize))
                .collect();
            (t, probes)
        },
        |(t, probes)| {
            let f = Fabric::new(t.clone());
            for &(a, b) in probes {
                let p = f.path(a, b).ok_or(format!("no path {a}->{b}"))?;
                // loop-free
                let mut seen = std::collections::HashSet::new();
                for &n in &p.nodes {
                    if !seen.insert(n) {
                        return Err(format!("loop at node {n}"));
                    }
                }
                // PBR walk reproduces it
                let mut cur = a;
                for &l in &p.links {
                    let port = f.router().pbr_port(cur, b).ok_or("missing PBR entry")?;
                    if port != l {
                        return Err(format!("PBR port {port} != path link {l}"));
                    }
                    let link = f.topo.link(l);
                    cur = if link.a == cur { link.b } else { link.a };
                }
                if cur != b {
                    return Err("PBR walk did not reach dst".into());
                }
                // latency positive and monotone in size
                if a != b {
                    let l1 = f.latency_ns(a, b, 64.0).unwrap();
                    let l2 = f.latency_ns(a, b, 1e6).unwrap();
                    if !(l1 > 0.0 && l2 > l1) {
                        return Err(format!("latency not monotone: {l1} vs {l2}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Routing parity: the flattened, parallel-built PBR table must yield
/// byte-identical paths to the reference serial BFS (the pre-flattening
/// implementation, kept in `fabric::routing::reference`) on randomized
/// topologies of all four Figure-4a shapes — and identical tables
/// regardless of worker count.
#[test]
fn prop_flat_parallel_routing_matches_serial_reference() {
    use scalepool::fabric::routing::reference::SerialRouter;
    use scalepool::fabric::Router;
    forall_res(
        Config { cases: 48, seed: 0xF1A7 },
        |rng: &mut Rng| {
            let t = match rng.below(4) {
                0 => Topology::single_hop(2 + rng.below(30) as usize, LinkKind::NvLink5, "r"),
                1 => {
                    let (mut t, leaves) = Topology::clos(
                        2 + rng.below(6) as usize,
                        1 + rng.below(4) as usize,
                        LinkKind::CxlCoherent,
                        "c",
                    );
                    let eps = 1 + rng.below(3) as usize;
                    for (i, &l) in leaves.iter().enumerate() {
                        for e in 0..eps {
                            let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                            t.connect(n, l, LinkKind::CxlCoherent);
                        }
                    }
                    t
                }
                2 => {
                    Topology::torus3d(
                        (
                            1 + rng.below(4) as usize,
                            1 + rng.below(4) as usize,
                            1 + rng.below(4) as usize,
                        ),
                        LinkKind::CxlCoherent,
                        "t",
                    )
                    .0
                }
                _ => {
                    Topology::dragonfly(
                        2 + rng.below(4) as usize,
                        2 + rng.below(4) as usize,
                        LinkKind::CxlCoherent,
                        "d",
                    )
                    .0
                }
            };
            let n = t.nodes.len();
            let probes: Vec<(usize, usize)> = (0..24)
                .map(|_| (rng.below(n as u64) as usize, rng.below(n as u64) as usize))
                .collect();
            let threads = 1 + rng.below(4) as usize;
            (t, probes, threads)
        },
        |(t, probes, threads)| {
            let flat = Router::build(t);
            let flat_t = Router::build_with_threads(t, *threads);
            let oracle = SerialRouter::build(t);
            let n = t.nodes.len();
            // exhaustive on small graphs, sampled on larger ones
            let pairs: Vec<(usize, usize)> = if n <= 24 {
                (0..n).flat_map(|a| (0..n).map(move |b| (a, b))).collect()
            } else {
                probes.clone()
            };
            for (a, b) in pairs {
                let want = oracle.path(a, b);
                if flat.path(a, b) != want {
                    return Err(format!("flat path {a}->{b} != serial reference"));
                }
                if flat_t.path(a, b) != want {
                    return Err(format!("{threads}-thread path {a}->{b} != serial reference"));
                }
                // the hot-path link walk must agree with the full path
                let mut links = Vec::new();
                let reachable = flat.links_into(a, b, &mut links);
                match want {
                    Some(p) => {
                        if !reachable || links != p.links {
                            return Err(format!("links_into {a}->{b} != reference links"));
                        }
                    }
                    None => {
                        if reachable {
                            return Err(format!("links_into {a}->{b} found a phantom path"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Multipath routing: on randomized Clos/torus/dragonfly/single-hop
/// topologies, every rail in every multipath cell is a genuine
/// equal-cost shortest alternative — each raw cell candidate sits one
/// hop closer to `dst` over a link that really connects the two nodes,
/// and every fixed-rail table walk reaches `dst` in exactly
/// `hops(src, dst)` hops with no repeated node.
#[test]
fn prop_multipath_rails_are_shortest_and_loop_free() {
    use scalepool::fabric::Router;
    forall_res(
        Config { cases: 36, seed: 0x4A115 },
        |rng: &mut Rng| {
            let t = random_fabric_shape(rng);
            let k = 2 + rng.below(3) as usize; // 2..=4 rails
            let n = t.nodes.len();
            let probes: Vec<(usize, usize)> = (0..16)
                .map(|_| (rng.below(n as u64) as usize, rng.below(n as u64) as usize))
                .collect();
            (t, k, probes)
        },
        |(t, k, probes)| {
            let r = Router::build_multipath(t, *k);
            let n = t.nodes.len();
            let pairs: Vec<(usize, usize)> = if n <= 20 {
                (0..n).flat_map(|a| (0..n).map(move |b| (a, b))).collect()
            } else {
                probes.clone()
            };
            for (a, b) in pairs {
                let h = r.hops(a, b).ok_or(format!("no route {a}->{b} on a connected shape"))?;
                // every fixed-rail walk is shortest and loop-free
                for rail in 0..*k {
                    let p = r.path_rail(a, b, rail).ok_or("rail walk lost the route")?;
                    if p.hops() != h {
                        return Err(format!("rail {rail} of {a}->{b}: {} hops != {h}", p.hops()));
                    }
                    let mut seen = std::collections::HashSet::new();
                    for &node in &p.nodes {
                        if !seen.insert(node) {
                            return Err(format!("rail {rail} of {a}->{b} repeats node {node}"));
                        }
                    }
                }
                // every raw cell candidate is one hop closer over a real link
                if a != b {
                    for rail in 0..r.rails(a, b) {
                        let (nxt, link) = r.rail_entry(a, b, rail).unwrap();
                        let hn = r.hops(nxt, b).ok_or("candidate lost the route")?;
                        if hn + 1 != h {
                            return Err(format!(
                                "rail {rail} of cell ({a}, dst {b}) is not equal-cost: {hn}+1 != {h}"
                            ));
                        }
                        let l = t.link(link);
                        if !((l.a == a && l.b == nxt) || (l.b == a && l.a == nxt)) {
                            return Err(format!("rail {rail} link {link} does not connect {a}<->{nxt}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Multipath parity: rail 0 of a multipath table — entries, paths and
/// the hot-path link walk — is byte-identical to the single-path router
/// and the seed `SerialRouter` oracle on the same randomized shapes.
#[test]
fn prop_deterministic_rail_matches_single_path() {
    use scalepool::fabric::routing::reference::SerialRouter;
    use scalepool::fabric::Router;
    forall_res(
        Config { cases: 36, seed: 0xD137 },
        |rng: &mut Rng| {
            let t = random_fabric_shape(rng);
            let k = 2 + rng.below(3) as usize;
            let n = t.nodes.len();
            let probes: Vec<(usize, usize)> = (0..20)
                .map(|_| (rng.below(n as u64) as usize, rng.below(n as u64) as usize))
                .collect();
            (t, k, probes)
        },
        |(t, k, probes)| {
            let multi = Router::build_multipath(t, *k);
            let single = Router::build(t);
            let oracle = SerialRouter::build(t);
            let n = t.nodes.len();
            let pairs: Vec<(usize, usize)> = if n <= 20 {
                (0..n).flat_map(|a| (0..n).map(move |b| (a, b))).collect()
            } else {
                probes.clone()
            };
            for (a, b) in pairs {
                let want = oracle.path(a, b);
                if single.path(a, b) != want {
                    return Err(format!("single path {a}->{b} != serial reference"));
                }
                if multi.path(a, b) != want {
                    return Err(format!("multipath rail-0 path {a}->{b} != serial reference"));
                }
                if multi.path_rail(a, b, 0) != want {
                    return Err(format!("path_rail(0) {a}->{b} != serial reference"));
                }
                if multi.next_hop(a, b) != single.next_hop(a, b) {
                    return Err(format!("rail-0 next_hop {a}->{b} diverged"));
                }
                let mut links = Vec::new();
                let reachable = multi.links_into(a, b, &mut links);
                match &want {
                    Some(p) => {
                        if !reachable || links != p.links {
                            return Err(format!("multipath links_into {a}->{b} != reference"));
                        }
                    }
                    None => {
                        if reachable {
                            return Err(format!("links_into {a}->{b} found a phantom path"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// End-to-end deterministic-routing parity (the PR's acceptance bar):
/// the same randomized workload on a multipath-enabled fabric under the
/// all-deterministic policy produces *bit-identical* per-run results —
/// completions, makespan, latency moments — to the single-path fabric.
#[test]
fn prop_deterministic_routing_parity() {
    forall_res(
        Config { cases: 14, seed: 0xDE7A11 },
        |rng: &mut Rng| {
            let (mut t, leaves) = Topology::clos(
                2 + rng.below(5) as usize,
                1 + rng.below(4) as usize,
                LinkKind::CxlCoherent,
                "c",
            );
            let per = 1 + rng.below(4) as usize;
            let mut eps = Vec::new();
            for (i, &l) in leaves.iter().enumerate() {
                for e in 0..per {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                    t.connect(n, l, LinkKind::CxlCoherent);
                    eps.push(n);
                }
            }
            let ntx = 80 + rng.below(300) as usize;
            (t, eps, ntx, rng.below(1 << 30))
        },
        |(t, eps, ntx, seed)| {
            if eps.len() < 2 {
                return Ok(());
            }
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    at += rng.exp(1.0 / 30.0);
                    let s = rng.below(eps.len() as u64) as usize;
                    let mut d = rng.below(eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction {
                        src: eps[s],
                        dst: eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 4096.0,
                        device_ns: rng.f64() * 150.0,
                    }
                })
                .collect();
            let single_fabric = Fabric::new(t.clone());
            let mut single_sim = MemSim::new(&single_fabric);
            let a = single_sim.run(txs.clone());
            let mut multi_fabric = Fabric::new(t.clone());
            multi_fabric.enable_multipath(4);
            let mut multi_sim = MemSim::new(&multi_fabric); // default: deterministic
            let b = multi_sim.run(txs.clone());
            if a.completed != b.completed {
                return Err(format!("completed {} vs {}", a.completed, b.completed));
            }
            if a.makespan_ns != b.makespan_ns {
                return Err(format!("makespan {} vs {} (must be exact)", a.makespan_ns, b.makespan_ns));
            }
            if a.latency.mean() != b.latency.mean() || a.latency.max() != b.latency.max() {
                return Err("latency stats not bit-identical".into());
            }
            if a.events != b.events {
                return Err(format!("event counts {} vs {}", a.events, b.events));
            }
            Ok(())
        },
    );
}

/// Pool allocator: random alloc/free sequences conserve bytes, never
/// overcommit a region, and every policy places exactly what was asked.
#[test]
fn prop_pool_conservation() {
    forall_res(
        Config { cases: 120, seed: 0xB0B },
        |rng: &mut Rng| {
            let regions: Vec<f64> = (0..1 + rng.below(5)).map(|_| rng.f64_range(10.0, 1000.0)).collect();
            let ops: Vec<(bool, f64, u8)> = (0..50)
                .map(|_| (rng.f64() < 0.65, rng.f64_range(1.0, 300.0), rng.below(3) as u8))
                .collect();
            (regions, ops)
        },
        |(regions, ops)| {
            let mut p = MemoryPool::new();
            for (i, &c) in regions.iter().enumerate() {
                p.add_region(i, Tier::Tier1Local, c);
            }
            let cap = p.capacity();
            let mut live = Vec::new();
            for &(is_alloc, bytes, pol) in ops {
                if is_alloc {
                    let policy = match pol {
                        0 => Placement::FirstFit,
                        1 => Placement::Interleave,
                        _ => Placement::WorstFit,
                    };
                    match p.alloc(bytes, policy) {
                        Ok(a) => {
                            let placed: f64 = a.extents.iter().map(|(_, b)| b).sum();
                            if (placed - bytes).abs() > 1e-6 {
                                return Err(format!("placed {placed} != asked {bytes}"));
                            }
                            live.push(a.id);
                        }
                        Err(_) => {
                            if bytes <= p.available() - 1e-6 {
                                return Err(format!(
                                    "spurious OOM: {bytes} <= {} available",
                                    p.available()
                                ));
                            }
                        }
                    }
                } else if !live.is_empty() {
                    let id = live.remove(0);
                    p.free(id).map_err(|e| e.to_string())?;
                }
                p.check_invariants()?;
                if p.used() > cap + 1e-6 {
                    return Err("overcommitted".into());
                }
            }
            Ok(())
        },
    );
}

/// Waterfall placement conserves bytes and respects capacities for any
/// tier stack and working set.
#[test]
fn prop_waterfall_conservation() {
    forall_res(
        Config { cases: 200, seed: 0xCAFE },
        |rng: &mut Rng| {
            let tiers: Vec<TierSpec> = (0..1 + rng.below(4))
                .map(|_| TierSpec::tier1_local(rng.f64_range(1.0, 1e4)))
                .collect();
            (tiers, rng.f64_range(0.1, 5e4))
        },
        |(tiers, ws)| {
            let placement = waterfall_placement(*ws, tiers);
            let placed: f64 = placement.iter().map(|(_, b)| b).sum();
            if (placed - ws).abs() > 1e-6 {
                return Err(format!("placed {placed} != ws {ws}"));
            }
            for (i, (spec, bytes)) in placement.iter().enumerate() {
                if *bytes > spec.capacity + 1e-9 {
                    return Err(format!("level {i} over capacity"));
                }
                if i + 1 < placement.len() && (spec.capacity - bytes).abs() > 1e-9 {
                    return Err(format!("level {i} not filled before spilling"));
                }
            }
            Ok(())
        },
    );
}

/// MESI directory: single-writer-multiple-readers invariant holds under
/// arbitrary interleavings, and hits never generate traffic.
#[test]
fn prop_mesi_swmr() {
    forall_res(
        Config { cases: 80, seed: 0xD1CE },
        |rng: &mut Rng| {
            let agents = 2 + rng.below(7) as usize;
            let ops: Vec<(usize, u64, u8)> = (0..300)
                .map(|_| (rng.below(agents as u64) as usize, rng.below(32), rng.below(3) as u8))
                .collect();
            (agents, ops)
        },
        |(agents, ops)| {
            let mut d = Directory::new(*agents);
            for &(a, block, op) in ops {
                let before = d.state_of(a, block);
                let m = match op {
                    0 => d.read(a, block),
                    1 => d.write(a, block),
                    _ => d.evict(a, block),
                };
                // a hit (already readable/owned) costs nothing
                if op == 0 && before != scalepool::coherence::MesiState::Invalid && m.total() != 0 {
                    return Err("read hit generated traffic".into());
                }
                d.check_invariants()?;
            }
            Ok(())
        },
    );
}

/// Collectives: all-reduce time is monotone in message size and never
/// cheaper than a single p2p of the per-step chunk; reduce-scatter +
/// all-gather equals ring all-reduce exactly.
#[test]
fn prop_collective_identities() {
    forall_res(
        Config { cases: 150, seed: 0xFEED },
        |rng: &mut Rng| {
            let t = Transport {
                base_latency_ns: rng.f64_range(100.0, 5_000.0),
                sw_overhead_ns: rng.f64_range(0.0, 10_000.0),
                bw: rng.f64_range(10.0, 900.0),
                bw_efficiency: rng.f64_range(0.3, 1.0),
            };
            let n = 2 + rng.below(127) as usize;
            let bytes = rng.f64_range(1e3, 1e9);
            (t, n, bytes)
        },
        |&(t, n, bytes)| {
            let m = CollectiveModel::flat(t);
            let ar = m.all_reduce(n, bytes, Algorithm::Ring);
            let ar2 = m.all_reduce(n, 2.0 * bytes, Algorithm::Ring);
            if ar2 <= ar {
                return Err("not monotone in bytes".into());
            }
            let ident = m.reduce_scatter(n, bytes) + m.all_gather(n, bytes);
            if (ident - ar).abs() / ar > 1e-9 {
                return Err(format!("rs+ag {ident} != ring ar {ar}"));
            }
            if ar < t.message_ns(bytes / n as f64) {
                return Err("all-reduce cheaper than one chunk p2p".into());
            }
            Ok(())
        },
    );
}

/// Link latency model: monotone in size, positive, and effective
/// bandwidth bounded by raw for every link kind and any size.
#[test]
fn prop_link_model_bounds() {
    let kinds = [
        LinkKind::NvLink5,
        LinkKind::UaLink,
        LinkKind::CxlCoherent,
        LinkKind::CxlCapacity,
        LinkKind::PcieGen5,
        LinkKind::InfiniBandNdr,
    ];
    forall_res(
        Config { cases: 200, seed: 0x11AB },
        |rng: &mut Rng| (kinds[rng.below(6) as usize], rng.f64_range(1.0, 1e8)),
        |&(kind, bytes)| {
            let p = kind.params();
            let l = p.message_latency_ns(bytes);
            let l2 = p.message_latency_ns(bytes * 2.0);
            if !(l > 0.0 && l2 >= l) {
                return Err(format!("{kind:?}: latency not monotone at {bytes}"));
            }
            let eff = p.effective_bw(bytes);
            if !(eff > 0.0 && eff <= p.raw_bw) {
                return Err(format!("{kind:?}: effective bw {eff} out of bounds"));
            }
            // implied throughput converges to effective bw for big messages
            let big = 1e9;
            let implied = big / p.message_latency_ns(big);
            if implied > p.raw_bw {
                return Err(format!("{kind:?}: implied bw {implied} beats raw"));
            }
            Ok(())
        },
    );
}

/// Fabric on random ScalePool systems: triangle-ish inequality at the
/// level the model promises (direct path never slower than 3x a relay
/// through any intermediate accelerator, for equal-size messages).
#[test]
fn prop_no_absurd_detours() {
    use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
    use scalepool::fabric::TopologyKind;
    forall_res(
        Config { cases: 20, seed: 0x7070 },
        |rng: &mut Rng| (2 + rng.below(4) as usize, 2 + rng.below(6) as usize, rng.f64_range(64.0, 1e6)),
        |&(racks, per, bytes)| {
            let sys = ScalePoolBuilder::new()
                .racks((0..racks).map(|i| {
                    Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), per).unwrap()
                }))
                .config(SystemConfig {
                    inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
                    mem_nodes: 2,
                    ..Default::default()
                })
                .build();
            let a = sys.racks[0].acc_ids[0];
            let b = sys.racks[racks - 1].acc_ids[per - 1];
            let mid = sys.racks[racks / 2].acc_ids[0];
            let direct = sys.fabric.latency_ns(a, b, bytes).unwrap();
            let relay = sys.fabric.latency_ns(a, mid, bytes).unwrap()
                + sys.fabric.latency_ns(mid, b, bytes).unwrap();
            if direct > 3.0 * relay.max(1.0) {
                return Err(format!("direct {direct} vs relay {relay}"));
            }
            Ok(())
        },
    );
}

/// Tiering byte conservation: after ANY sequence of alloc / touch /
/// free / demote / promotion-scan ops, the sum of each pool's `used`
/// equals the live objects mapped to it (checked per step by the
/// engine's cross-pool invariant, which covers both tiers).
#[test]
fn prop_tiering_byte_conservation() {
    forall_res(
        Config { cases: 80, seed: 0x7143 },
        |rng: &mut Rng| {
            let t1_regions = 1 + rng.below(4) as usize;
            let t1_cap = rng.f64_range(50.0, 400.0);
            let t2_cap = rng.f64_range(500.0, 5_000.0);
            let ops: Vec<(u8, f64)> = (0..120)
                .map(|_| (rng.below(5) as u8, rng.f64_range(1.0, 120.0)))
                .collect();
            (t1_regions, t1_cap, t2_cap, ops)
        },
        |(t1_regions, t1_cap, t2_cap, ops)| {
            let mut t1 = MemoryPool::new();
            for i in 0..*t1_regions {
                t1.add_region(i, Tier::Tier1Local, *t1_cap);
            }
            let mut t2 = MemoryPool::new();
            t2.add_region(100, Tier::Tier2Pool, *t2_cap);
            let mut e = TieringEngine::new(t1, t2, TieringPolicy { t1_high_watermark: 0.85, promote_heat: 3 });
            e.record_migrations(true);
            let mut live: Vec<u64> = Vec::new();
            for &(op, bytes) in ops {
                match op {
                    0 | 1 => {
                        if let Ok(id) = e.alloc(bytes) {
                            live.push(id);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let id = live.remove(0);
                            e.free(id).map_err(|er| er.to_string())?;
                        }
                    }
                    3 => {
                        if let Some(&id) = live.last() {
                            for _ in 0..4 {
                                e.touch(id);
                            }
                            e.promote_ready(2);
                        }
                    }
                    _ => {
                        e.demote_coldest();
                    }
                }
                e.check_invariants()?;
            }
            // every logged migration's bytes match a live or once-live
            // object (sanity on the record stream)
            for m in e.take_migrations() {
                if m.bytes <= 0.0 {
                    return Err(format!("migration of {} bytes", m.bytes));
                }
            }
            Ok(())
        },
    );
}

/// Routed-mode directory: the emitted message multiset always matches
/// the count breakdown, endpoints never degenerate, and the
/// owner-XOR-sharers invariant (strengthened: no empty entries) holds
/// under arbitrary interleavings.
#[test]
fn prop_directory_routed_consistent() {
    forall_res(
        Config { cases: 60, seed: 0xC0DE },
        |rng: &mut Rng| {
            let agents = 2 + rng.below(7) as usize;
            let ops: Vec<(usize, u64, u8)> = (0..250)
                .map(|_| (rng.below(agents as u64) as usize, rng.below(24), rng.below(3) as u8))
                .collect();
            (agents, ops)
        },
        |(agents, ops)| {
            let mut d = Directory::new(*agents);
            let mut out: Vec<ProtocolMsg> = Vec::new();
            for &(a, block, op) in ops {
                out.clear();
                let m = match op {
                    0 => d.read_routed(a, block, &mut out),
                    1 => d.write_routed(a, block, &mut out),
                    _ => d.evict_routed(a, block, &mut out),
                };
                let count = |k: MsgKind| out.iter().filter(|x| x.kind == k).count() as u32;
                if count(MsgKind::DirReq) != m.dir_req
                    || count(MsgKind::Intervention) != m.interventions
                    || count(MsgKind::Data) != m.data
                    || count(MsgKind::Ack) != m.acks
                {
                    return Err(format!("routed messages disagree with counts: {m:?} vs {out:?}"));
                }
                for msg in &out {
                    if msg.src == msg.dst {
                        return Err(format!("degenerate message {msg:?}"));
                    }
                }
                d.check_invariants()?;
            }
            Ok(())
        },
    );
}

/// Streamed-vs-batch equivalence: the same transaction set, run as one
/// pre-sorted batch or split across several streamed sources, produces
/// the identical report (completions, latency stats, makespan).
#[test]
fn prop_streamed_matches_batch() {
    forall_res(
        Config { cases: 40, seed: 0x57E4 },
        |rng: &mut Rng| {
            let n = 4 + rng.below(12) as usize;
            let txs = 50 + rng.below(400) as usize;
            let sources = 2 + rng.below(4) as usize;
            let bytes = rng.f64_range(64.0, 65_536.0);
            (n, txs, sources, bytes, rng.below(1 << 30))
        },
        |&(n, txs, sources, bytes, seed)| {
            let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
            let accs = t.nodes_of(NodeKind::Accelerator);
            let f = Fabric::new(t);
            let mut rng = Rng::new(seed);
            let mut at = 0.0;
            let all: Vec<Transaction> = (0..txs)
                .map(|_| {
                    at += rng.exp(1.0 / 30.0);
                    let s = rng.below(n as u64) as usize;
                    let mut d = rng.below(n as u64) as usize;
                    if d == s {
                        d = (d + 1) % n;
                    }
                    Transaction { src: accs[s], dst: accs[d], at, bytes, device_ns: 80.0 }
                })
                .collect();

            let mut sim_batch = MemSim::new(&f);
            let batch = sim_batch.run(all.clone());

            // round-robin split: each sub-stream stays time-sorted
            let mut parts: Vec<Vec<Transaction>> = vec![Vec::new(); sources];
            for (i, tx) in all.into_iter().enumerate() {
                parts[i % sources].push(tx);
            }
            let mut srcs: Vec<BatchSource> =
                parts.into_iter().map(|p| BatchSource::new(p, TrafficClass::Generic)).collect();
            let mut refs: Vec<&mut dyn TrafficSource> =
                srcs.iter_mut().map(|s| s as &mut dyn TrafficSource).collect();
            let mut sim_stream = MemSim::new(&f);
            let streamed = sim_stream.run_streamed(&mut refs);

            if batch.completed != streamed.total.completed {
                return Err(format!(
                    "completed {} vs {}",
                    batch.completed, streamed.total.completed
                ));
            }
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1.0);
            if !close(batch.makespan_ns, streamed.total.makespan_ns) {
                return Err(format!(
                    "makespan {} vs {}",
                    batch.makespan_ns, streamed.total.makespan_ns
                ));
            }
            if !close(batch.latency.mean(), streamed.total.latency.mean())
                || !close(batch.latency.max(), streamed.total.latency.max())
                || !close(batch.latency.min(), streamed.total.latency.min())
            {
                return Err("latency stats diverged".into());
            }
            Ok(())
        },
    );
}

/// Calendar-queue scheduler parity: on randomized schedule/after/next
/// interleavings — including same-timestamp bursts, sub-granularity
/// spacing and far-future overflow — the calendar [`Engine`] must
/// dispatch byte-identically (times, payloads, FIFO `seq` tie-breaks,
/// clock, counts) to the retained binary-heap oracle
/// (`sim::engine::reference::HeapEngine`), mirroring the PR-1
/// `SerialRouter` pattern.
#[test]
fn calendar_queue_matches_heap_reference() {
    use scalepool::sim::engine::reference::HeapEngine;
    use scalepool::sim::{Engine, EventKind};
    forall_res(
        Config { cases: 48, seed: 0xCA7E },
        |rng: &mut Rng| {
            let n = 200 + rng.below(1200) as usize;
            let ops: Vec<(u8, u64)> = (0..n).map(|_| (rng.below(10) as u8, rng.below(1 << 20))).collect();
            let granularity = [1e-3, 0.1, 1.0, 50.0][rng.below(4) as usize];
            (ops, granularity)
        },
        |(ops, granularity)| {
            let mut cal = Engine::with_granularity(*granularity);
            let mut heap = HeapEngine::new();
            let mut tag = 0u64;
            for &(op, v) in ops {
                if op < 6 {
                    // engines advance in lockstep, so both nows agree
                    let base = cal.now();
                    let at = match op {
                        0 | 1 => base, // same-timestamp burst
                        2 => base + (v % 97) as f64 * 0.25, // near
                        3 => base + (v % 10_000) as f64, // mid-range
                        4 => base + 1e9 + v as f64, // far-future overflow
                        _ => base + v as f64 * 1e-4, // sub-granularity spacing
                    };
                    cal.schedule(at, EventKind::Custom { tag });
                    heap.schedule(at, EventKind::Custom { tag });
                    tag += 1;
                } else {
                    if cal.peek_time() != heap.peek_time() {
                        return Err(format!("peek diverged: {:?} vs {:?}", cal.peek_time(), heap.peek_time()));
                    }
                    let (a, b) = (cal.next(), heap.next());
                    if a != b {
                        return Err(format!("dispatch diverged: {a:?} vs {b:?}"));
                    }
                }
                if cal.pending() != heap.pending() {
                    return Err(format!("pending diverged: {} vs {}", cal.pending(), heap.pending()));
                }
            }
            loop {
                let (a, b) = (cal.next(), heap.next());
                if a != b {
                    return Err(format!("drain diverged: {a:?} vs {b:?}"));
                }
                if a.is_none() {
                    break;
                }
            }
            if cal.dispatched() != heap.dispatched() || cal.now() != heap.now() {
                return Err("dispatch count / clock diverged".into());
            }
            Ok(())
        },
    );
}

/// A batch workload instrumented to remember every per-transaction
/// completion time — the probe for the shard-vs-serial equivalence test.
struct RecordingSource {
    txs: std::collections::VecDeque<Transaction>,
    next_token: u64,
    completions: Vec<(u64, f64)>,
}

impl RecordingSource {
    fn new(txs: Vec<Transaction>) -> RecordingSource {
        RecordingSource { txs: txs.into(), next_token: 0, completions: Vec::new() }
    }
}

impl TrafficSource for RecordingSource {
    fn class(&self) -> scalepool::sim::TrafficClass {
        TrafficClass::Generic
    }
    fn pull(&mut self, _now: f64) -> scalepool::sim::Pull {
        match self.txs.pop_front() {
            Some(tx) => {
                let token = self.next_token;
                self.next_token += 1;
                scalepool::sim::Pull::Tx(scalepool::sim::SourcedTx::new(tx, token))
            }
            None => scalepool::sim::Pull::Done,
        }
    }
    fn on_complete(&mut self, token: u64, now: f64) {
        self.completions.push((token, now));
    }
    fn open_loop(&self) -> bool {
        true
    }
}

/// Shard-vs-serial equivalence: on randomized Clos and torus fabrics with
/// randomized open-loop workloads, the sharded conservative backend must
/// reproduce the serial streamed backend exactly — per-class completed
/// counts, byte totals, the sorted per-transaction latency multiset, and
/// the makespan — swept over the rail-selector policies it supports:
/// the original single-path run, then a 4-rail multipath table under
/// Deterministic and HashSpray (the coordinator-side rail resolution
/// must hash identically to the serial loop's injection-time one).
#[test]
fn prop_sharded_matches_serial() {
    forall_res(
        Config { cases: 22, seed: 0x5AD3 },
        |rng: &mut Rng| {
            let (t, eps) = if rng.below(2) == 0 {
                // Clos with endpoints per leaf
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(6) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 2 + rng.below(4) as usize;
                let mut eps = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            } else {
                // torus with endpoints on alternating switches
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    if i % 2 == 0 {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                        t.connect(n, s, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            };
            let ntx = 100 + rng.below(400) as usize;
            let shards = 2 + rng.below(3) as usize;
            (t, eps, ntx, shards, rng.below(1 << 30))
        },
        |(t, eps, ntx, shards, seed)| {
            if eps.len() < 2 {
                return Ok(());
            }
            let mut f = Fabric::new(t.clone());
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    // strictly increasing issue times: cross-shard event
                    // ordering is only defined up to exact-time ties
                    at += rng.exp(1.0 / 30.0) + 1e-6;
                    let s = rng.below(eps.len() as u64) as usize;
                    let mut d = rng.below(eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction {
                        src: eps[s],
                        dst: eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 8192.0,
                        device_ns: rng.f64() * 200.0,
                    }
                })
                .collect();

            let issue_of = |token: u64| txs[token as usize].at;

            // policy sweep: single-path deterministic (the original pin),
            // then the 4-rail table under Deterministic and HashSpray
            for (multipath, selector) in [
                (false, RailSelector::Deterministic),
                (true, RailSelector::Deterministic),
                (true, RailSelector::HashSpray),
            ] {
                if multipath && f.max_rails() == 1 {
                    f.enable_multipath(4);
                }
                let policy = RoutingPolicy::uniform(selector);
                let ctx = format!(
                    "[{} {}]",
                    if multipath { "multipath" } else { "single-path" },
                    selector.name()
                );

                let mut serial_src = RecordingSource::new(txs.clone());
                let mut serial_sim = MemSim::with_routing(&f, policy);
                let serial = {
                    let mut sources: [&mut dyn TrafficSource; 1] = [&mut serial_src];
                    serial_sim.run_streamed(&mut sources)
                };

                let mut sharded_src = RecordingSource::new(txs.clone());
                let mut sharded_sim = MemSim::with_routing(&f, policy);
                let sharded = {
                    let mut sources: [&mut dyn TrafficSource; 1] = [&mut sharded_src];
                    sharded_sim.run_streamed_sharded_with(&mut sources, *shards)
                };

                if serial.total.completed != sharded.total.completed
                    || serial.total.completed != *ntx as u64
                {
                    return Err(format!(
                        "{ctx} completed {} vs {}",
                        serial.total.completed, sharded.total.completed
                    ));
                }
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
                for c in scalepool::sim::TrafficClass::ALL {
                    let (a, b) = (serial.class(c), sharded.class(c));
                    if a.completed != b.completed || !close(a.bytes, b.bytes) {
                        return Err(format!("{ctx} class {} diverged", c.name()));
                    }
                }
                if !close(serial.total.makespan_ns, sharded.total.makespan_ns) {
                    return Err(format!(
                        "{ctx} makespan {} vs {}",
                        serial.total.makespan_ns, sharded.total.makespan_ns
                    ));
                }
                if serial.total.events != sharded.total.events {
                    return Err(format!(
                        "{ctx} event counts {} vs {}",
                        serial.total.events, sharded.total.events
                    ));
                }
                // sorted per-transaction latency multisets must match
                let lat = |recs: &[(u64, f64)]| -> Vec<f64> {
                    let mut v: Vec<f64> =
                        recs.iter().map(|&(tok, now)| now - issue_of(tok)).collect();
                    v.sort_by(|a, b| a.total_cmp(b));
                    v
                };
                let (ls, lp) = (lat(&serial_src.completions), lat(&sharded_src.completions));
                if ls.len() != lp.len() {
                    return Err(format!("{ctx} latency multiset sizes differ"));
                }
                for (i, (a, b)) in ls.iter().zip(&lp).enumerate() {
                    if !close(*a, *b) {
                        return Err(format!("{ctx} latency multiset diverged at {i}: {a} vs {b}"));
                    }
                }
                if !close(serial.total.latency.mean(), sharded.total.latency.mean())
                    || !close(serial.total.latency.max(), sharded.total.latency.max())
                {
                    return Err(format!("{ctx} aggregate latency stats diverged"));
                }
            }
            Ok(())
        },
    );
}

/// Reactive-source sharded-vs-serial equivalence (ISSUE 7): randomized
/// mixes of *closed-loop* reactive sources — per-group coherence sharing
/// domains and per-group collective rings, optionally alongside an
/// open-loop background stream — must produce the identical report on
/// the sharded backend, which pins each reactive source to the shard
/// owning its declared footprint. On Clos shapes (group footprints land
/// in disjoint shards) the run must actually shard; on torus shapes the
/// planner may legitimately fall back to serial, and parity must hold
/// either way. Compared against the serial oracle: per-class completed
/// counts and bytes, event counts, makespan, aggregate latency moments,
/// each source's own domain-latency accumulator, and the full per-link
/// QoS telemetry.
#[test]
fn prop_reactive_sharded_matches_serial() {
    forall_res(
        Config { cases: 18, seed: 0x5AD7 },
        |rng: &mut Rng| {
            // (topology, per-group endpoint sets, is_clos)
            let (t, groups, clos) = if rng.below(2) == 0 {
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(5) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 3 + rng.below(3) as usize;
                let mut groups = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    let mut eps = Vec::new();
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                    groups.push(eps);
                }
                (t, groups, true)
            } else {
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                    t.connect(n, s, LinkKind::CxlCoherent);
                    eps.push(n);
                }
                let groups: Vec<Vec<usize>> =
                    eps.chunks(3).filter(|c| c.len() >= 3).map(|c| c.to_vec()).collect();
                (t, groups, false)
            };
            let coh_ops = 40 + rng.below(120);
            let col_bytes = 4096.0 + rng.f64() * 65_536.0;
            let with_bg = rng.below(2) == 1;
            let bg_txs = 60 + rng.below(200) as usize;
            let shards = 2 + rng.below(3) as usize;
            (t, groups, clos, coh_ops, col_bytes, with_bg, bg_txs, shards, rng.below(1 << 30))
        },
        |(t, groups, clos, coh_ops, col_bytes, with_bg, bg_txs, shards, seed)| {
            if groups.len() < 2 {
                return Ok(());
            }
            let f = Fabric::new(t.clone());
            let all_eps: Vec<usize> = groups.iter().flatten().copied().collect();
            // one coherence sharing domain + one collective ring per
            // group: the first endpoint is the home node, the rest the
            // caching agents; the ring spans the whole group
            let make_reactive = || -> (Vec<CoherenceTraffic>, Vec<EventDrivenCollective>) {
                let coh = groups
                    .iter()
                    .enumerate()
                    .map(|(g, eps)| {
                        let ccfg = CoherenceConfig {
                            ops: *coh_ops,
                            mean_interarrival_ns: 40.0,
                            window: eps.len().max(4),
                            ..Default::default()
                        };
                        CoherenceTraffic::new(
                            eps[1..].to_vec(),
                            vec![eps[0]],
                            ccfg,
                            seed.wrapping_add(g as u64 * 7919),
                        )
                    })
                    .collect();
                let col = groups
                    .iter()
                    .map(|eps| EventDrivenCollective::ring(eps.clone(), *col_bytes, 1))
                    .collect();
                (coh, col)
            };
            let make_bg = || -> Option<BatchSource> {
                if !*with_bg {
                    return None;
                }
                let mut rng = Rng::new(seed.wrapping_mul(31).wrapping_add(7));
                let mut at = 0.0;
                let txs: Vec<Transaction> = (0..*bg_txs)
                    .map(|_| {
                        at += rng.exp(1.0 / 60.0) + 1e-6;
                        let s = rng.below(all_eps.len() as u64) as usize;
                        let mut d = rng.below(all_eps.len() as u64) as usize;
                        if d == s {
                            d = (d + 1) % all_eps.len();
                        }
                        Transaction {
                            src: all_eps[s],
                            dst: all_eps[d],
                            at,
                            bytes: 64.0 + rng.f64() * 4096.0,
                            device_ns: rng.f64() * 120.0,
                        }
                    })
                    .collect();
                Some(BatchSource::new(txs, TrafficClass::Generic))
            };
            let run = |sharded: bool| {
                let (mut coh, mut col) = make_reactive();
                let mut bg = make_bg();
                let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
                for c in &mut coh {
                    sources.push(c);
                }
                for c in &mut col {
                    sources.push(c);
                }
                if let Some(b) = &mut bg {
                    sources.push(b);
                }
                let mut sim = MemSim::new(&f);
                let rep = if sharded {
                    sim.run_streamed_sharded_with(&mut sources, *shards)
                } else {
                    sim.run_streamed(&mut sources)
                };
                let coh_lat: Vec<(u64, f64)> =
                    coh.iter().map(|c| (c.op_latency().count(), c.op_latency().mean())).collect();
                let col_lat: Vec<(u64, f64)> = col
                    .iter()
                    .map(|c| (c.repeat_latency().count(), c.repeat_latency().mean()))
                    .collect();
                (rep, coh_lat, col_lat)
            };

            let (serial, ser_coh, ser_col) = run(false);
            let (sharded, shr_coh, shr_col) = run(true);

            if *clos && !sharded.mode.is_sharded() {
                return Err(format!(
                    "disjoint per-leaf footprints on Clos must shard, got {:?}",
                    sharded.mode
                ));
            }
            if serial.mode != ShardMode::Serial {
                return Err("serial run reported a non-serial mode".into());
            }
            if serial.total.completed == 0 {
                return Err("workload moved nothing".into());
            }
            if serial.total.completed != sharded.total.completed {
                return Err(format!(
                    "completed {} vs {}",
                    serial.total.completed, sharded.total.completed
                ));
            }
            if serial.total.events != sharded.total.events {
                return Err(format!(
                    "event counts {} vs {}",
                    serial.total.events, sharded.total.events
                ));
            }
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
            if !close(serial.total.makespan_ns, sharded.total.makespan_ns) {
                return Err(format!(
                    "makespan {} vs {}",
                    serial.total.makespan_ns, sharded.total.makespan_ns
                ));
            }
            for c in TrafficClass::ALL {
                let (a, b) = (serial.class(c), sharded.class(c));
                if a.completed != b.completed || !close(a.bytes, b.bytes) {
                    return Err(format!("class {} diverged", c.name()));
                }
                if !close(a.latency.mean(), b.latency.mean())
                    || !close(a.latency.max(), b.latency.max())
                {
                    return Err(format!("class {} latency stats diverged", c.name()));
                }
            }
            // each reactive source's own domain-latency accumulator: the
            // pinned worker must deliver the same completions at the same
            // times as the serial pump
            for (i, (a, b)) in ser_coh.iter().zip(&shr_coh).enumerate() {
                if a.0 != b.0 || (a.0 > 0 && !close(a.1, b.1)) {
                    return Err(format!("coherence domain {i} op latency diverged: {a:?} vs {b:?}"));
                }
            }
            for (i, (a, b)) in ser_col.iter().zip(&shr_col).enumerate() {
                if a.0 != b.0 || (a.0 > 0 && !close(a.1, b.1)) {
                    return Err(format!("ring {i} repeat latency diverged: {a:?} vs {b:?}"));
                }
            }
            // per-link per-class QoS telemetry, field-wise
            if serial.qos.len() != sharded.qos.len() {
                return Err(format!(
                    "qos telemetry sizes {} vs {}",
                    serial.qos.len(),
                    sharded.qos.len()
                ));
            }
            for (a, b) in serial.qos.iter().zip(&sharded.qos) {
                if a.link != b.link
                    || a.dir != b.dir
                    || a.class != b.class
                    || a.served != b.served
                    || !close(a.bytes, b.bytes)
                    || !close(a.busy_ns, b.busy_ns)
                    || !close(a.queue_delay_ns, b.queue_delay_ns)
                {
                    return Err(format!(
                        "qos telemetry diverged on link {} dir {} class {}",
                        a.link,
                        a.dir,
                        a.class.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Optimistic sharded-vs-serial equivalence (ISSUE 8): a reactive
/// collective ring whose footprint covers *every* endpoint — the shape
/// that used to force the serial fallback — now runs on the coordinator
/// under checkpoint/rollback, alongside per-group coherence domains and
/// an open-loop background stream. The serial streamed loop is the
/// byte-exact oracle: per-class completed counts and bytes, event
/// counts, makespan, aggregate latency moments, the background stream's
/// sorted per-transaction latency multiset, the ring's own domain
/// accumulator, and the full per-link [`StreamReport::qos`] telemetry
/// must all match. On Clos shapes the run must actually shard and
/// report the spanning source as optimistic; on torus shapes the
/// planner may fall back, and parity must hold either way.
#[test]
fn prop_optimistic_matches_serial() {
    forall_res(
        Config { cases: 14, seed: 0x0B71 },
        |rng: &mut Rng| {
            let (t, groups, clos) = if rng.below(2) == 0 {
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(5) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 3 + rng.below(3) as usize;
                let mut groups = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    let mut eps = Vec::new();
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                    groups.push(eps);
                }
                (t, groups, true)
            } else {
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                    t.connect(n, s, LinkKind::CxlCoherent);
                    eps.push(n);
                }
                let groups: Vec<Vec<usize>> =
                    eps.chunks(3).filter(|c| c.len() >= 3).map(|c| c.to_vec()).collect();
                (t, groups, false)
            };
            let coh_ops = 30 + rng.below(90);
            let col_bytes = 4096.0 + rng.f64() * 32_768.0;
            let bg_txs = 60 + rng.below(160) as usize;
            let shards = 2 + rng.below(3) as usize;
            (t, groups, clos, coh_ops, col_bytes, bg_txs, shards, rng.below(1 << 30))
        },
        |(t, groups, clos, coh_ops, col_bytes, bg_txs, shards, seed)| {
            if groups.len() < 2 {
                return Ok(());
            }
            let f = Fabric::new(t.clone());
            let all_eps: Vec<usize> = groups.iter().flatten().copied().collect();
            let mut rng = Rng::new(seed.wrapping_mul(31).wrapping_add(7));
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*bg_txs)
                .map(|_| {
                    at += rng.exp(1.0 / 60.0) + 1e-6;
                    let s = rng.below(all_eps.len() as u64) as usize;
                    let mut d = rng.below(all_eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % all_eps.len();
                    }
                    Transaction {
                        src: all_eps[s],
                        dst: all_eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 4096.0,
                        device_ns: rng.f64() * 120.0,
                    }
                })
                .collect();
            let issue_of = |token: u64| txs[token as usize].at;

            let run = |sharded: bool| {
                let mut coh: Vec<CoherenceTraffic> = groups
                    .iter()
                    .enumerate()
                    .map(|(g, eps)| {
                        let ccfg = CoherenceConfig {
                            ops: *coh_ops,
                            mean_interarrival_ns: 40.0,
                            window: eps.len().max(4),
                            ..Default::default()
                        };
                        CoherenceTraffic::new(
                            eps[1..].to_vec(),
                            vec![eps[0]],
                            ccfg,
                            seed.wrapping_add(g as u64 * 7919),
                        )
                    })
                    .collect();
                // the spanning source: one ring over every endpoint in
                // the fabric, two back-to-back repeats
                let mut ring = EventDrivenCollective::ring(all_eps.clone(), *col_bytes, 2);
                let mut bg = RecordingSource::new(txs.clone());
                let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
                for c in &mut coh {
                    sources.push(c);
                }
                sources.push(&mut ring);
                sources.push(&mut bg);
                let mut sim = MemSim::new(&f);
                let rep = if sharded {
                    sim.run_streamed_sharded_with(&mut sources, *shards)
                } else {
                    sim.run_streamed(&mut sources)
                };
                let ring_lat = (ring.repeat_latency().count(), ring.repeat_latency().mean());
                (rep, bg.completions, ring_lat)
            };

            let (serial, ser_bg, ser_ring) = run(false);
            let (sharded, shr_bg, shr_ring) = run(true);

            if serial.mode != ShardMode::Serial {
                return Err("serial run reported a non-serial mode".into());
            }
            if *clos {
                if !sharded.mode.is_sharded() {
                    return Err(format!(
                        "spanning ring on Clos must shard optimistically, got {:?}",
                        sharded.mode
                    ));
                }
                if sharded.optimistic_sources != 1 {
                    return Err(format!(
                        "expected 1 optimistic source, got {}",
                        sharded.optimistic_sources
                    ));
                }
                if sharded.checkpoints == 0 || sharded.epochs == 0 {
                    return Err(format!(
                        "spanning ring never gated a window (epochs {}, checkpoints {})",
                        sharded.epochs, sharded.checkpoints
                    ));
                }
            }
            if serial.total.completed == 0 {
                return Err("workload moved nothing".into());
            }
            if serial.total.completed != sharded.total.completed {
                return Err(format!(
                    "completed {} vs {}",
                    serial.total.completed, sharded.total.completed
                ));
            }
            if serial.total.events != sharded.total.events {
                return Err(format!(
                    "event counts {} vs {}",
                    serial.total.events, sharded.total.events
                ));
            }
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
            if !close(serial.total.makespan_ns, sharded.total.makespan_ns) {
                return Err(format!(
                    "makespan {} vs {}",
                    serial.total.makespan_ns, sharded.total.makespan_ns
                ));
            }
            for c in TrafficClass::ALL {
                let (a, b) = (serial.class(c), sharded.class(c));
                if a.completed != b.completed || !close(a.bytes, b.bytes) {
                    return Err(format!("class {} diverged", c.name()));
                }
                if !close(a.latency.mean(), b.latency.mean())
                    || !close(a.latency.max(), b.latency.max())
                {
                    return Err(format!("class {} latency stats diverged", c.name()));
                }
            }
            // the spanning ring's own domain accumulator: the optimistic
            // replay must deliver every completion at the serial instant
            if ser_ring.0 != shr_ring.0 || (ser_ring.0 > 0 && !close(ser_ring.1, shr_ring.1)) {
                return Err(format!(
                    "ring repeat latency diverged: {ser_ring:?} vs {shr_ring:?}"
                ));
            }
            // background stream's sorted per-transaction latency multiset
            let lat = |recs: &[(u64, f64)]| -> Vec<f64> {
                let mut v: Vec<f64> = recs.iter().map(|&(tok, now)| now - issue_of(tok)).collect();
                v.sort_by(|a, b| a.total_cmp(b));
                v
            };
            let (ls, lp) = (lat(&ser_bg), lat(&shr_bg));
            if ls.len() != lp.len() {
                return Err("latency multiset sizes differ".into());
            }
            for (i, (a, b)) in ls.iter().zip(&lp).enumerate() {
                if !close(*a, *b) {
                    return Err(format!("latency multiset diverged at {i}: {a} vs {b}"));
                }
            }
            // per-link per-class QoS telemetry, field-wise
            if serial.qos.len() != sharded.qos.len() {
                return Err(format!(
                    "qos telemetry sizes {} vs {}",
                    serial.qos.len(),
                    sharded.qos.len()
                ));
            }
            for (a, b) in serial.qos.iter().zip(&sharded.qos) {
                if a.link != b.link
                    || a.dir != b.dir
                    || a.class != b.class
                    || a.served != b.served
                    || !close(a.bytes, b.bytes)
                    || !close(a.busy_ns, b.busy_ns)
                    || !close(a.queue_delay_ns, b.queue_delay_ns)
                {
                    return Err(format!(
                        "qos telemetry diverged on link {} dir {} class {}",
                        a.link,
                        a.dir,
                        a.class.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Checkpoint/restore roundtrip (ISSUE 8): the primitives the optimistic
/// sharded backend rolls back — the calendar [`Engine`] via
/// [`EngineSnapshot`] and the [`ClassedServer`] link state via `Clone` —
/// must restore byte-identically mid-run. An engine drained partway
/// through a randomized event stream (sized off random Clos/torus
/// shapes), snapshotted, drained to the end, restored and drained again
/// must reproduce the identical tail, clock and dispatch count; a server
/// cloned mid-sequence and driven with the identical remaining
/// admissions must end bit-equal to the never-snapshotted original,
/// under all three arbitration policies.
#[test]
fn prop_checkpoint_restore_roundtrip() {
    use scalepool::sim::{ClassedServer, Engine, EngineSnapshot, EventKind};
    forall_res(
        Config { cases: 30, seed: 0xC4E7 },
        |rng: &mut Rng| {
            let t = if rng.below(2) == 0 {
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(5) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                for (i, &l) in leaves.iter().enumerate() {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                    t.connect(n, l, LinkKind::CxlCoherent);
                }
                t
            } else {
                Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                )
                .0
            };
            let n = 40 + rng.below(160) as usize;
            let cut = rng.below(n as u64) as usize;
            let arb = rng.below(3);
            (t, n, cut, arb, rng.below(1 << 30))
        },
        |(t, n, cut, arb, seed)| {
            let mut rng = Rng::new(*seed);
            let links = t.links.len().max(1);

            // --- engine: drain `cut`, snapshot, finish, restore, finish
            let mut eng = Engine::with_granularity(1.0);
            for i in 0..*n {
                let at = rng.f64() * 10_000.0;
                let kind = match rng.below(4) {
                    0 => EventKind::Arrive { id: i, hop: rng.below(6) as usize },
                    1 => EventKind::Complete { id: i },
                    2 => EventKind::Depart {
                        link: rng.below(links as u64) as u32,
                        dir: (i & 1) as u8,
                    },
                    _ => EventKind::Custom { tag: i as u64 },
                };
                eng.schedule(at, kind);
            }
            for _ in 0..*cut {
                eng.next();
            }
            let snap: EngineSnapshot = eng.snapshot();
            let drain = |e: &mut Engine| {
                let mut out = Vec::new();
                while let Some((t2, k)) = e.next() {
                    out.push((t2.to_bits(), k));
                }
                (out, e.now().to_bits(), e.dispatched())
            };
            let never = drain(&mut eng);
            eng.restore(&snap);
            let restored = drain(&mut eng);
            if never != restored {
                return Err(format!(
                    "engine restore diverged after cut {} of {} (tails {} vs {} events)",
                    cut,
                    n,
                    never.0.len(),
                    restored.0.len()
                ));
            }

            // --- server: clone mid-sequence, drive both with the same
            // remaining admissions/departs, compare final state bitwise
            let mut srv = match arb {
                0 => ClassedServer::fcfs(),
                1 => ClassedServer::new(scalepool::sim::ArbPolicy::strict_default()),
                _ => ClassedServer::new(scalepool::sim::ArbPolicy::weighted_default()),
            };
            let evs: Vec<(f64, f64, f64, TrafficClass, bool)> = {
                let mut at = 0.0;
                (0..*n)
                    .map(|_| {
                        at += rng.exp(1.0 / 20.0) + 1e-6;
                        let class = TrafficClass::ALL[rng.below(4) as usize];
                        (at, 1.0 + rng.f64() * 50.0, 64.0 + rng.f64() * 4096.0, class, rng.below(3) == 0)
                    })
                    .collect()
            };
            let drive = |s: &mut ClassedServer, evs: &[(f64, f64, f64, TrafficClass, bool)],
                         log: &mut Vec<u64>| {
                for (i, &(at, service, bytes, class, depart)) in evs.iter().enumerate() {
                    s.admit(at, service, bytes, class, i as u32, 0);
                    if depart {
                        if let Some((id, hop, done)) = s.depart(at + service) {
                            log.push(u64::from(id));
                            log.push(u64::from(hop));
                            log.push(done.to_bits());
                        }
                    }
                }
            };
            let mut pre_log = Vec::new();
            drive(&mut srv, &evs[..*cut], &mut pre_log);
            let mut cloned = srv.clone();
            let (mut log_a, mut log_b) = (Vec::new(), Vec::new());
            drive(&mut srv, &evs[*cut..], &mut log_a);
            drive(&mut cloned, &evs[*cut..], &mut log_b);
            if log_a != log_b {
                return Err("server depart sequences diverged after clone".into());
            }
            let horizon = evs.last().map(|e| e.0 + e.1).unwrap_or(1.0);
            let fingerprint = |s: &ClassedServer| {
                let mut v = vec![
                    s.served(),
                    s.busy_ns().to_bits(),
                    s.pending_ns(horizon).to_bits(),
                    s.backlog() as u64,
                ];
                for c in TrafficClass::ALL {
                    let st = s.class_stats(c);
                    v.push(st.served);
                    v.push(st.bytes.to_bits());
                    v.push(st.busy_ns.to_bits());
                    v.push(st.queued_ns.to_bits());
                }
                v
            };
            if fingerprint(&srv) != fingerprint(&cloned) {
                return Err(format!(
                    "server state diverged after clone at cut {cut} (policy {arb})"
                ));
            }
            Ok(())
        },
    );
}

/// Adaptive rail steering on the sharded backend (ISSUE 8): runs steered
/// by the barrier-piggybacked backlog digests are bit-reproducible
/// across identical invocations and work-conserving against the serial
/// backend — same completed count and per-class bytes, even though the
/// one-barrier-stale digest may pick different rails than the serial
/// live-state scoring (the documented semantic difference; byte parity
/// is pinned for Deterministic/HashSpray by `prop_sharded_matches_serial`).
#[test]
fn prop_sharded_adaptive_deterministic_and_conserving() {
    forall_res(
        Config { cases: 12, seed: 0xADA7 },
        |rng: &mut Rng| {
            let (mut t, leaves) = Topology::clos(
                2 + rng.below(5) as usize,
                2 + rng.below(3) as usize,
                LinkKind::CxlCoherent,
                "c",
            );
            let per = 2 + rng.below(4) as usize;
            let mut eps = Vec::new();
            for (i, &l) in leaves.iter().enumerate() {
                for e in 0..per {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                    t.connect(n, l, LinkKind::CxlCoherent);
                    eps.push(n);
                }
            }
            let ntx = 100 + rng.below(300) as usize;
            let shards = 2 + rng.below(3) as usize;
            (t, eps, ntx, shards, rng.below(1 << 30))
        },
        |(t, eps, ntx, shards, seed)| {
            if eps.len() < 2 {
                return Ok(());
            }
            let mut f = Fabric::new(t.clone());
            f.enable_multipath(4);
            let policy = RoutingPolicy::uniform(RailSelector::Adaptive);
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    at += rng.exp(1.0 / 30.0) + 1e-6;
                    let s = rng.below(eps.len() as u64) as usize;
                    let mut d = rng.below(eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction {
                        src: eps[s],
                        dst: eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 8192.0,
                        device_ns: rng.f64() * 200.0,
                    }
                })
                .collect();
            let run_sharded = || {
                let mut src = BatchSource::new(txs.clone(), TrafficClass::Generic);
                let mut sim = MemSim::with_routing(&f, policy);
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                sim.run_streamed_sharded_with(&mut sources, *shards)
            };
            let a = run_sharded();
            let b = run_sharded();
            if !a.mode.is_sharded() {
                return Err(format!("adaptive clos run must shard, got {:?}", a.mode));
            }
            if a.total.completed != b.total.completed
                || a.total.events != b.total.events
                || a.total.makespan_ns.to_bits() != b.total.makespan_ns.to_bits()
                || a.total.latency.mean().to_bits() != b.total.latency.mean().to_bits()
            {
                return Err("adaptive sharded run is not bit-reproducible".into());
            }
            // work conservation vs the serial adaptive backend
            let mut src = BatchSource::new(txs.clone(), TrafficClass::Generic);
            let mut sim = MemSim::with_routing(&f, policy);
            let serial = {
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                sim.run_streamed(&mut sources)
            };
            if serial.total.completed != a.total.completed
                || serial.total.completed != *ntx as u64
            {
                return Err(format!(
                    "adaptive work not conserved: serial {} vs sharded {} of {}",
                    serial.total.completed, a.total.completed, ntx
                ));
            }
            let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0);
            for c in TrafficClass::ALL {
                if serial.class(c).completed != a.class(c).completed
                    || !close(serial.class(c).bytes, a.class(c).bytes)
                {
                    return Err(format!("class {} byte conservation violated", c.name()));
                }
            }
            Ok(())
        },
    );
}

/// Copy-on-write fork parity (ISSUE 6): a [`MemSim::fork`] of a master
/// that was warmed on the workload and path-frozen must reproduce a
/// freshly built simulator byte-for-byte — per-class completed counts
/// and bytes, the sorted per-transaction latency multiset, event count,
/// makespan, and the full [`StreamReport::qos`] telemetry — on
/// randomized Clos and torus fabrics swept over single-/multi-path
/// tables, all three rail selectors, and all three arbitration
/// policies. This is the invariant that lets the sweep experiments
/// build one system per configuration family and fork per point.
#[test]
fn prop_forked_sim_matches_fresh_build() {
    forall_res(
        Config { cases: 18, seed: 0xF02C },
        |rng: &mut Rng| {
            let (t, eps) = if rng.below(2) == 0 {
                // Clos with endpoints per leaf
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(6) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 2 + rng.below(4) as usize;
                let mut eps = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            } else {
                // torus with endpoints on alternating switches
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    if i % 2 == 0 {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                        t.connect(n, s, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            };
            let ntx = 80 + rng.below(300) as usize;
            (t, eps, ntx, rng.below(2) == 1, rng.below(3), rng.below(3), rng.below(1 << 30))
        },
        |(t, eps, ntx, multipath, sel, arb, seed)| {
            if eps.len() < 2 {
                return Ok(());
            }
            let mut f = Fabric::new(t.clone());
            if *multipath {
                f.enable_multipath(4);
            }
            let selector = match *sel {
                0 => RailSelector::Deterministic,
                1 => RailSelector::HashSpray,
                _ => RailSelector::Adaptive,
            };
            let routing = RoutingPolicy::uniform(selector);
            let qos = match *arb {
                0 => QosPolicy::fcfs(),
                1 => QosPolicy::uniform(ArbPolicy::strict_default()),
                _ => QosPolicy::uniform(ArbPolicy::weighted_default()),
            };
            let ctx = format!(
                "[{} {} {}]",
                if *multipath { "multipath" } else { "single-path" },
                selector.name(),
                qos.tier(scalepool::sim::LinkTier::CxlSpine).name(),
            );
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    at += rng.exp(1.0 / 30.0) + 1e-6;
                    let s = rng.below(eps.len() as u64) as usize;
                    let mut d = rng.below(eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction {
                        src: eps[s],
                        dst: eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 8192.0,
                        device_ns: rng.f64() * 200.0,
                    }
                })
                .collect();
            let issue_of = |token: u64| txs[token as usize].at;

            // A: fresh build, configured, run once — the reference
            let mut fresh_src = RecordingSource::new(txs.clone());
            let mut fresh_sim = MemSim::with_routing(&f, routing);
            fresh_sim.set_qos(qos);
            let fresh = {
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut fresh_src];
                fresh_sim.run_streamed(&mut sources)
            };

            // B: master warmed on the same workload (fills the path
            // arena), frozen, then forked — the sweep-loop shape
            let mut master = MemSim::with_routing(&f, routing);
            master.set_qos(qos);
            {
                let mut warm_src = RecordingSource::new(txs.clone());
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut warm_src];
                let _ = master.run_streamed(&mut sources);
            }
            master.freeze_paths();
            let mut forked_sim = master.fork();
            let mut forked_src = RecordingSource::new(txs.clone());
            let forked = {
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut forked_src];
                forked_sim.run_streamed(&mut sources)
            };

            if fresh.total.completed != forked.total.completed
                || fresh.total.completed != *ntx as u64
            {
                return Err(format!(
                    "{ctx} completed {} vs {}",
                    fresh.total.completed, forked.total.completed
                ));
            }
            if fresh.total.events != forked.total.events {
                return Err(format!(
                    "{ctx} event counts {} vs {}",
                    fresh.total.events, forked.total.events
                ));
            }
            // the fork replays the identical event sequence over the
            // identical interned paths: bit-exact, no tolerance
            if fresh.total.makespan_ns != forked.total.makespan_ns {
                return Err(format!(
                    "{ctx} makespan {} vs {}",
                    fresh.total.makespan_ns, forked.total.makespan_ns
                ));
            }
            for c in TrafficClass::ALL {
                let (a, b) = (fresh.class(c), forked.class(c));
                if a.completed != b.completed || a.bytes != b.bytes {
                    return Err(format!("{ctx} class {} diverged", c.name()));
                }
            }
            let lat = |recs: &[(u64, f64)]| -> Vec<f64> {
                let mut v: Vec<f64> = recs.iter().map(|&(tok, now)| now - issue_of(tok)).collect();
                v.sort_by(|a, b| a.total_cmp(b));
                v
            };
            let (la, lb) = (lat(&fresh_src.completions), lat(&forked_src.completions));
            if la != lb {
                return Err(format!("{ctx} latency multisets diverged"));
            }
            // per-link per-class telemetry, field-wise (no PartialEq on
            // LinkClassStats): collect_qos_stats emits in link order, so
            // the two runs must agree element by element
            if fresh.qos.len() != forked.qos.len() {
                return Err(format!(
                    "{ctx} qos telemetry sizes {} vs {}",
                    fresh.qos.len(),
                    forked.qos.len()
                ));
            }
            for (a, b) in fresh.qos.iter().zip(&forked.qos) {
                if a.link != b.link
                    || a.dir != b.dir
                    || a.tier != b.tier
                    || a.class != b.class
                    || a.served != b.served
                    || a.bytes != b.bytes
                    || a.busy_ns != b.busy_ns
                    || a.queue_delay_ns != b.queue_delay_ns
                {
                    return Err(format!(
                        "{ctx} qos telemetry diverged on link {} dir {} class {}",
                        a.link,
                        a.dir,
                        a.class.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Flight-recorder inertness (ISSUE 9): arming the trace sink must not
/// perturb the simulation in any observable way. On randomized Clos and
/// torus fabrics, a traced run's [`StreamReport`] — totals, per-class
/// stats, QoS telemetry, backend mode and protocol counters — and the
/// source's per-transaction completion instants must be bit-identical
/// to the untraced run's, on BOTH the serial and the sharded backend
/// (`dropped_spans`/`trace_overhead_ns` are the recorder's own fields
/// and are excluded by construction).
#[test]
fn prop_tracing_is_inert() {
    use scalepool::sim::{StreamReport, TraceConfig};
    let fingerprint = |r: &StreamReport| -> Vec<u64> {
        let mut v = vec![
            r.total.completed,
            r.total.events,
            r.total.makespan_ns.to_bits(),
            r.total.latency.mean().to_bits(),
            r.total.latency.min().to_bits(),
            r.total.latency.max().to_bits(),
            r.peak_inflight as u64,
            r.epochs,
            r.barriers,
            r.optimistic_sources as u64,
            r.checkpoints,
            r.rollbacks,
        ];
        for c in TrafficClass::ALL {
            let cr = r.class(c);
            v.push(cr.completed);
            v.push(cr.bytes.to_bits());
            v.push(cr.latency.mean().to_bits());
            v.push(cr.latency.max().to_bits());
            v.push(cr.hist.p50().to_bits());
            v.push(cr.hist.p99().to_bits());
        }
        for q in &r.qos {
            v.push(q.link as u64);
            v.push(q.dir as u64);
            v.push(q.tier.index() as u64);
            v.push(q.class.index() as u64);
            v.push(q.served);
            v.push(q.bytes.to_bits());
            v.push(q.busy_ns.to_bits());
            v.push(q.queue_delay_ns.to_bits());
        }
        v
    };
    forall_res(
        Config { cases: 16, seed: 0x71ACE },
        |rng: &mut Rng| {
            let (t, eps) = if rng.below(2) == 0 {
                // Clos with endpoints per leaf
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(6) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 2 + rng.below(4) as usize;
                let mut eps = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            } else {
                // torus with endpoints on alternating switches
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    if i % 2 == 0 {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                        t.connect(n, s, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                }
                (t, eps)
            };
            let ntx = 80 + rng.below(300) as usize;
            let shards = 2 + rng.below(3) as usize;
            (t, eps, ntx, shards, rng.below(1 << 30))
        },
        |(t, eps, ntx, shards, seed)| {
            if eps.len() < 2 {
                return Ok(());
            }
            let f = Fabric::new(t.clone());
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    at += rng.exp(1.0 / 30.0) + 1e-6;
                    let s = rng.below(eps.len() as u64) as usize;
                    let mut d = rng.below(eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction {
                        src: eps[s],
                        dst: eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 8192.0,
                        device_ns: rng.f64() * 200.0,
                    }
                })
                .collect();

            for sharded in [false, true] {
                let ctx = if sharded { "[sharded]" } else { "[serial]" };
                let run = |traced: bool| {
                    let mut src = RecordingSource::new(txs.clone());
                    let mut sim = MemSim::new(&f);
                    if traced {
                        sim.set_trace(TraceConfig::default());
                    }
                    let rep = {
                        let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                        if sharded {
                            sim.run_streamed_sharded_with(&mut sources, *shards)
                        } else {
                            sim.run_streamed(&mut sources)
                        }
                    };
                    (rep, src.completions, sim.take_trace())
                };
                let (plain, plain_done, no_data) = run(false);
                let (traced, traced_done, data) = run(true);
                if no_data.is_some() {
                    return Err(format!("{ctx} untraced run produced a recording"));
                }
                let data = data.ok_or(format!("{ctx} traced run produced no recording"))?;
                if traced.total.completed > 0 && data.spans.is_empty() {
                    return Err(format!("{ctx} armed recorder captured no spans"));
                }
                if plain.dropped_spans != 0 || plain.trace_overhead_ns != 0.0 {
                    return Err(format!("{ctx} untraced report carries recorder fields"));
                }
                if plain.mode != traced.mode {
                    return Err(format!(
                        "{ctx} backend mode changed under tracing: {:?} vs {:?}",
                        plain.mode, traced.mode
                    ));
                }
                if fingerprint(&plain) != fingerprint(&traced) {
                    return Err(format!("{ctx} traced report diverged from untraced"));
                }
                if plain_done.len() != traced_done.len() {
                    return Err(format!("{ctx} completion counts diverged"));
                }
                for (a, b) in plain_done.iter().zip(&traced_done) {
                    if a.0 != b.0 || a.1.to_bits() != b.1.to_bits() {
                        return Err(format!(
                            "{ctx} completion instants diverged: {a:?} vs {b:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Trace conservation (ISSUE 9): with the ring sized above the workload,
/// a traced serial run records a well-formed span chain for every
/// transaction — exactly one inject and one complete per token, every
/// hop ordered `arrive <= start <= done` with the next hop arriving no
/// earlier than the previous finished, the complete's latency equal to
/// `complete.at - inject.at` — and the per-class completed counts and
/// byte totals rebuilt from the complete spans match the report. A
/// sharded rerun of the same workload must additionally carry epoch
/// instants from the coordinator protocol.
#[test]
fn trace_conserves_transactions() {
    use scalepool::sim::{SpanRecord, TraceConfig};
    use std::collections::BTreeMap;

    let (mut t, leaves) = Topology::clos(4, 2, LinkKind::CxlCoherent, "c");
    let mut eps = Vec::new();
    for (i, &l) in leaves.iter().enumerate() {
        for e in 0..3 {
            let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
            t.connect(n, l, LinkKind::CxlCoherent);
            eps.push(n);
        }
    }
    let f = Fabric::new(t);
    let mut rng = Rng::new(0x7C09E);
    let mut at = 0.0;
    let ntx = 400usize;
    let txs: Vec<Transaction> = (0..ntx)
        .map(|_| {
            at += rng.exp(1.0 / 25.0) + 1e-6;
            let s = rng.below(eps.len() as u64) as usize;
            let mut d = rng.below(eps.len() as u64) as usize;
            if d == s {
                d = (d + 1) % eps.len();
            }
            Transaction {
                src: eps[s],
                dst: eps[d],
                at,
                bytes: 64.0 + rng.f64() * 4096.0,
                device_ns: rng.f64() * 150.0,
            }
        })
        .collect();

    let mut src = RecordingSource::new(txs.clone());
    let mut sim = MemSim::new(&f);
    sim.set_trace(TraceConfig::default());
    let rep = {
        let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
        sim.run_streamed(&mut sources)
    };
    let data = sim.take_trace().expect("traced run must yield a recording");
    assert_eq!(rep.total.completed, ntx as u64);
    assert_eq!(rep.dropped_spans, 0, "ring sized above the workload must not drop");
    assert_eq!(data.dropped_spans, 0);
    assert!(data.instants.is_empty(), "serial runs have no backend protocol instants");
    assert!(rep.trace_overhead_ns > 0.0, "recording must report its own cost");

    // group spans per token; single source, so tokens are unique
    #[derive(Default)]
    struct Chain {
        inject: Option<(f64, f64)>,          // at, bytes
        hops: Vec<(f64, f64, f64)>,          // arrive, start, done
        complete: Option<(f64, f64, f64)>,   // at, latency_ns, bytes
    }
    let mut chains: BTreeMap<u64, Chain> = BTreeMap::new();
    let mut class_bytes = 0.0f64;
    let mut class_completed = 0u64;
    for s in &data.spans {
        match *s {
            SpanRecord::Inject { at, bytes, token, shard, .. } => {
                assert_eq!(shard, 0, "serial spans are shard 0");
                let c = chains.entry(token).or_default();
                assert!(c.inject.is_none(), "token {token} injected twice");
                c.inject = Some((at, bytes));
            }
            SpanRecord::Hop { arrive, start, done, token, .. } => {
                chains.entry(token).or_default().hops.push((arrive, start, done));
            }
            SpanRecord::Complete { at, latency_ns, bytes, class, token, .. } => {
                let c = chains.entry(token).or_default();
                assert!(c.complete.is_none(), "token {token} completed twice");
                c.complete = Some((at, latency_ns, bytes));
                if class == TrafficClass::Generic {
                    class_bytes += bytes;
                    class_completed += 1;
                }
            }
        }
    }
    assert_eq!(chains.len(), ntx, "every transaction must leave a span chain");
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
    for (token, c) in &chains {
        let (inj_at, inj_bytes) = c.inject.unwrap_or_else(|| panic!("token {token} has no inject"));
        assert_eq!(inj_at.to_bits(), txs[*token as usize].at.to_bits());
        assert_eq!(inj_bytes.to_bits(), txs[*token as usize].bytes.to_bits());
        assert!(!c.hops.is_empty(), "token {token}: distinct endpoints need >= 1 hop");
        let mut hops = c.hops.clone();
        hops.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.2.total_cmp(&b.2)));
        let mut prev_done = inj_at;
        for &(arrive, start, done) in &hops {
            assert!(arrive <= start && start <= done, "token {token}: hop out of order");
            assert!(
                arrive >= prev_done - 1e-9,
                "token {token}: hop arrives before the previous one finished"
            );
            prev_done = done;
        }
        let (done_at, latency, done_bytes) =
            c.complete.unwrap_or_else(|| panic!("token {token} never completed"));
        assert!(done_at >= prev_done - 1e-9, "token {token}: completed mid-flight");
        assert!(close(latency, done_at - inj_at), "token {token}: latency mismatch");
        assert_eq!(done_bytes.to_bits(), inj_bytes.to_bits());
    }
    let generic = rep.class(TrafficClass::Generic);
    assert_eq!(class_completed, generic.completed);
    assert!(close(class_bytes, generic.bytes), "byte totals diverged from the report");

    // the sharded backend must additionally stamp coordinator protocol
    // instants into the merged recording
    let mut src = RecordingSource::new(txs);
    let mut sim = MemSim::new(&f);
    sim.set_trace(TraceConfig::default());
    let shr = {
        let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
        sim.run_streamed_sharded_with(&mut sources, 4)
    };
    let sdata = sim.take_trace().expect("sharded traced run must yield a recording");
    if shr.mode.is_sharded() {
        assert!(
            sdata
                .instants
                .iter()
                .any(|i| i.kind == scalepool::sim::InstantKind::Epoch),
            "sharded recording carries no epoch instants"
        );
        assert!(
            sdata.spans.iter().any(|s| match *s {
                SpanRecord::Hop { shard, .. } => shard > 0,
                _ => false,
            }),
            "no span was stamped by a non-zero shard"
        );
    }
}

/// The fig7 model: for ANY fabric-derived parameter set with sane
/// ordering, the three-config ordering holds in region 3.
#[test]
fn prop_fig7_ordering_robust() {
    use scalepool::experiments::fig7;
    forall_res(
        Config { cases: 100, seed: 0xF16 },
        |rng: &mut Rng| fig7::Fig7Params {
            intra_rack_rt: rng.f64_range(300.0, 1_200.0),
            inter_cluster_rt: rng.f64_range(1_500.0, 6_000.0),
            tier2_rt: rng.f64_range(400.0, 1_400.0),
            coherence_ns: rng.f64_range(20.0, 200.0),
        },
        |p| {
            if p.tier2_rt >= p.inter_cluster_rt {
                return Ok(()); // precondition of the design: tier-2 is nearer
            }
            // second design precondition: coherent CXL remote access beats
            // the RDMA software path (otherwise acc-clusters ≥ baseline is
            // expected and fine)
            let rdma = scalepool::coherence::SoftwareCopyModel::rdma_inter_cluster()
                .per_access_ns()
                + 90.0;
            if p.inter_cluster_rt + p.coherence_ns + 100.0 >= rdma {
                return Ok(());
            }
            let rows = fig7::run_fig7_with(p);
            for r in rows.iter().filter(|r| r.working_set > fig7::CLUSTER_HBM) {
                if !(r.tiered_ns <= r.acc_clusters_ns && r.acc_clusters_ns <= r.baseline_ns + 1e-9) {
                    return Err(format!(
                        "ordering violated at ws {:.2e}: {} / {} / {}",
                        r.working_set, r.baseline_ns, r.acc_clusters_ns, r.tiered_ns
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Express-dispatch inertness (ISSUE 10): peek-gated hop fusion is a
/// pure event-count optimization — it must not perturb any observable
/// result. On randomized Clos and torus fabrics carrying either a
/// sparse open-loop stream (the fusion-friendly regime) or a dense
/// reactive mix (coherence domains + collective rings + open-loop
/// background), a fused run's [`StreamReport`] — totals, per-class
/// stats, event counts, makespan bits, full QoS telemetry — the
/// source-observed completion instants, and the recorded span chain
/// must be bit-identical to the same run with fusion disabled
/// ([`MemSim::set_fusion`]), swept across arbitration policies (FCFS /
/// strict / weighted), rail selectors (single-path, multipath
/// Deterministic, multipath HashSpray), both backends, traced and
/// untraced. Sparse serial cases must additionally fuse at least one
/// hop (the optimization actually fires where it is supposed to).
#[test]
fn prop_fused_matches_unfused() {
    use scalepool::sim::{StreamReport, TraceConfig};
    let fingerprint = |r: &StreamReport| -> Vec<u64> {
        let mut v = vec![
            r.total.completed,
            r.total.events,
            r.total.makespan_ns.to_bits(),
            r.total.latency.mean().to_bits(),
            r.total.latency.min().to_bits(),
            r.total.latency.max().to_bits(),
            r.peak_inflight as u64,
            r.epochs,
            r.barriers,
            r.optimistic_sources as u64,
            r.checkpoints,
            r.rollbacks,
        ];
        for c in TrafficClass::ALL {
            let cr = r.class(c);
            v.push(cr.completed);
            v.push(cr.bytes.to_bits());
            v.push(cr.latency.mean().to_bits());
            v.push(cr.latency.max().to_bits());
            v.push(cr.hist.p50().to_bits());
            v.push(cr.hist.p99().to_bits());
        }
        for q in &r.qos {
            v.push(q.link as u64);
            v.push(q.dir as u64);
            v.push(q.tier.index() as u64);
            v.push(q.class.index() as u64);
            v.push(q.served);
            v.push(q.bytes.to_bits());
            v.push(q.busy_ns.to_bits());
            v.push(q.queue_delay_ns.to_bits());
        }
        v
    };
    forall_res(
        Config { cases: 10, seed: 0xF05ED },
        |rng: &mut Rng| {
            let (t, groups) = if rng.below(2) == 0 {
                let (mut t, leaves) = Topology::clos(
                    2 + rng.below(5) as usize,
                    1 + rng.below(3) as usize,
                    LinkKind::CxlCoherent,
                    "c",
                );
                let per = 3 + rng.below(3) as usize;
                let mut groups = Vec::new();
                for (i, &l) in leaves.iter().enumerate() {
                    let mut eps = Vec::new();
                    for e in 0..per {
                        let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
                        t.connect(n, l, LinkKind::CxlCoherent);
                        eps.push(n);
                    }
                    groups.push(eps);
                }
                (t, groups)
            } else {
                let (mut t, sw) = Topology::torus3d(
                    (2 + rng.below(3) as usize, 2 + rng.below(3) as usize, 1 + rng.below(2) as usize),
                    LinkKind::CxlCoherent,
                    "t",
                );
                let mut eps = Vec::new();
                for (i, &s) in sw.iter().enumerate() {
                    let n = t.add_node(NodeKind::Accelerator, format!("e{i}"));
                    t.connect(n, s, LinkKind::CxlCoherent);
                    eps.push(n);
                }
                let groups: Vec<Vec<usize>> =
                    eps.chunks(3).filter(|c| c.len() >= 3).map(|c| c.to_vec()).collect();
                (t, groups)
            };
            // sparse: a lone open-loop stream with interarrivals far above
            // the per-hop latency, so nearly every hop beats the peek gate.
            // dense: the reactive mix, where fusion fires opportunistically.
            let sparse = rng.below(2) == 1;
            let ntx = 80 + rng.below(200) as usize;
            let coh_ops = 30 + rng.below(60);
            let col_bytes = 4096.0 + rng.f64() * 32_768.0;
            let shards = 2 + rng.below(3) as usize;
            (t, groups, sparse, ntx, coh_ops, col_bytes, shards, rng.below(1 << 30))
        },
        |(t, groups, sparse, ntx, coh_ops, col_bytes, shards, seed)| {
            if groups.len() < 2 {
                return Ok(());
            }
            let mut f = Fabric::new(t.clone());
            let all_eps: Vec<usize> = groups.iter().flatten().copied().collect();
            let mut rng = Rng::new(*seed);
            let mut at = 0.0;
            let mean = if *sparse { 2_500.0 } else { 60.0 };
            let txs: Vec<Transaction> = (0..*ntx)
                .map(|_| {
                    at += rng.exp(1.0 / mean) + 1e-6;
                    let s = rng.below(all_eps.len() as u64) as usize;
                    let mut d = rng.below(all_eps.len() as u64) as usize;
                    if d == s {
                        d = (d + 1) % all_eps.len();
                    }
                    Transaction {
                        src: all_eps[s],
                        dst: all_eps[d],
                        at,
                        bytes: 64.0 + rng.f64() * 4096.0,
                        device_ns: rng.f64() * 120.0,
                    }
                })
                .collect();

            // policy sweep: FCFS single-path (also the traced combo), then
            // the queued-mode arbiters on a 4-rail multipath table
            for pi in 0..3usize {
                if pi > 0 && f.max_rails() == 1 {
                    f.enable_multipath(4);
                }
                let selector = match pi {
                    1 => RailSelector::HashSpray,
                    _ => RailSelector::Deterministic,
                };
                let ctx = format!(
                    "[{} pi={pi} {}]",
                    if *sparse { "sparse" } else { "dense" },
                    selector.name()
                );
                for sharded in [false, true] {
                    let traced_set: &[bool] = if pi == 0 { &[false, true] } else { &[false] };
                    for &traced in traced_set {
                        let run = |fuse: bool| {
                            let mut coh: Vec<CoherenceTraffic> = Vec::new();
                            let mut col: Vec<EventDrivenCollective> = Vec::new();
                            if !*sparse {
                                for (g, eps) in groups.iter().enumerate() {
                                    let ccfg = CoherenceConfig {
                                        ops: *coh_ops,
                                        mean_interarrival_ns: 40.0,
                                        window: eps.len().max(4),
                                        ..Default::default()
                                    };
                                    coh.push(CoherenceTraffic::new(
                                        eps[1..].to_vec(),
                                        vec![eps[0]],
                                        ccfg,
                                        seed.wrapping_add(g as u64 * 7919),
                                    ));
                                    col.push(EventDrivenCollective::ring(
                                        eps.clone(),
                                        *col_bytes,
                                        1,
                                    ));
                                }
                            }
                            let mut bg = RecordingSource::new(txs.clone());
                            let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
                            for c in &mut coh {
                                sources.push(c);
                            }
                            for c in &mut col {
                                sources.push(c);
                            }
                            sources.push(&mut bg);
                            let mut sim = MemSim::with_routing(
                                &f,
                                RoutingPolicy::uniform(selector),
                            );
                            sim.set_qos(match pi {
                                0 => QosPolicy::fcfs(),
                                1 => QosPolicy::uniform(ArbPolicy::strict_default()),
                                _ => QosPolicy::uniform(ArbPolicy::weighted_default()),
                            });
                            sim.set_fusion(fuse);
                            if traced {
                                sim.set_trace(TraceConfig::default());
                            }
                            let rep = if sharded {
                                sim.run_streamed_sharded_with(&mut sources, *shards)
                            } else {
                                sim.run_streamed(&mut sources)
                            };
                            let coh_lat: Vec<(u64, u64)> = coh
                                .iter()
                                .map(|c| {
                                    (c.op_latency().count(), c.op_latency().mean().to_bits())
                                })
                                .collect();
                            let col_lat: Vec<(u64, u64)> = col
                                .iter()
                                .map(|c| {
                                    (
                                        c.repeat_latency().count(),
                                        c.repeat_latency().mean().to_bits(),
                                    )
                                })
                                .collect();
                            (rep, bg.completions, coh_lat, col_lat, sim.take_trace())
                        };
                        let (fused, f_done, f_coh, f_col, f_tr) = run(true);
                        let (plain, p_done, p_coh, p_col, p_tr) = run(false);
                        if plain.fused_hops != 0 {
                            return Err(format!(
                                "{ctx} fusion disabled but {} hops fused",
                                plain.fused_hops
                            ));
                        }
                        if fused.mode != plain.mode {
                            return Err(format!(
                                "{ctx} fusion changed the backend mode: {:?} vs {:?}",
                                fused.mode, plain.mode
                            ));
                        }
                        if fingerprint(&fused) != fingerprint(&plain) {
                            return Err(format!(
                                "{ctx} sharded={sharded} traced={traced} fused report diverged \
                                 (events {} vs {}, makespan {} vs {})",
                                fused.total.events,
                                plain.total.events,
                                fused.total.makespan_ns,
                                plain.total.makespan_ns
                            ));
                        }
                        if fused.fused_hops > 0 && fused.fusion_rate() <= 0.0 {
                            return Err(format!("{ctx} fused_hops > 0 but fusion_rate is 0"));
                        }
                        if f_done.len() != p_done.len() {
                            return Err(format!("{ctx} completion counts diverged"));
                        }
                        for (a, b) in f_done.iter().zip(&p_done) {
                            if a.0 != b.0 || a.1.to_bits() != b.1.to_bits() {
                                return Err(format!(
                                    "{ctx} completion instants diverged: {a:?} vs {b:?}"
                                ));
                            }
                        }
                        if f_coh != p_coh || f_col != p_col {
                            return Err(format!("{ctx} reactive-source accumulators diverged"));
                        }
                        if traced {
                            let ft = f_tr.ok_or(format!("{ctx} fused traced run lost data"))?;
                            let pt = p_tr.ok_or(format!("{ctx} plain traced run lost data"))?;
                            // fused hops record their spans inline at the
                            // true hop times — the chain must be identical
                            if ft.spans != pt.spans {
                                return Err(format!(
                                    "{ctx} sharded={sharded} span chains diverged \
                                     ({} vs {} spans)",
                                    ft.spans.len(),
                                    pt.spans.len()
                                ));
                            }
                        }
                        if *sparse && !sharded && fused.fused_hops == 0 {
                            return Err(format!(
                                "{ctx} sparse serial run fused nothing \
                                 (events {}, completed {})",
                                fused.total.events, fused.total.completed
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
