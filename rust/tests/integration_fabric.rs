//! Integration: topologies + routing + latency model + event simulation
//! composed across modules, at sizes closer to the paper's deployment.

use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::fabric::{Fabric, LinkKind, NodeKind, Topology, TopologyKind};
use scalepool::sim::{MemSim, Transaction};
use scalepool::util::Rng;

/// A full NVL72 rack: 72 GPUs on one switch complex, paper's Table-1
/// latency class end to end.
#[test]
fn nvl72_rack_latency_class() {
    let t = Topology::single_hop(72, LinkKind::NvLink5, "nvl72");
    let accs = t.nodes_of(NodeKind::Accelerator);
    let f = Fabric::new(t);
    let lat = f.latency_ns(accs[0], accs[71], 256.0).unwrap();
    assert!(lat < 500.0, "NVL72 device-to-device 256 B: {lat} ns (paper: <500 ns)");
}

/// Full-size ScalePool: 8 NVL72 racks + tier-2 nodes over a CXL Clos.
/// (Direct per-accelerator CXL ports are disabled at this scale — a
/// 64-radix leaf cannot take 72 endpoints; the rack uplink model applies.)
#[test]
fn eight_rack_scalepool_is_sound() {
    let sys = ScalePoolBuilder::new()
        .racks((0..8).map(|i| Rack::nvl72(&format!("rack{i}"))))
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 16,
            fabric_width: 4,
            direct_cxl_ports: false,
            ..Default::default()
        })
        .build();
    assert_eq!(sys.accelerator_count(), 576);
    assert!(sys.fabric.topo.is_connected());
    sys.fabric.topo.validate_radix().unwrap();

    // latency hierarchy: intra-rack < inter-rack
    let intra = sys.acc_latency_ns((0, 0), (0, 71), 64.0);
    let inter = sys.acc_latency_ns((0, 0), (7, 71), 64.0);
    assert!(intra < inter);
    let t2 = sys.tier2_rt_ns(0).unwrap();
    assert!(t2 < 4.0 * inter, "tier-2 rt {t2} should not dwarf inter-rack {inter}");
}

/// The three CXL fabric shapes of Figure 4a all produce working systems
/// with bounded diameter.
#[test]
fn all_fabric_shapes_work() {
    for kind in [TopologyKind::MultiLevelClos, TopologyKind::Torus3d, TopologyKind::DragonFly] {
        let sys = ScalePoolBuilder::new()
            .racks((0..6).map(|i| {
                Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 8).unwrap()
            }))
            .config(SystemConfig { inter: InterCluster::Cxl(kind), mem_nodes: 6, ..Default::default() })
            .build();
        assert!(sys.fabric.topo.is_connected(), "{kind:?}");
        for i in 1..6 {
            let p = sys.fabric.path(sys.racks[0].acc_ids[0], sys.racks[i].acc_ids[0]).unwrap();
            assert!(p.hops() <= 10, "{kind:?}: {} hops to rack {i}", p.hops());
        }
    }
}

/// Event simulation agrees with the analytic model on an uncontended
/// path within the cut-through modeling band, and degrades under load.
#[test]
fn event_sim_vs_analytic_consistency() {
    let sys = ScalePoolBuilder::new()
        .racks((0..2).map(|i| Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 8).unwrap()))
        .config(SystemConfig::default())
        .build();
    let src = sys.racks[0].acc_ids[0];
    let dst = sys.racks[1].acc_ids[0];
    let analytic = sys.fabric.latency_ns(src, dst, 4096.0).unwrap();

    let mut sim = MemSim::new(&sys.fabric);
    let solo = sim
        .run(vec![Transaction { src, dst, at: 0.0, bytes: 4096.0, device_ns: 0.0 }])
        .latency
        .mean();
    let ratio = solo / analytic;
    assert!(
        (0.7..3.0).contains(&ratio),
        "solo sim {solo} vs analytic {analytic} (ratio {ratio})"
    );

    // heavy fan-in must queue well beyond the solo latency
    let mut rng = Rng::new(9);
    let mut at = 0.0;
    let all: Vec<_> = sys.racks.iter().flat_map(|r| r.acc_ids.iter().copied()).collect();
    let txs: Vec<Transaction> = (0..5_000)
        .map(|_| {
            at += rng.exp(1.0 / 2.0); // near-saturation arrivals
            Transaction { src: all[rng.below(16) as usize], dst, at, bytes: 4096.0, device_ns: 0.0 }
        })
        .filter(|t| t.src != t.dst)
        .collect();
    let mut sim2 = MemSim::new(&sys.fabric);
    let loaded = sim2.run(txs);
    assert!(loaded.latency.mean() > 1.5 * solo, "contention must show up");
}

/// PBR routing tables stay consistent with shortest paths on a big torus.
#[test]
fn pbr_consistency_on_torus() {
    let (t, ids) = Topology::torus3d((5, 5, 5), LinkKind::CxlCoherent, "torus");
    let f = Fabric::new(t);
    let r = f.router();
    let mut rng = Rng::new(17);
    for _ in 0..200 {
        let a = ids[rng.below(125) as usize];
        let b = ids[rng.below(125) as usize];
        let p = r.path(a, b).unwrap();
        // walk PBR ports and land at b in exactly p.hops() steps
        let mut cur = a;
        for &l in &p.links {
            assert_eq!(r.pbr_port(cur, b), Some(l));
            let link = f.topo.link(l);
            cur = if link.a == cur { link.b } else { link.a };
        }
        assert_eq!(cur, b);
    }
}

/// Degenerate systems: single rack (no inter-cluster), two-node fabric.
#[test]
fn degenerate_systems() {
    let sys = ScalePoolBuilder::new()
        .rack(Rack::homogeneous("solo", Accelerator::b200(), 2).unwrap())
        .config(SystemConfig { mem_nodes: 1, ..Default::default() })
        .build();
    assert!(sys.fabric.topo.is_connected());
    assert!(sys.inter_rack_rt_ns().is_none());
    assert!(sys.tier2_rt_ns(0).is_some());
    let l = sys.acc_latency_ns((0, 0), (0, 1), 64.0);
    assert!(l > 0.0 && l < 1_000.0);
}
