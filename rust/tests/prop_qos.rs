//! Property tests for the fabric QoS subsystem (`sim::qos`): FCFS parity
//! against the pre-QoS plain `Server` (the oracle pattern of
//! `SerialRouter` / `HeapEngine`), per-class byte conservation under
//! every arbitration policy, work conservation on a shared bottleneck,
//! strict-priority protection of the high class, and serial-vs-sharded
//! equivalence with class-aware arbitration enabled on both backends.

use scalepool::fabric::{Fabric, LinkKind, NodeKind, Topology};
use scalepool::sim::{
    ArbPolicy, BatchSource, Engine, EventKind, MemSim, Pull, QosPolicy, Server, SourcedTx,
    TrafficClass, TrafficSource, Transaction,
};
use scalepool::util::prop::{forall_res, Config};
use scalepool::util::Rng;

/// A batch source that remembers every per-transaction completion —
/// token = index into its transaction list.
struct RecordingSource {
    txs: std::collections::VecDeque<Transaction>,
    class: TrafficClass,
    next_token: u64,
    completions: Vec<(u64, f64)>,
}

impl RecordingSource {
    fn new(txs: Vec<Transaction>, class: TrafficClass) -> RecordingSource {
        RecordingSource { txs: txs.into(), class, next_token: 0, completions: Vec::new() }
    }
}

impl TrafficSource for RecordingSource {
    fn class(&self) -> TrafficClass {
        self.class
    }
    fn pull(&mut self, _now: f64) -> Pull {
        match self.txs.pop_front() {
            Some(tx) => {
                let token = self.next_token;
                self.next_token += 1;
                Pull::Tx(SourcedTx::new(tx, token))
            }
            None => Pull::Done,
        }
    }
    fn on_complete(&mut self, token: u64, now: f64) {
        self.completions.push((token, now));
    }
    fn open_loop(&self) -> bool {
        true
    }
}

/// The pre-QoS simulation semantics, reimplemented directly on the plain
/// FCFS [`Server`]: every transaction walks its routed path hop by hop,
/// `admit` time-releases each hop, the receiving node's switch traversal
/// and the link's fixed latency ride on top, and the destination pays
/// device time before completing. This is the parity oracle for
/// `ClassedServer` in FCFS mode — same arithmetic, same dispatch order,
/// so results must be byte-identical.
fn reference_pre_qos_run(f: &Fabric, txs: &[Transaction]) -> (f64, Vec<f64>) {
    struct C {
        inv_rate: f64,
        fixed: f64,
        sw: [f64; 2],
    }
    let topo = &f.topo;
    let consts: Vec<C> = topo
        .links
        .iter()
        .map(|l| {
            let p = &l.params;
            let sw = |n: usize| topo.node(n).switch.as_ref().map(|s| s.traversal_ns()).unwrap_or(0.0);
            C {
                inv_rate: 1.0 / (p.raw_bw * p.phy.efficiency()),
                fixed: p.prop_ns + p.phy.latency_ns() + p.flit_overhead_ns,
                sw: [sw(l.a), sw(l.b)],
            }
        })
        .collect();
    let mut servers: Vec<[Server; 2]> =
        (0..topo.links.len()).map(|_| [Server::new(), Server::new()]).collect();
    let router = f.router();
    let paths: Vec<Vec<(usize, usize)>> = txs
        .iter()
        .map(|tx| {
            let mut hops = Vec::new();
            let mut cur = tx.src;
            while cur != tx.dst {
                let (nxt, link) = router.next_hop(cur, tx.dst).expect("connected fabric");
                let dir = if topo.link(link).a == cur { 0 } else { 1 };
                hops.push((link, dir));
                cur = nxt;
            }
            hops
        })
        .collect();
    let mut engine = Engine::new();
    for (id, tx) in txs.iter().enumerate() {
        engine.schedule(tx.at, EventKind::Arrive { id, hop: 0 });
    }
    let mut latencies = vec![0.0f64; txs.len()];
    while let Some((now, ev)) = engine.next() {
        match ev {
            EventKind::Arrive { id, hop } => {
                let path = &paths[id];
                if hop >= path.len() {
                    engine.after(txs[id].device_ns, EventKind::Complete { id });
                    continue;
                }
                let (link, dir) = path[hop];
                let c = &consts[link];
                let service = topo.link(link).params.flit.wire_bytes(txs[id].bytes) * c.inv_rate;
                let done = servers[link][dir].admit(now, service);
                engine.schedule(done + c.fixed + c.sw[1 - dir], EventKind::Arrive { id, hop: hop + 1 });
            }
            EventKind::Complete { id } => latencies[id] = now - txs[id].at,
            other => unreachable!("unexpected event {other:?}"),
        }
    }
    (engine.now(), latencies)
}

/// Clos fabric with `per` endpoints per leaf.
fn clos_with_eps(leaves: usize, spines: usize, per: usize) -> (Fabric, Vec<usize>) {
    let (mut t, leaf_ids) = Topology::clos(leaves, spines, LinkKind::CxlCoherent, "c");
    let mut eps = Vec::new();
    for (i, &l) in leaf_ids.iter().enumerate() {
        for e in 0..per {
            let n = t.add_node(NodeKind::Accelerator, format!("e{i}-{e}"));
            t.connect(n, l, LinkKind::CxlCoherent);
            eps.push(n);
        }
    }
    (Fabric::new(t), eps)
}

/// Random workload over `eps` with strictly increasing issue times.
fn workload(eps: &[usize], n: usize, bytes: Option<f64>, rng: &mut Rng) -> Vec<Transaction> {
    let mut at = 0.0;
    (0..n)
        .map(|_| {
            at += rng.exp(1.0 / 30.0) + 1e-6;
            let s = rng.below(eps.len() as u64) as usize;
            let mut d = rng.below(eps.len() as u64) as usize;
            if d == s {
                d = (d + 1) % eps.len();
            }
            Transaction {
                src: eps[s],
                dst: eps[d],
                at,
                bytes: bytes.unwrap_or(64.0 + rng.f64() * 8192.0),
                device_ns: 50.0,
            }
        })
        .collect()
}

/// FCFS parity: the default `MemSim` (every link a `ClassedServer` in
/// `FcfsShared` mode) must reproduce the pre-QoS plain-`Server`
/// simulation byte-identically — makespan and the per-transaction
/// latency multiset, exact float equality.
#[test]
fn prop_fcfs_matches_pre_qos_server() {
    forall_res(
        Config { cases: 30, seed: 0xFC5 },
        |rng: &mut Rng| {
            let (f, eps) = if rng.below(2) == 0 {
                let t = Topology::single_hop(4 + rng.below(12) as usize, LinkKind::NvLink5, "r");
                let eps = t.nodes_of(NodeKind::Accelerator);
                (Fabric::new(t), eps)
            } else {
                clos_with_eps(
                    2 + rng.below(5) as usize,
                    1 + rng.below(3) as usize,
                    2 + rng.below(4) as usize,
                )
            };
            let txs = workload(&eps, 80 + rng.below(300) as usize, None, rng);
            (f, txs)
        },
        |(f, txs)| {
            let (ref_makespan, ref_lat) = reference_pre_qos_run(f, txs);

            let mut src = RecordingSource::new(txs.clone(), TrafficClass::Generic);
            let mut sim = MemSim::new(f);
            assert_eq!(sim.qos_policy(), QosPolicy::fcfs(), "default policy must be the parity baseline");
            let rep = {
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                sim.run_streamed(&mut sources)
            };

            if rep.total.completed as usize != txs.len() {
                return Err(format!("completed {} != {}", rep.total.completed, txs.len()));
            }
            if rep.total.makespan_ns != ref_makespan {
                return Err(format!(
                    "makespan {} != pre-QoS {ref_makespan} (must be byte-identical)",
                    rep.total.makespan_ns
                ));
            }
            for &(token, now) in &src.completions {
                let got = now - txs[token as usize].at;
                let want = ref_lat[token as usize];
                if got != want {
                    return Err(format!("tx {token}: latency {got} != pre-QoS {want}"));
                }
            }
            Ok(())
        },
    );
}

/// Byte conservation: under every policy, per-class completed counts and
/// byte totals equal exactly what the sources injected.
#[test]
fn prop_qos_byte_conservation_under_every_policy() {
    forall_res(
        Config { cases: 24, seed: 0xB17E },
        |rng: &mut Rng| {
            let (f, eps) = clos_with_eps(2 + rng.below(4) as usize, 1 + rng.below(2) as usize, 3);
            let classes = [TrafficClass::Coherence, TrafficClass::Collective, TrafficClass::Generic];
            let batches: Vec<(TrafficClass, Vec<Transaction>)> = classes
                .iter()
                .map(|&c| (c, workload(&eps, 40 + rng.below(150) as usize, None, rng)))
                .collect();
            (f, batches, rng.below(1 << 20))
        },
        |(f, batches, seed)| {
            let policies = [
                ArbPolicy::FcfsShared,
                ArbPolicy::strict_default(),
                ArbPolicy::WeightedFair([
                    1.0 + (*seed % 7) as f64,
                    1.0,
                    1.0 + (*seed % 3) as f64,
                    0.5,
                ]),
            ];
            for policy in policies {
                let mut srcs: Vec<BatchSource> = batches
                    .iter()
                    .map(|(c, txs)| BatchSource::new(txs.clone(), *c))
                    .collect();
                let mut refs: Vec<&mut dyn TrafficSource> =
                    srcs.iter_mut().map(|s| s as &mut dyn TrafficSource).collect();
                let mut sim = MemSim::with_qos(f, QosPolicy::uniform(policy));
                let rep = sim.run_streamed(&mut refs);
                for (c, txs) in batches {
                    let injected: f64 = txs.iter().map(|t| t.bytes).sum();
                    let cr = rep.class(*c);
                    if cr.completed as usize != txs.len() {
                        return Err(format!(
                            "{}/{}: completed {} != injected {}",
                            policy.name(),
                            c.name(),
                            cr.completed,
                            txs.len()
                        ));
                    }
                    if (cr.bytes - injected).abs() > 1e-6 * injected.max(1.0) {
                        return Err(format!(
                            "{}/{}: bytes {} != injected {injected}",
                            policy.name(),
                            c.name(),
                            cr.bytes
                        ));
                    }
                }
                // telemetry side: per-link served bytes of a class must sum
                // to >= the class's payload bytes (each tx crosses >= 1 link
                // unless src == dst, which workload() never emits)
                for (c, txs) in batches {
                    let injected: f64 = txs.iter().map(|t| t.bytes).sum();
                    let served: f64 =
                        rep.qos.iter().filter(|s| s.class == *c).map(|s| s.bytes).sum();
                    if served < injected - 1e-6 {
                        return Err(format!(
                            "{}/{}: telemetry served {served} < injected {injected}",
                            policy.name(),
                            c.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Work conservation: on a single shared bottleneck with equal-size
/// transactions, every policy finishes the same work in the same time —
/// the link never idles while any VC is backlogged, so reordering the
/// backlog cannot stretch the busy period (makespan is policy-invariant
/// up to float-summation order).
#[test]
fn prop_qos_work_conservation_on_shared_bottleneck() {
    forall_res(
        Config { cases: 30, seed: 0x30C0 },
        |rng: &mut Rng| {
            let n = 60 + rng.below(200) as usize;
            let bytes = 512.0 * (1 + rng.below(16)) as f64;
            (n, bytes, rng.below(1 << 30))
        },
        |&(n, bytes, seed)| {
            let t = Topology::single_hop(4, LinkKind::NvLink5, "r");
            let eps = t.nodes_of(NodeKind::Accelerator);
            let f = Fabric::new(t);
            let mut rng = Rng::new(seed);
            // everything acc0 -> acc1: one bottleneck link direction,
            // saturating arrivals (1 ns apart, service far larger)
            let mut at = 0.0;
            let mk = |at: f64| Transaction { src: eps[0], dst: eps[1], at, bytes, device_ns: 20.0 };
            let mut coh = Vec::new();
            let mut gen = Vec::new();
            for _ in 0..n {
                at += rng.f64() + 1e-3;
                if rng.below(2) == 0 {
                    coh.push(mk(at));
                } else {
                    gen.push(mk(at));
                }
            }
            let run = |policy: ArbPolicy| {
                let mut a = BatchSource::new(coh.clone(), TrafficClass::Coherence);
                let mut b = BatchSource::new(gen.clone(), TrafficClass::Generic);
                let mut sources: [&mut dyn TrafficSource; 2] = [&mut a, &mut b];
                let mut sim = MemSim::with_qos(&f, QosPolicy::uniform(policy));
                sim.run_streamed(&mut sources)
            };
            let fcfs = run(ArbPolicy::FcfsShared);
            for policy in [ArbPolicy::strict_default(), ArbPolicy::weighted_default()] {
                let rep = run(policy);
                if rep.total.completed != fcfs.total.completed {
                    return Err(format!("{}: completion count diverged", policy.name()));
                }
                let (a, b) = (rep.total.makespan_ns, fcfs.total.makespan_ns);
                if (a - b).abs() > 1e-6 * b.max(1.0) {
                    return Err(format!(
                        "{}: makespan {a} != fcfs {b} — a work-conserving policy \
                         cannot stretch a saturated bottleneck",
                        policy.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Strict priority on a contended link: the high class's mean latency
/// under interference must not exceed FCFS's, and within the strict run
/// the high class must beat the low class outright. Checked through the
/// per-class report and the per-link telemetry.
#[test]
fn prop_strict_priority_protects_the_high_class() {
    forall_res(
        Config { cases: 20, seed: 0x591C7 },
        |rng: &mut Rng| (80 + rng.below(200) as usize, rng.below(1 << 30)),
        |&(n, seed)| {
            let t = Topology::single_hop(4, LinkKind::NvLink5, "r");
            let eps = t.nodes_of(NodeKind::Accelerator);
            let f = Fabric::new(t);
            let mut rng = Rng::new(seed);
            // saturating interleaved burst on one link direction
            let mut at = 0.0;
            let mut coh = Vec::new();
            let mut gen = Vec::new();
            for i in 0..2 * n {
                at += rng.f64() * 2.0 + 1e-3;
                let tx = Transaction { src: eps[0], dst: eps[1], at, bytes: 4096.0, device_ns: 0.0 };
                if i % 2 == 0 {
                    coh.push(tx);
                } else {
                    gen.push(tx);
                }
            }
            let run = |policy: ArbPolicy| {
                let mut a = BatchSource::new(coh.clone(), TrafficClass::Coherence);
                let mut b = BatchSource::new(gen.clone(), TrafficClass::Generic);
                let mut sources: [&mut dyn TrafficSource; 2] = [&mut a, &mut b];
                let mut sim = MemSim::with_qos(&f, QosPolicy::uniform(policy));
                sim.run_streamed(&mut sources)
            };
            let fcfs = run(ArbPolicy::FcfsShared);
            let strict = run(ArbPolicy::strict_default());
            let coh_fcfs = fcfs.class(TrafficClass::Coherence).latency.mean();
            let coh_strict = strict.class(TrafficClass::Coherence).latency.mean();
            let gen_strict = strict.class(TrafficClass::Generic).latency.mean();
            if coh_strict > coh_fcfs * 1.001 + 1.0 {
                return Err(format!(
                    "strict coherence mean {coh_strict} worse than fcfs {coh_fcfs}"
                ));
            }
            if coh_strict >= gen_strict {
                return Err(format!(
                    "under strict priority coherence ({coh_strict}) must beat generic ({gen_strict})"
                ));
            }
            // telemetry: on the contended link, coherence queue delay must
            // be below generic queue delay in the strict run
            let delay = |rep: &scalepool::sim::StreamReport, class: TrafficClass| {
                let (mut q, mut s) = (0.0, 0u64);
                for e in rep.qos.iter().filter(|e| e.class == class) {
                    q += e.queue_delay_ns;
                    s += e.served;
                }
                if s == 0 {
                    0.0
                } else {
                    q / s as f64
                }
            };
            let (dc, dg) = (delay(&strict, TrafficClass::Coherence), delay(&strict, TrafficClass::Generic));
            if dc >= dg {
                return Err(format!("strict telemetry: coherence queue delay {dc} >= generic {dg}"));
            }
            Ok(())
        },
    );
}

/// Serial-vs-sharded equivalence with class-aware arbitration enabled on
/// both backends (strict priority and weighted-fair): per-class counts
/// and bytes, the makespan, and the sorted per-transaction latency
/// multiset must match.
#[test]
fn prop_sharded_matches_serial_under_qos_policies() {
    forall_res(
        Config { cases: 14, seed: 0x5A9D },
        |rng: &mut Rng| {
            let (f, eps) = clos_with_eps(
                3 + rng.below(4) as usize,
                1 + rng.below(3) as usize,
                2 + rng.below(4) as usize,
            );
            let coh = workload(&eps, 60 + rng.below(200) as usize, None, rng);
            let gen = workload(&eps, 60 + rng.below(200) as usize, None, rng);
            let shards = 2 + rng.below(3) as usize;
            let policy = if rng.below(2) == 0 {
                ArbPolicy::strict_default()
            } else {
                ArbPolicy::weighted_default()
            };
            (f, coh, gen, shards, policy)
        },
        |(f, coh, gen, shards, policy)| {
            let run = |sharded: bool| {
                let mut a = RecordingSource::new(coh.clone(), TrafficClass::Coherence);
                let mut b = RecordingSource::new(gen.clone(), TrafficClass::Generic);
                let mut sim = MemSim::with_qos(f, QosPolicy::uniform(*policy));
                let rep = {
                    let mut sources: [&mut dyn TrafficSource; 2] = [&mut a, &mut b];
                    if sharded {
                        sim.run_streamed_sharded_with(&mut sources, *shards)
                    } else {
                        sim.run_streamed(&mut sources)
                    }
                };
                let lat = |src: &RecordingSource, txs: &[Transaction]| -> Vec<f64> {
                    let mut v: Vec<f64> =
                        src.completions.iter().map(|&(tok, now)| now - txs[tok as usize].at).collect();
                    v.sort_by(|x, y| x.total_cmp(y));
                    v
                };
                (rep, lat(&a, coh), lat(&b, gen))
            };
            let (serial, s_coh, s_gen) = run(false);
            let (sharded, p_coh, p_gen) = run(true);

            if serial.total.completed != sharded.total.completed {
                return Err(format!(
                    "{}: completed {} vs {}",
                    policy.name(),
                    serial.total.completed,
                    sharded.total.completed
                ));
            }
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
            if !close(serial.total.makespan_ns, sharded.total.makespan_ns) {
                return Err(format!(
                    "{}: makespan {} vs {}",
                    policy.name(),
                    serial.total.makespan_ns,
                    sharded.total.makespan_ns
                ));
            }
            for c in [TrafficClass::Coherence, TrafficClass::Generic] {
                let (a, b) = (serial.class(c), sharded.class(c));
                if a.completed != b.completed || !close(a.bytes, b.bytes) {
                    return Err(format!("{}: class {} diverged", policy.name(), c.name()));
                }
            }
            for (name, s, p) in [("coherence", &s_coh, &p_coh), ("generic", &s_gen, &p_gen)] {
                if s.len() != p.len() {
                    return Err(format!("{name}: multiset sizes differ"));
                }
                for (i, (a, b)) in s.iter().zip(p.iter()).enumerate() {
                    if !close(*a, *b) {
                        return Err(format!(
                            "{} ({name}): latency multiset diverged at {i}: {a} vs {b}",
                            policy.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
