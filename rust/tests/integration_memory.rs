//! Integration: memory tiers + pools + coherence + workloads + the Fig 7
//! harness — the capacity/latency story of §5 end to end.

use scalepool::coherence::{Directory, SoftwareCopyModel};
use scalepool::experiments::fig7;
use scalepool::memory::pool::{MemoryPool, Placement};
use scalepool::memory::Tier;
use scalepool::util::units::GB;
use scalepool::workloads::{EmbeddingWorkload, KvCacheWorkload, RagWorkload, WorkingSetSweep};

/// Figure 7 crossovers land where the capacities say they must.
#[test]
fn fig7_crossovers_at_capacity_boundaries() {
    let rows = fig7::run_fig7();
    // below one accelerator: all three identical
    let below = rows.iter().filter(|r| r.working_set <= fig7::ACCEL_HBM).count();
    assert!(below >= 3);
    for r in rows.iter().take(below) {
        assert!((r.baseline_ns - r.tiered_ns).abs() < 1.0);
    }
    // between accelerator and cluster: tiered wins, the other two tie
    for r in rows.iter().filter(|r| {
        r.working_set > fig7::ACCEL_HBM && r.working_set <= fig7::CLUSTER_HBM
    }) {
        assert!(r.tiered_ns < r.baseline_ns);
        assert!((r.baseline_ns - r.acc_clusters_ns).abs() < 1.0);
    }
    // beyond the cluster: strict ordering tiered < acc-clusters < baseline
    for r in rows.iter().filter(|r| r.working_set > fig7::CLUSTER_HBM) {
        assert!(r.tiered_ns < r.acc_clusters_ns);
        assert!(r.acc_clusters_ns < r.baseline_ns);
    }
}

/// The three motivating workloads of §2 actually exceed the capacities
/// that make tier-2 worthwhile.
#[test]
fn motivating_workloads_exceed_hbm() {
    let kv = KvCacheWorkload { conversations: 2048, ..Default::default() }.trace();
    assert!(kv.working_set > fig7::ACCEL_HBM);

    let emb = EmbeddingWorkload::default();
    assert!(emb.table_bytes() > fig7::ACCEL_HBM);

    let rag = RagWorkload::default();
    assert!(rag.working_set() > fig7::ACCEL_HBM);
    // and each one's mean latency is better on the tiered config
    let p = fig7::Fig7Params::reference();
    let [base, _acc, tier] = fig7::configs(&p);
    for ws in [kv.working_set, emb.table_bytes(), rag.working_set()] {
        assert!(
            tier.mean_latency_ns(ws) <= base.mean_latency_ns(ws),
            "ws {ws:.2e}"
        );
    }
}

/// A pooled allocation spanning tiers keeps pool invariants through a
/// realistic allocate/access/free lifecycle driven by a workload trace.
#[test]
fn pool_lifecycle_with_trace() {
    let mut pool = MemoryPool::new();
    pool.add_region(0, Tier::Tier1Local, 192.0 * GB);
    pool.add_region(1, Tier::Tier2Pool, 4096.0 * GB);

    let sweep = WorkingSetSweep { accesses: 1000, ..Default::default() };
    let trace = sweep.trace(1000.0 * GB);
    // allocate the working set across the pool
    let a = pool.alloc(trace.working_set, Placement::FirstFit).unwrap();
    assert_eq!(a.extents.len(), 2, "must span both tiers");
    assert!((a.extents[0].1 - 192.0 * GB).abs() < 1.0);
    pool.check_invariants().unwrap();

    // fraction of accesses landing in tier-1 equals its share of the WS
    let f = trace.fraction_below(192.0 * GB);
    assert!((f - 0.192).abs() < 0.05, "tier-1 access share {f}");

    pool.free(a.id).unwrap();
    assert_eq!(pool.used(), 0.0);
}

/// Coherent sharing vs software copies: the directory's message counts
/// times fabric latency reproduce the ordering the Fig 7 middle region
/// depends on.
#[test]
fn coherence_beats_software_copy_for_sparse_sharing() {
    let mut dir = Directory::new(4);
    let mut rng = scalepool::util::Rng::new(23);
    let mut msgs = 0u64;
    let n = 50_000;
    for _ in 0..n {
        let a = rng.below(4) as usize;
        let block = rng.below(1_000_000); // sparse: almost no reuse
        msgs += dir.read(a, block).total() as u64;
    }
    dir.check_invariants().unwrap();
    let per_msg_ns = 300.0; // one fabric traversal per protocol message
    let coherent_ns = msgs as f64 / n as f64 * per_msg_ns + 100.0;
    let sw = SoftwareCopyModel::xlink_intra_rack().per_access_ns() + 100.0;
    assert!(
        coherent_ns < sw,
        "coherent {coherent_ns:.0} ns/access must beat sw-copy {sw:.0} ns/access on sparse sharing"
    );
}

/// Fig 7 params derived from different reference topologies give the same
/// qualitative result (the conclusion is not an artifact of one build).
#[test]
fn fig7_robust_to_fabric_shape() {
    use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
    use scalepool::fabric::TopologyKind;
    for kind in [TopologyKind::MultiLevelClos, TopologyKind::DragonFly] {
        let sys = ScalePoolBuilder::new()
            .racks((0..4).map(|i| {
                Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 8).unwrap()
            }))
            .config(SystemConfig { inter: InterCluster::Cxl(kind), mem_nodes: 4, ..Default::default() })
            .build();
        let p = fig7::Fig7Params::from_system(&sys);
        let rows = fig7::run_fig7_with(&p);
        let r3 = rows.iter().find(|r| r.working_set == 8.0 * fig7::CLUSTER_HBM).unwrap();
        assert!(
            r3.speedup_vs_baseline() > 2.0,
            "{kind:?}: region-3 speedup {:.2}",
            r3.speedup_vs_baseline()
        );
        assert!(r3.speedup_vs_acc_clusters() > 1.0, "{kind:?}");
    }
}
