//! Integration: the calculon execution model against the collective cost
//! models and the Figure 6 harness — cross-module consistency checks on
//! the quantities the paper reports.

use scalepool::calculon::execution::SystemProfile;
use scalepool::calculon::presets::{megatron_530b, paper_workloads};
use scalepool::calculon::{ExecutionModel, Parallelism};
use scalepool::collective::{Algorithm, CollectiveModel};
use scalepool::experiments::fig6;

/// The full Figure 6 run satisfies the paper's structural claims.
#[test]
fn fig6_structural_claims() {
    let res = fig6::run_fig6();
    assert_eq!(res.rows.len(), 5);
    for r in &res.rows {
        // every workload gains, and gains come from inter-cluster comm
        assert!(r.speedup() > 1.0, "{}", r.name);
        assert!(r.comm_speedup() > r.speedup(), "{}: comm speedup must exceed total", r.name);
        // compute identical
        assert_eq!(r.baseline.compute_ns, r.scalepool.compute_ns);
        // normalized bars: baseline sums to 1
        let [b, s] = r.normalized();
        assert!((b.0 + b.1 + b.2 - 1.0).abs() < 1e-9);
        assert!(s.0 + s.1 + s.2 < 1.0, "scalepool bar must be shorter");
    }
}

/// Scaling behavior: doubling the DP degree cannot reduce inter-cluster
/// communication time on the RDMA baseline.
#[test]
fn dp_scaling_monotone_on_baseline() {
    let w = megatron_530b();
    let model = ExecutionModel::new(SystemProfile::baseline_rdma());
    let mut last = 0.0;
    for dp in [4, 8, 16, 32] {
        let par = Parallelism { dp, ..w.par };
        let e = model.estimate(&w.model, &par);
        // dp shards the same gradient volume across more slower-joined
        // replicas: ring volume per rank stays ~constant, latency terms grow
        assert!(e.dp_comm_ns >= last * 0.8, "dp={dp}: {} vs {last}", e.dp_comm_ns);
        last = e.dp_comm_ns;
    }
}

/// Microbatch size trades TP message count against message size; the
/// model must be consistent: total TP bytes moved is conserved.
#[test]
fn tp_volume_conserved_across_microbatching() {
    let w = paper_workloads().into_iter().next().unwrap();
    let model = ExecutionModel::new(SystemProfile::scalepool_cxl());
    let e1 = model.estimate(&w.model, &Parallelism { microbatch: 1, ..w.par });
    let e2 = model.estimate(&w.model, &Parallelism { microbatch: 2, ..w.par });
    // 2x bigger messages, half as many: bandwidth term identical, latency
    // term halves -> tp time must not increase
    assert!(e2.tp_comm_ns <= e1.tp_comm_ns * 1.001);
    assert!(e2.tp_comm_ns >= e1.tp_comm_ns * 0.5);
}

/// The hierarchical collective the coordinator would use for DP beats the
/// flat ring over the slow inter-cluster transport for rack-aligned groups.
#[test]
fn hierarchical_dp_is_an_improvement() {
    let base = SystemProfile::baseline_rdma();
    let flat = CollectiveModel::flat(base.inter_rack);
    let hier = CollectiveModel::hierarchical(base.inter_rack, base.intra_rack, 8);
    let bytes = 4e9; // a 2 GB gradient shard
    let n = 64;
    let f = flat.all_reduce(n, bytes, Algorithm::Ring);
    let h = hier.all_reduce(n, bytes, Algorithm::Hierarchical);
    assert!(h < f, "hierarchical {h} !< flat {f}");
}

/// Offload exposure: with a slow enough offload path the exposed time
/// appears in "other" and is identical in structure across configs.
#[test]
fn offload_exposure_behaves() {
    let w = paper_workloads().into_iter().next().unwrap();
    let mut slow = SystemProfile::baseline_rdma();
    slow.offload_bw = 1.0; // 1 GB/s: clearly exposed
    let e_slow = ExecutionModel::new(slow).estimate(&w.model, &w.par);
    let e_fast = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&w.model, &w.par);
    assert!(e_slow.offload_ns > e_fast.offload_ns * 5.0);
    assert!(e_slow.other_ns() > e_fast.other_ns());
}

/// GPU-count sanity: per-GPU compute time shrinks as GPUs grow for a
/// fixed model+batch (weak scaling of the estimator).
#[test]
fn compute_scales_with_gpus() {
    let w = paper_workloads().into_iter().next().unwrap();
    let model = ExecutionModel::new(SystemProfile::scalepool_cxl());
    let small = model.estimate(&w.model, &Parallelism { dp: 8, ..w.par });
    let big = model.estimate(&w.model, &Parallelism { dp: 32, ..w.par });
    assert!(big.compute_ns < small.compute_ns);
}
