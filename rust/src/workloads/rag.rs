//! Retrieval-augmented-generation workload (§2): per-query ANN probes over
//! a sharded vector index plus bulk chunk fetches from the knowledge base —
//! a mix of small random reads and medium sequential reads.

use super::memws::{Access, AccessTrace};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RagWorkload {
    /// Knowledge-base size, bytes.
    pub kb_bytes: f64,
    /// Vector-index size, bytes (probed randomly).
    pub index_bytes: f64,
    /// Queries to generate.
    pub queries: usize,
    /// Index probes per query (IVF list scans).
    pub probes_per_query: usize,
    /// Retrieved chunks per query.
    pub chunks_per_query: usize,
    /// Chunk size, bytes.
    pub chunk_bytes: u32,
    pub seed: u64,
}

impl Default for RagWorkload {
    fn default() -> Self {
        RagWorkload {
            kb_bytes: 2e12,     // 2 TB corpus
            index_bytes: 200e9, // 200 GB index
            queries: 64,
            probes_per_query: 32,
            chunks_per_query: 8,
            chunk_bytes: 64 * 1024,
            seed: 17,
        }
    }
}

impl RagWorkload {
    pub fn working_set(&self) -> f64 {
        self.kb_bytes + self.index_bytes
    }

    pub fn trace(&self) -> AccessTrace {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        let mut accesses = Vec::new();
        for _ in 0..self.queries {
            // index probes: small random reads in [0, index_bytes)
            for _ in 0..self.probes_per_query {
                t += rng.exp(1.0 / 3.0);
                let off = rng.below(self.index_bytes as u64 / 64) * 64;
                accesses.push(Access { offset: off, bytes: 4096, at: t });
            }
            // chunk fetches: medium reads in [index_bytes, index+kb)
            for _ in 0..self.chunks_per_query {
                t += rng.exp(1.0 / 2.0);
                let span = (self.kb_bytes as u64 - self.chunk_bytes as u64) / 64;
                let off = self.index_bytes as u64 + rng.below(span) * 64;
                accesses.push(Access { offset: off, bytes: self.chunk_bytes, at: t });
            }
            t += 500.0;
        }
        AccessTrace { working_set: self.working_set(), accesses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn working_set_is_pool_scale() {
        // RAG is the tier-2 poster child: way beyond cluster HBM
        assert!(RagWorkload::default().working_set() > 1e12);
    }

    #[test]
    fn mixes_probe_and_chunk_reads() {
        let w = RagWorkload::default();
        let trace = w.trace();
        let small = trace.accesses.iter().filter(|a| a.bytes == 4096).count();
        let big = trace.accesses.iter().filter(|a| a.bytes == w.chunk_bytes).count();
        assert_eq!(small, w.queries * w.probes_per_query);
        assert_eq!(big, w.queries * w.chunks_per_query);
    }

    #[test]
    fn probes_hit_index_chunks_hit_kb() {
        let w = RagWorkload::default();
        for a in w.trace().accesses {
            if a.bytes == 4096 {
                assert!((a.offset as f64) < w.index_bytes);
            } else {
                assert!((a.offset as f64) >= w.index_bytes);
            }
        }
    }
}
