//! Workload generators: the Figure 7 working-set sweep plus the three
//! memory-intensive access patterns the paper motivates (§2): KV-cache
//! serving, embedding-table lookups, and RAG retrieval.

pub mod memws;
pub mod kvcache;
pub mod embedding;
pub mod rag;
pub mod traffic;

pub use embedding::EmbeddingWorkload;
pub use kvcache::KvCacheWorkload;
pub use memws::{AccessTrace, WorkingSetSweep};
pub use rag::RagWorkload;
pub use traffic::{SyntheticTraffic, WorkingSetTraffic, WorkingSetTrafficConfig};
