//! The Figure 7 workload: a synthetic access stream over a configurable
//! working set, swept from "fits in one accelerator's HBM" to "exceeds the
//! whole cluster" — plus the trace representation shared by all workloads.

use crate::util::Rng;

/// One memory access in a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Access {
    /// Byte offset into the working set.
    pub offset: u64,
    /// Access size, bytes.
    pub bytes: u32,
    /// Issue time relative to trace start, ns.
    pub at: f64,
}

/// A generated access trace over a working set.
#[derive(Clone, Debug)]
pub struct AccessTrace {
    pub working_set: f64,
    pub accesses: Vec<Access>,
}

impl AccessTrace {
    /// Fraction of accesses whose offset falls below `boundary` bytes.
    pub fn fraction_below(&self, boundary: f64) -> f64 {
        if self.accesses.is_empty() {
            return 0.0;
        }
        let n = self.accesses.iter().filter(|a| (a.offset as f64) < boundary).count();
        n as f64 / self.accesses.len() as f64
    }
}

/// Sweep generator for Figure 7.
#[derive(Clone, Debug)]
pub struct WorkingSetSweep {
    /// Access granularity, bytes (64 B cache line by default).
    pub access_bytes: u32,
    /// Accesses per trace point.
    pub accesses: usize,
    /// Zipf skew (0 = uniform — the paper's capacity-bound regime).
    pub theta: f64,
    /// Mean issue interval, ns (Poisson arrivals).
    pub interval_ns: f64,
    pub seed: u64,
}

impl Default for WorkingSetSweep {
    fn default() -> Self {
        WorkingSetSweep { access_bytes: 64, accesses: 10_000, theta: 0.0, interval_ns: 10.0, seed: 7 }
    }
}

impl WorkingSetSweep {
    /// Working-set sizes (bytes) to sweep, anchored on the two capacity
    /// thresholds of Figure 7: one accelerator's HBM and one cluster.
    pub fn sweep_points(accel_hbm: f64, cluster_hbm: f64, beyond: f64) -> Vec<f64> {
        vec![
            0.25 * accel_hbm,
            0.5 * accel_hbm,
            1.0 * accel_hbm,
            4.0 * accel_hbm,
            16.0 * accel_hbm,
            0.5 * cluster_hbm,
            1.0 * cluster_hbm,
            2.0 * cluster_hbm,
            4.0 * cluster_hbm,
            beyond * cluster_hbm,
        ]
    }

    /// Generate traces for a whole sweep at once, one scoped worker
    /// thread per point (§Perf: trace generation is the setup cost of
    /// every event-simulated sweep). Each point derives its own seed from
    /// the base seed and its index, so the result is deterministic and
    /// identical to calling [`Self::trace`] point by point with those
    /// seeds.
    pub fn traces(&self, working_sets: &[f64]) -> Vec<AccessTrace> {
        let indexed: Vec<(usize, f64)> = working_sets.iter().copied().enumerate().collect();
        crate::util::par::par_map(&indexed, |&(i, ws)| {
            WorkingSetSweep { seed: self.seed.wrapping_add(i as u64), ..self.clone() }.trace(ws)
        })
    }

    /// Generate a trace over `working_set` bytes.
    pub fn trace(&self, working_set: f64) -> AccessTrace {
        let mut rng = Rng::new(self.seed);
        let lines = (working_set / self.access_bytes as f64).max(1.0) as u64;
        let mut t = 0.0;
        let accesses = (0..self.accesses)
            .map(|_| {
                let line = if self.theta > 0.0 { rng.zipf(lines, self.theta) } else { rng.below(lines) };
                t += rng.exp(1.0 / self.interval_ns);
                Access { offset: line * self.access_bytes as u64, bytes: self.access_bytes, at: t }
            })
            .collect();
        AccessTrace { working_set, accesses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::GB;

    #[test]
    fn sweep_points_bracket_thresholds() {
        let pts = WorkingSetSweep::sweep_points(192.0 * GB, 72.0 * 192.0 * GB, 8.0);
        assert!(pts.first().unwrap() < &(192.0 * GB));
        assert!(pts.last().unwrap() > &(72.0 * 192.0 * GB));
        assert!(pts.windows(2).all(|w| w[0] < w[1]), "sweep must be increasing");
    }

    #[test]
    fn uniform_trace_spans_working_set() {
        let sweep = WorkingSetSweep { accesses: 20_000, ..Default::default() };
        let ws = 1.0 * GB;
        let trace = sweep.trace(ws);
        assert_eq!(trace.accesses.len(), 20_000);
        // uniform: about half the accesses below the midpoint
        let f = trace.fraction_below(ws / 2.0);
        assert!((f - 0.5).abs() < 0.02, "uniform split {f}");
        assert!(trace.accesses.iter().all(|a| (a.offset as f64) < ws));
    }

    #[test]
    fn zipf_trace_skews_low_offsets() {
        let sweep = WorkingSetSweep { theta: 0.99, accesses: 20_000, ..Default::default() };
        let ws = 1.0 * GB;
        let trace = sweep.trace(ws);
        assert!(trace.fraction_below(ws * 0.01) > 0.3, "zipf must concentrate low offsets");
    }

    #[test]
    fn issue_times_increase() {
        let trace = WorkingSetSweep::default().trace(1e6);
        assert!(trace.accesses.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkingSetSweep::default().trace(1e6);
        let b = WorkingSetSweep::default().trace(1e6);
        assert_eq!(a.accesses, b.accesses);
    }

    #[test]
    fn parallel_traces_match_serial_per_point() {
        let sweep = WorkingSetSweep { accesses: 2000, ..Default::default() };
        let points = [1e6, 4e6, 16e6, 64e6, 256e6];
        let par = sweep.traces(&points);
        assert_eq!(par.len(), points.len());
        for (i, (&ws, trace)) in points.iter().zip(&par).enumerate() {
            let serial =
                WorkingSetSweep { seed: sweep.seed.wrapping_add(i as u64), ..sweep.clone() }.trace(ws);
            assert_eq!(trace.accesses, serial.accesses, "point {i} diverged");
            assert_eq!(trace.working_set, ws);
        }
    }
}
