//! Recommendation-model embedding-lookup workload (§2: "embedding
//! lookups"): many small gathers over a huge table, Zipf-skewed — the
//! classic capacity-over-bandwidth consumer.

use super::memws::{Access, AccessTrace};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct EmbeddingWorkload {
    /// Number of embedding rows.
    pub rows: u64,
    /// Bytes per row (dim * dtype).
    pub row_bytes: u32,
    /// Lookups per batch.
    pub lookups_per_batch: usize,
    /// Batches to generate.
    pub batches: usize,
    /// Popularity skew.
    pub theta: f64,
    pub seed: u64,
}

impl Default for EmbeddingWorkload {
    fn default() -> Self {
        EmbeddingWorkload {
            rows: 400_000_000,  // 400M-row table
            row_bytes: 512,     // 128-dim fp32
            lookups_per_batch: 4096,
            batches: 8,
            theta: 0.9,
            seed: 13,
        }
    }
}

impl EmbeddingWorkload {
    pub fn table_bytes(&self) -> f64 {
        self.rows as f64 * self.row_bytes as f64
    }

    pub fn trace(&self) -> AccessTrace {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        let mut accesses = Vec::with_capacity(self.batches * self.lookups_per_batch);
        for _ in 0..self.batches {
            for _ in 0..self.lookups_per_batch {
                let row = rng.zipf(self.rows, self.theta);
                t += rng.exp(1.0);
                accesses.push(Access { offset: row * self.row_bytes as u64, bytes: self.row_bytes, at: t });
            }
            t += 1_000.0; // inter-batch gap
        }
        AccessTrace { working_set: self.table_bytes(), accesses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_exceeds_accelerator_memory() {
        let w = EmbeddingWorkload::default();
        assert!(w.table_bytes() > 192e9, "table {:.2e} B", w.table_bytes());
    }

    #[test]
    fn lookups_are_skewed() {
        let trace = EmbeddingWorkload::default().trace();
        let hot = trace.fraction_below(trace.working_set * 0.001);
        assert!(hot > 0.15, "hot 0.1% share {hot}");
    }

    #[test]
    fn trace_size() {
        let w = EmbeddingWorkload::default();
        assert_eq!(w.trace().accesses.len(), w.batches * w.lookups_per_batch);
    }
}
