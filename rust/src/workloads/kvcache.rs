//! KV-cache serving workload (§2: "KV caching and RAG require extensive
//! memory capacities combined with high I/O bandwidth"): per-request
//! sequential reads of a conversation's KV blocks, with a long-tail
//! distribution of context lengths.

use super::memws::{Access, AccessTrace};
use crate::util::Rng;

/// A batched-decoding KV-cache access generator.
#[derive(Clone, Debug)]
pub struct KvCacheWorkload {
    /// Concurrent conversations resident in the cache.
    pub conversations: usize,
    /// KV bytes per token per layer-stack (2 * layers * hidden * kv_heads
    /// ratio * dtype — precomputed).
    pub bytes_per_token: f64,
    /// Mean context length, tokens (exponential tail).
    pub mean_context: f64,
    /// Decode steps to simulate.
    pub steps: usize,
    pub seed: u64,
}

impl Default for KvCacheWorkload {
    fn default() -> Self {
        KvCacheWorkload {
            conversations: 256,
            bytes_per_token: 160.0 * 1024.0, // ~160 KB/token (70B-class)
            mean_context: 2_048.0,
            steps: 32,
            seed: 11,
        }
    }
}

impl KvCacheWorkload {
    /// Total cache footprint, bytes.
    pub fn footprint(&self, contexts: &[u64]) -> f64 {
        contexts.iter().map(|&c| c as f64 * self.bytes_per_token).sum()
    }

    /// Generate the trace: each decode step reads every conversation's
    /// whole KV prefix (attention over the full context), block by block.
    pub fn trace(&self) -> AccessTrace {
        let mut rng = Rng::new(self.seed);
        let contexts: Vec<u64> =
            (0..self.conversations).map(|_| (rng.exp(1.0 / self.mean_context)).max(16.0) as u64).collect();
        // conversation base offsets laid out back to back
        let mut bases = Vec::with_capacity(contexts.len());
        let mut cursor = 0u64;
        for &c in &contexts {
            bases.push(cursor);
            cursor += (c as f64 * self.bytes_per_token) as u64;
        }
        let block = 16.0 * 1024.0; // paged-attention block
        let mut t = 0.0;
        let mut accesses = Vec::new();
        for _step in 0..self.steps {
            for (i, &c) in contexts.iter().enumerate() {
                let total = c as f64 * self.bytes_per_token;
                let blocks = (total / block).ceil() as u64;
                // sample a subset of blocks per step to bound trace size
                let stride = (blocks / 16).max(1);
                let mut b = 0;
                while b < blocks {
                    t += rng.exp(1.0 / 5.0);
                    accesses.push(Access {
                        offset: bases[i] + b * block as u64,
                        bytes: block as u32,
                        at: t,
                    });
                    b += stride;
                }
            }
        }
        AccessTrace { working_set: cursor as f64, accesses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_exceeds_hbm_at_scale() {
        // the paper's motivation: serving KV caches outgrow one GPU
        let w = KvCacheWorkload { conversations: 2048, ..Default::default() };
        let trace = w.trace();
        assert!(trace.working_set > 192e9, "footprint {:.2e}", trace.working_set);
    }

    #[test]
    fn accesses_within_working_set() {
        let trace = KvCacheWorkload::default().trace();
        for a in &trace.accesses {
            assert!((a.offset as f64) < trace.working_set);
        }
    }

    #[test]
    fn sequential_within_conversation() {
        let trace = KvCacheWorkload { conversations: 1, steps: 1, ..Default::default() }.trace();
        let offs: Vec<u64> = trace.accesses.iter().map(|a| a.offset).collect();
        assert!(offs.windows(2).all(|w| w[0] < w[1]), "per-conversation reads are sequential");
    }

    #[test]
    fn deterministic() {
        let a = KvCacheWorkload::default().trace();
        let b = KvCacheWorkload::default().trace();
        assert_eq!(a.accesses.len(), b.accesses.len());
        assert_eq!(a.accesses.first(), b.accesses.first());
    }
}
