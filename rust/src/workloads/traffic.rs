//! Synthetic background traffic as a streamed [`TrafficSource`]: the
//! generator the `simulate` subcommand uses instead of materializing a
//! `Vec<Transaction>` up front — a million-transaction run holds O(peak
//! in-flight) state, generating each transaction as the clock reaches it.
//!
//! Also home to [`WorkingSetTraffic`], the Figure-7 working-set access
//! stream as a streamed source: the detailed fig7 mode and the traffic
//! layer share [`MemSim::run_streamed`](crate::sim::MemSim::run_streamed)
//! end-to-end.

use crate::fabric::NodeId;
use crate::sim::{Pull, SourcedTx, TrafficClass, TrafficSource, Transaction};
use crate::util::Rng;

/// Open-loop random point-to-point (plus memory-node) traffic.
pub struct SyntheticTraffic {
    endpoints: Vec<NodeId>,
    mem_nodes: Vec<NodeId>,
    /// Probability a transaction targets a memory node.
    mem_frac: f64,
    /// Mean interarrival, ns (exponential).
    mean_interarrival_ns: f64,
    bytes: f64,
    device_ns: f64,
    total: u64,
    issued: u64,
    at: f64,
    rng: Rng,
}

impl SyntheticTraffic {
    pub fn new(
        endpoints: Vec<NodeId>,
        mem_nodes: Vec<NodeId>,
        total: u64,
        bytes: f64,
        mean_interarrival_ns: f64,
        seed: u64,
    ) -> SyntheticTraffic {
        assert!(endpoints.len() >= 2, "need at least two endpoints");
        SyntheticTraffic {
            endpoints,
            mem_nodes,
            mem_frac: 0.3,
            mean_interarrival_ns,
            bytes,
            device_ns: 130.0,
            total,
            issued: 0,
            at: 0.0,
            rng: Rng::new(seed),
        }
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }
}

impl TrafficSource for SyntheticTraffic {
    fn class(&self) -> TrafficClass {
        TrafficClass::Generic
    }

    fn pull(&mut self, _now: f64) -> Pull {
        if self.issued >= self.total {
            return Pull::Done;
        }
        self.issued += 1;
        self.at += self.rng.exp(1.0 / self.mean_interarrival_ns);
        let eps = &self.endpoints;
        let src = eps[self.rng.below(eps.len() as u64) as usize];
        let dst = if !self.mem_nodes.is_empty() && self.rng.f64() < self.mem_frac {
            self.mem_nodes[self.rng.below(self.mem_nodes.len() as u64) as usize]
        } else {
            let mut d = eps[self.rng.below(eps.len() as u64) as usize];
            while d == src {
                d = eps[self.rng.below(eps.len() as u64) as usize];
            }
            d
        };
        Pull::Tx(SourcedTx::new(
            Transaction { src, dst, at: self.at, bytes: self.bytes, device_ns: self.device_ns },
            0,
        ))
    }

    fn open_loop(&self) -> bool {
        true // open-loop by construction: arrivals are a Poisson process
    }
}

/// Cost/shape parameters of a [`WorkingSetTraffic`] stream — one per
/// Figure-7 configuration (baseline / accelerator-clusters / tiered),
/// differing only in where beyond-capacity offsets go and what per-access
/// software/protocol cost rides on top of the fabric path.
#[derive(Clone, Debug)]
pub struct WorkingSetTrafficConfig {
    /// Swept working-set size, bytes.
    pub working_set: f64,
    /// Capacity of the requester's own HBM (level-1 boundary), bytes.
    pub accel_capacity: f64,
    /// Capacity of the whole cluster's tier-1 (level-2 boundary), bytes.
    pub cluster_capacity: f64,
    /// Access granularity, bytes (64 B cache line by default).
    pub line_bytes: u32,
    /// Mean issue interval, ns (Poisson arrivals).
    pub interval_ns: f64,
    pub accesses: u64,
    pub seed: u64,
    /// Device time of a tier-1 HBM access, ns.
    pub hbm_ns: f64,
    /// Device time at the beyond-cluster level, ns.
    pub remote_device_ns: f64,
    /// Per-access software/protocol adder for the intra-cluster remote
    /// level (software copy on XLink configs, CXL.cache protocol cost on
    /// the coherent config), ns.
    pub mid_extra_ns: f64,
    /// Same for the beyond-cluster level, ns.
    pub far_extra_ns: f64,
}

/// The Figure-7 working-set access stream as a streamed traffic source:
/// offsets below `accel_capacity` are local HBM hits (zero-hop, device
/// time only), offsets within `cluster_capacity` hit a same-rack peer,
/// and the remainder goes to the configuration's beyond-cluster level
/// (remote-rack accelerators or tier-2 memory nodes) — each access is a
/// real fabric transaction, so queuing at the shared links emerges
/// instead of being a closed-form adder. Open-loop (sharding-eligible).
pub struct WorkingSetTraffic {
    cfg: WorkingSetTrafficConfig,
    /// Requesters and intra-cluster peers: the home rack's accelerators.
    home: Vec<NodeId>,
    /// Beyond-cluster targets (memory nodes or remote-rack accelerators);
    /// may be empty when the working set never spills past the cluster.
    remote: Vec<NodeId>,
    issued: u64,
    at: f64,
    rng: Rng,
}

impl WorkingSetTraffic {
    pub fn new(cfg: WorkingSetTrafficConfig, home: Vec<NodeId>, remote: Vec<NodeId>) -> WorkingSetTraffic {
        assert!(home.len() >= 2, "need at least two home accelerators");
        assert!(
            !remote.is_empty() || cfg.working_set <= cfg.cluster_capacity,
            "working set spills past the cluster but no beyond-cluster targets were given"
        );
        let seed = cfg.seed;
        WorkingSetTraffic { cfg, home, remote, issued: 0, at: 0.0, rng: Rng::new(seed) }
    }
}

impl TrafficSource for WorkingSetTraffic {
    fn class(&self) -> TrafficClass {
        TrafficClass::Generic
    }

    fn pull(&mut self, _now: f64) -> Pull {
        let c = &self.cfg;
        if self.issued >= c.accesses {
            return Pull::Done;
        }
        self.issued += 1;
        // same draw order as WorkingSetSweep::trace: offset, then interval
        let lines = (c.working_set / c.line_bytes as f64).max(1.0) as u64;
        let line = self.rng.below(lines);
        self.at += self.rng.exp(1.0 / c.interval_ns);
        let off = line as f64 * c.line_bytes as f64;
        let h = self.home.len() as u64;
        let src = self.home[(line % h) as usize];
        let (dst, device_ns) = if off < c.accel_capacity {
            (src, c.hbm_ns) // local hit: device time only
        } else if off < c.cluster_capacity || self.remote.is_empty() {
            let mut d = self.home[((line / h) % h) as usize];
            if d == src {
                d = self.home[((line / h + 1) % h) as usize];
            }
            (d, c.hbm_ns + c.mid_extra_ns)
        } else {
            let d = self.remote[(line % self.remote.len() as u64) as usize];
            (d, c.remote_device_ns + c.far_extra_ns)
        };
        Pull::Tx(SourcedTx::new(
            Transaction { src, dst, at: self.at, bytes: c.line_bytes as f64, device_ns },
            0,
        ))
    }

    fn open_loop(&self) -> bool {
        true // the access stream never waits on completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
    use crate::sim::MemSim;

    fn ws_cfg(working_set: f64) -> WorkingSetTrafficConfig {
        WorkingSetTrafficConfig {
            working_set,
            accel_capacity: 1e6,
            cluster_capacity: 8e6,
            line_bytes: 64,
            interval_ns: 10.0,
            accesses: 5_000,
            seed: 7,
            hbm_ns: 100.0,
            remote_device_ns: 130.0,
            mid_extra_ns: 80.0,
            far_extra_ns: 0.0,
        }
    }

    #[test]
    fn working_set_traffic_tiers_by_offset() {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        let f = Fabric::new(t);
        // within one accelerator: all local, latency == device time exactly
        let mut local = WorkingSetTraffic::new(ws_cfg(0.5e6), accs.clone(), vec![]);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut s: [&mut dyn TrafficSource; 1] = [&mut local];
            sim.run_streamed(&mut s)
        };
        assert_eq!(rep.total.completed, 5_000);
        assert!((rep.total.latency.mean() - 100.0).abs() < 1e-9, "local hits pay device only");

        // beyond one accelerator: peer traffic pays the fabric + adder
        let mut mid = WorkingSetTraffic::new(ws_cfg(4e6), accs.clone(), vec![]);
        let mut sim2 = MemSim::new(&f);
        let rep2 = {
            let mut s: [&mut dyn TrafficSource; 1] = [&mut mid];
            sim2.run_streamed(&mut s)
        };
        assert_eq!(rep2.total.completed, 5_000);
        assert!(rep2.total.latency.mean() > rep.total.latency.mean() + 50.0, "remote level must cost more");
    }

    #[test]
    #[should_panic(expected = "beyond-cluster targets")]
    fn working_set_traffic_rejects_missing_far_targets() {
        let t = Topology::single_hop(4, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        WorkingSetTraffic::new(ws_cfg(64e6), accs, vec![]);
    }

    #[test]
    fn streams_without_materializing_the_workload() {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        let f = Fabric::new(t);
        let mut src = SyntheticTraffic::new(accs, vec![], 20_000, 1024.0, 50.0, 7);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed(&mut sources)
        };
        assert_eq!(rep.total.completed, 20_000);
        // the memory contract: peak in-flight stays far below the
        // workload length
        assert!(
            rep.peak_inflight < 2_000,
            "streaming should bound concurrency: {} slots",
            rep.peak_inflight
        );
    }
}
