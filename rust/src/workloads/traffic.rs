//! Synthetic background traffic as a streamed [`TrafficSource`]: the
//! generator the `simulate` subcommand uses instead of materializing a
//! `Vec<Transaction>` up front — a million-transaction run holds O(peak
//! in-flight) state, generating each transaction as the clock reaches it.

use crate::fabric::NodeId;
use crate::sim::{Pull, SourcedTx, TrafficClass, TrafficSource, Transaction};
use crate::util::Rng;

/// Open-loop random point-to-point (plus memory-node) traffic.
pub struct SyntheticTraffic {
    endpoints: Vec<NodeId>,
    mem_nodes: Vec<NodeId>,
    /// Probability a transaction targets a memory node.
    mem_frac: f64,
    /// Mean interarrival, ns (exponential).
    mean_interarrival_ns: f64,
    bytes: f64,
    device_ns: f64,
    total: u64,
    issued: u64,
    at: f64,
    rng: Rng,
}

impl SyntheticTraffic {
    pub fn new(
        endpoints: Vec<NodeId>,
        mem_nodes: Vec<NodeId>,
        total: u64,
        bytes: f64,
        mean_interarrival_ns: f64,
        seed: u64,
    ) -> SyntheticTraffic {
        assert!(endpoints.len() >= 2, "need at least two endpoints");
        SyntheticTraffic {
            endpoints,
            mem_nodes,
            mem_frac: 0.3,
            mean_interarrival_ns,
            bytes,
            device_ns: 130.0,
            total,
            issued: 0,
            at: 0.0,
            rng: Rng::new(seed),
        }
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }
}

impl TrafficSource for SyntheticTraffic {
    fn class(&self) -> TrafficClass {
        TrafficClass::Generic
    }

    fn pull(&mut self, _now: f64) -> Pull {
        if self.issued >= self.total {
            return Pull::Done;
        }
        self.issued += 1;
        self.at += self.rng.exp(1.0 / self.mean_interarrival_ns);
        let eps = &self.endpoints;
        let src = eps[self.rng.below(eps.len() as u64) as usize];
        let dst = if !self.mem_nodes.is_empty() && self.rng.f64() < self.mem_frac {
            self.mem_nodes[self.rng.below(self.mem_nodes.len() as u64) as usize]
        } else {
            let mut d = eps[self.rng.below(eps.len() as u64) as usize];
            while d == src {
                d = eps[self.rng.below(eps.len() as u64) as usize];
            }
            d
        };
        Pull::Tx(SourcedTx {
            tx: Transaction { src, dst, at: self.at, bytes: self.bytes, device_ns: self.device_ns },
            token: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
    use crate::sim::MemSim;

    #[test]
    fn streams_without_materializing_the_workload() {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        let f = Fabric::new(t);
        let mut src = SyntheticTraffic::new(accs, vec![], 20_000, 1024.0, 50.0, 7);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed(&mut sources)
        };
        assert_eq!(rep.total.completed, 20_000);
        // the memory contract: peak in-flight stays far below the
        // workload length
        assert!(
            rep.peak_inflight < 2_000,
            "streaming should bound concurrency: {} slots",
            rep.peak_inflight
        );
    }
}
