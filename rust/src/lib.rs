//! # ScalePool
//!
//! Reproduction of *"ScalePool: Hybrid XLink-CXL Fabric for Composable Resource
//! Disaggregation in Unified Scale-up Domains"* (Panmnesia, 2025).
//!
//! ScalePool interconnects many accelerators through hardware interconnects
//! instead of long-distance networking: XLink (NVLink / UALink) for
//! intra-cluster accelerator communication, and hierarchical CXL switching
//! fabrics for scalable, coherent inter-cluster memory sharing — plus an
//! explicit two-tier memory hierarchy (tier-1 accelerator-local + coherence-
//! centric CXL, tier-2 capacity-oriented CXL memory nodes).
//!
//! This crate is the Layer-3 (rust) side of a three-layer stack:
//!
//! * **L3 (this crate)** — the fabric/cluster/memory simulator, the
//!   Calculon-style LLM co-design model, and the ScalePool coordinator
//!   (allocation, routing, tiering, job scheduling).
//! * **L2 (python/compile/model.py)** — a JAX transformer LM fwd/bwd +
//!   optimizer, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (fused attention,
//!   tiled matmul, fused AdamW) called from L2, interpret-mode for CPU PJRT.
//!
//! Python never runs on the request path: [`runtime`] loads the AOT HLO
//! artifacts through PJRT (the `xla` crate) and executes them from rust.
//!
//! See `DESIGN.md` for the full system inventory and per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod util;
pub mod fabric;
pub mod sim;
pub mod coherence;
pub mod memory;
pub mod cluster;
pub mod collective;
pub mod calculon;
pub mod workloads;
pub mod coordinator;
pub mod runtime;
pub mod experiments;
pub mod bench;
pub mod cli;

pub use fabric::{Fabric, LinkKind, Topology};
