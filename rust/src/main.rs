//! `scalepool` CLI — leader entrypoint for the ScalePool reproduction.
//!
//! Subcommands map onto the paper's evaluation:
//! * `fig6`   — LLM training time, ScalePool vs RDMA baseline (Figure 6)
//! * `fig7`   — tiered-memory latency sweep (Figure 7)
//! * `mixed`  — coherence + tiering + collective traffic concurrently on
//!              one fabric; per-class latency under interference
//! * `table1` — CXL / UALink / NVLink link-characteristics table (Table 1)
//! * `topo`   — build and inspect fabric topologies
//! * `train`  — end-to-end: run the AOT-compiled JAX/Pallas train step on
//!              PJRT under the ScalePool coordinator (hybrid emulation)
//! * `simulate` — discrete-event memory-access simulation on a topology
fn main() {
    let code = scalepool::cli::run(std::env::args().skip(1).collect());
    std::process::exit(code);
}
