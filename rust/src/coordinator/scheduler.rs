//! Hybrid-emulation training scheduler: real numerics via the PJRT
//! runtime (the AOT JAX/Pallas train step), cluster timing via the
//! calculon model — one step's wall-clock compute is measured, the
//! communication/bubble/offload overheads of the emulated multi-rack
//! deployment are injected from the estimate, and both baseline and
//! ScalePool timelines are maintained for the same loss curve.
//!
//! This is the end-to-end validation driver: it proves L3 (this crate),
//! L2 (the lowered JAX model) and L1 (the Pallas kernels inside it)
//! compose on a real workload.
//!
//! [`TrainJobScheduler`] drives the PJRT runtime and is only available
//! with the `pjrt` feature (the `xla` crate is not in the offline vendor
//! set); [`EmulatedCluster`] is pure modeling and always available.

use crate::calculon::execution::SystemProfile;
use crate::calculon::{ExecutionModel, LlmModel, Parallelism, TrainingEstimate};
#[cfg(feature = "pjrt")]
use crate::coordinator::metrics::Metrics;
#[cfg(feature = "pjrt")]
use crate::runtime::{SyntheticCorpus, Trainer};
#[cfg(feature = "pjrt")]
use crate::util::error::Result;

/// The emulated deployment a training job runs on.
#[derive(Clone, Debug)]
pub struct EmulatedCluster {
    pub model: LlmModel,
    pub par: Parallelism,
    pub baseline: SystemProfile,
    pub scalepool: SystemProfile,
}

impl EmulatedCluster {
    /// Describe the *actual* PJRT-resident model as a calculon workload
    /// (so the emulated comm volumes match the real tensor sizes), mapped
    /// onto a multi-rack deployment.
    pub fn for_preset(
        vocab: usize,
        hidden: usize,
        layers: usize,
        heads: usize,
        seq: usize,
        global_batch: usize,
        par: Parallelism,
    ) -> EmulatedCluster {
        EmulatedCluster {
            model: LlmModel {
                name: "e2e".into(),
                layers,
                hidden,
                heads,
                seq,
                vocab,
                global_batch,
                mlp_mult: 4,
            },
            par,
            baseline: SystemProfile::baseline_rdma(),
            scalepool: SystemProfile::scalepool_cxl(),
        }
    }

    pub fn estimates(&self) -> (TrainingEstimate, TrainingEstimate) {
        (
            ExecutionModel::new(self.baseline.clone()).estimate(&self.model, &self.par),
            ExecutionModel::new(self.scalepool.clone()).estimate(&self.model, &self.par),
        )
    }
}

/// One scheduled step's record.
#[cfg(feature = "pjrt")]
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    /// Measured PJRT wall-clock, ns.
    pub compute_wall_ns: u64,
    /// Emulated step time on the baseline deployment, ns.
    pub baseline_step_ns: f64,
    /// Emulated step time on ScalePool, ns.
    pub scalepool_step_ns: f64,
}

/// The scheduler.
#[cfg(feature = "pjrt")]
pub struct TrainJobScheduler {
    trainer: Trainer,
    corpus: SyntheticCorpus,
    cluster: EmulatedCluster,
    pub metrics: Metrics,
    log: Vec<StepLog>,
    /// emulated clocks, ns
    baseline_clock: f64,
    scalepool_clock: f64,
}

#[cfg(feature = "pjrt")]
impl TrainJobScheduler {
    pub fn new(trainer: Trainer, cluster: EmulatedCluster, seed: u64) -> TrainJobScheduler {
        let vocab = trainer.manifest().vocab;
        TrainJobScheduler {
            trainer,
            corpus: SyntheticCorpus::new(vocab, seed),
            cluster,
            metrics: Metrics::new(),
            log: Vec::new(),
            baseline_clock: 0.0,
            scalepool_clock: 0.0,
        }
    }

    pub fn init(&mut self, seed: i32) -> Result<()> {
        self.trainer.init(seed)
    }

    /// Run `steps` training steps.
    pub fn run(&mut self, steps: usize) -> Result<&[StepLog]> {
        let (be, se) = self.cluster.estimates();
        let (b, s) = (self.trainer.manifest().batch, self.trainer.manifest().seq);
        for _ in 0..steps {
            let (toks, tgts) = self.corpus.batch(b, s);
            let r = self.trainer.step(&toks, &tgts)?;
            // inject the emulated deployment's non-compute overheads on
            // top of the (scaled) real compute
            self.baseline_clock += be.total_ns();
            self.scalepool_clock += se.total_ns();
            self.metrics.observe("pjrt_step", r.exec_ns as f64);
            self.metrics.inc("steps");
            self.log.push(StepLog {
                step: r.step,
                loss: r.loss,
                compute_wall_ns: r.exec_ns,
                baseline_step_ns: be.total_ns(),
                scalepool_step_ns: se.total_ns(),
            });
        }
        Ok(&self.log)
    }

    pub fn log(&self) -> &[StepLog] {
        &self.log
    }

    /// Emulated end-to-end speedup of ScalePool over the baseline for the
    /// work done so far.
    pub fn emulated_speedup(&self) -> f64 {
        if self.scalepool_clock <= 0.0 {
            1.0
        } else {
            self.baseline_clock / self.scalepool_clock
        }
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emulated_cluster_estimates_ordering() {
        let c = EmulatedCluster::for_preset(
            256,
            64,
            2,
            2,
            64,
            64,
            Parallelism { tp: 8, pp: 4, dp: 8, microbatch: 1 },
        );
        let (b, s) = c.estimates();
        assert!(b.total_ns() > s.total_ns(), "ScalePool must win");
        assert_eq!(b.compute_ns, s.compute_ns);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn end_to_end_tiny_schedule() {
        if !crate::runtime::artifacts_available("tiny") {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let dir = crate::runtime::default_artifacts_dir();
        let trainer = Trainer::load(&dir, "tiny").unwrap();
        let m = trainer.manifest().clone();
        let cluster = EmulatedCluster::for_preset(
            m.vocab,
            64,
            2,
            2,
            m.seq,
            512,
            Parallelism { tp: 8, pp: 4, dp: 16, microbatch: 1 },
        );
        let mut sched = TrainJobScheduler::new(trainer, cluster, 1);
        sched.init(0).unwrap();
        let log = sched.run(10).unwrap();
        assert_eq!(log.len(), 10);
        assert!(log.last().unwrap().loss < log.first().unwrap().loss * 1.05);
        assert!(sched.emulated_speedup() > 1.0);
        assert_eq!(sched.metrics.counter("steps"), 10);
    }
}
