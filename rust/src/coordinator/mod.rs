//! The ScalePool coordinator: the runtime brain that makes the paper's
//! architecture operational — job admission and accelerator allocation
//! over XLink domains, data-movement routing across the hybrid fabric,
//! runtime memory tiering over the composable pools, and the training-job
//! scheduler that drives the PJRT runtime under simulated cluster timing
//! (hybrid emulation).

pub mod metrics;
pub mod qos;
pub mod router;
pub mod routing;
pub mod tiering;
pub mod traffic;
pub mod manager;
pub mod scheduler;

pub use manager::{JobId, JobSpec, ScalePoolManager};
pub use metrics::Metrics;
pub use qos::QosManager;
pub use router::{DataMovementRouter, RouteClass, RouteDecision};
pub use routing::RoutingManager;
pub use scheduler::EmulatedCluster;
#[cfg(feature = "pjrt")]
pub use scheduler::TrainJobScheduler;
pub use tiering::{MigrationKind, MigrationRecord, TieringEngine, TieringPolicy, TieringStats};
pub use traffic::{TieringTraffic, TieringTrafficConfig};
