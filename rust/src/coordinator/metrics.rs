//! Lightweight metrics registry: named counters and ns-scale histograms
//! (log-bucketed), shared by the coordinator components.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Log-bucketed latency histogram (1 ns .. ~18 s in x2 buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: [u64; 35],
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 35], count: 0, sum: 0.0 }
    }
}

impl Histogram {
    #[inline]
    pub fn record(&mut self, ns: f64) {
        let idx = if ns <= 1.0 { 0 } else { (ns.log2() as usize).min(34) };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += ns;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << i) as f64;
            }
        }
        (1u64 << 34) as f64
    }
}

/// The registry.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn observe(&mut self, name: &str, ns: f64) {
        self.histograms.entry(name.to_string()).or_default().record(ns);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Prometheus-ish text dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "{k} {v}");
        }
        for (k, h) in &self.histograms {
            let _ = writeln!(
                out,
                "{k}_count {}  {k}_mean_ns {:.1}  {k}_p50_ns {:.0}  {k}_p99_ns {:.0}",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99)
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("jobs");
        m.add("jobs", 4);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(i as f64 * 100.0);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > 0.0);
    }

    #[test]
    fn render_contains_everything() {
        let mut m = Metrics::new();
        m.inc("a");
        m.observe("lat", 500.0);
        let r = m.render();
        assert!(r.contains("a 1"));
        assert!(r.contains("lat_count 1"));
    }
}
