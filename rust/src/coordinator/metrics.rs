//! Lightweight metrics registry: named counters and ns-scale histograms,
//! shared by the coordinator components. Latency distributions ride the
//! fixed-memory [`LogHistogram`] from [`crate::util::stats`] — the same
//! 416-bin (~±4%) geometry the traffic layer uses — plus a running sum
//! for exact means.

use crate::util::stats::LogHistogram;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A ns-scale latency histogram: log-binned counts for percentiles and an
/// exact running sum for the mean.
#[derive(Clone, Debug, Default)]
pub struct NsHist {
    hist: LogHistogram,
    sum: f64,
}

impl NsHist {
    #[inline]
    pub fn record(&mut self, ns: f64) {
        self.hist.push(ns);
        self.sum += ns;
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    pub fn mean(&self) -> f64 {
        if self.hist.count() == 0 {
            0.0
        } else {
            self.sum / self.hist.count() as f64
        }
    }

    /// Approximate quantile (geometric bin midpoint, ~±4%).
    pub fn quantile(&self, q: f64) -> f64 {
        self.hist.percentile(q)
    }
}

/// The registry.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, NsHist>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn observe(&mut self, name: &str, ns: f64) {
        self.histograms.entry(name.to_string()).or_default().record(ns);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&NsHist> {
        self.histograms.get(name)
    }

    /// Prometheus-ish text dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "{k} {v}");
        }
        for (k, h) in &self.histograms {
            let _ = writeln!(
                out,
                "{k}_count {}  {k}_mean_ns {:.1}  {k}_p50_ns {:.0}  {k}_p99_ns {:.0}",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99)
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("jobs");
        m.add("jobs", 4);
        assert_eq!(m.counter("jobs"), 5);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = NsHist::default();
        for i in 1..=1000u64 {
            h.record(i as f64 * 100.0);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > 0.0);
    }

    #[test]
    fn quantiles_land_near_samples() {
        // the 416-bin geometry resolves to ~±4%: a uniform ramp's median
        // must land within a bin width of the true value, which the old
        // 35-bucket power-of-two histogram could miss by 2x
        let mut h = NsHist::default();
        for i in 1..=1000u64 {
            h.record(i as f64 * 100.0);
        }
        let p50 = h.quantile(0.5);
        assert!(
            (p50 - 50_000.0).abs() / 50_000.0 < 0.10,
            "p50 {p50} too far from 50000"
        );
    }

    #[test]
    fn render_contains_everything() {
        let mut m = Metrics::new();
        m.inc("a");
        m.observe("lat", 500.0);
        let r = m.render();
        assert!(r.contains("a 1"));
        assert!(r.contains("lat_count 1"));
    }
}
