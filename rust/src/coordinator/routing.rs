//! The coordinator's multipath-routing authority: owns the fabric-wide
//! rail-selection configuration ([`RoutingPolicy`], per [`LinkTier`])
//! and applies it to simulators — the routing twin of
//! [`QosManager`](super::QosManager). The ROADMAP's "multi-rail /
//! adaptive routing under interference" item: the PBR table holds the
//! equal-cost candidates ([`crate::fabric::routing`] §Multipath), the
//! coordinator decides how transactions spread over them (deterministic
//! rail 0, ECMP hash-spray, or congestion-adaptive steering on the live
//! per-link QoS state), and the
//! [`StreamReport::qos`](crate::sim::StreamReport) telemetry closes the
//! loop.

use crate::sim::qos::LinkTier;
use crate::sim::rails::{RailSelector, RoutingPolicy};
use crate::sim::MemSim;

/// Owns and configures the per-tier rail-selection policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutingManager {
    policy: RoutingPolicy,
}

impl RoutingManager {
    pub fn new(policy: RoutingPolicy) -> RoutingManager {
        RoutingManager { policy }
    }

    /// The parity baseline: rail 0 on every tier (exactly the
    /// pre-multipath fabric, byte-identical paths and latencies).
    pub fn deterministic() -> RoutingManager {
        RoutingManager::new(RoutingPolicy::deterministic())
    }

    /// One selector across every tier.
    pub fn uniform(s: RailSelector) -> RoutingManager {
        RoutingManager::new(RoutingPolicy::uniform(s))
    }

    /// ECMP everywhere: deterministic per-transaction hash-spray over
    /// the equal-cost rails.
    pub fn spray() -> RoutingManager {
        RoutingManager::uniform(RailSelector::HashSpray)
    }

    /// Congestion-adaptive everywhere: steer each transaction onto its
    /// least-backlogged candidate path (live [`ClassedServer`] state;
    /// degrades to hash-spray on the sharded backend).
    ///
    /// [`ClassedServer`]: crate::sim::ClassedServer
    pub fn adaptive() -> RoutingManager {
        RoutingManager::uniform(RailSelector::Adaptive)
    }

    /// Override one tier's selector (e.g. spray over the contended CXL
    /// spine, deterministic inside the racks).
    pub fn set_tier(&mut self, tier: LinkTier, s: RailSelector) -> &mut RoutingManager {
        self.policy.set(tier, s);
        self
    }

    pub fn tier(&self, tier: LinkTier) -> RailSelector {
        self.policy.tier(tier)
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Push the configuration into a simulator (drops its path cache —
    /// call before running traffic). Meaningful on a multipath-enabled
    /// fabric ([`Fabric::enable_multipath`](crate::fabric::Fabric::enable_multipath));
    /// on a single-path fabric every selector degenerates to rail 0.
    pub fn apply(&self, sim: &mut MemSim) {
        sim.set_routing(self.policy);
    }

    /// Human-readable per-tier summary for CLI output and logs.
    pub fn describe(&self) -> String {
        LinkTier::ALL
            .iter()
            .map(|&t| format!("{}={}", t.name(), self.policy.tier(t).name()))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

impl Default for RoutingManager {
    fn default() -> RoutingManager {
        RoutingManager::deterministic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, Topology};

    #[test]
    fn per_tier_overrides_compose() {
        let mut m = RoutingManager::deterministic();
        m.set_tier(LinkTier::CxlSpine, RailSelector::HashSpray)
            .set_tier(LinkTier::CxlLeaf, RailSelector::Adaptive);
        assert_eq!(m.tier(LinkTier::Xlink).name(), "det");
        assert_eq!(m.tier(LinkTier::CxlSpine).name(), "spray");
        assert_eq!(m.tier(LinkTier::CxlLeaf).name(), "adaptive");
        let d = m.describe();
        assert!(d.contains("xlink=det") && d.contains("cxl-spine=spray"), "{d}");
    }

    #[test]
    fn apply_configures_the_simulator() {
        let t = Topology::single_hop(4, LinkKind::CxlCoherent, "c");
        let mut f = Fabric::new(t);
        f.enable_multipath(4);
        assert_eq!(f.max_rails(), 4);
        let mut sim = MemSim::new(&f);
        assert_eq!(sim.routing_policy(), RoutingPolicy::deterministic());
        let m = RoutingManager::spray();
        m.apply(&mut sim);
        assert_eq!(sim.routing_policy(), m.policy());
    }
}
