//! Tiering as fabric traffic: a [`TrafficSource`] that drives a
//! [`TieringEngine`] with a synthetic allocate/touch/free schedule and
//! replays the engine's migration log (spills, promotions, demotions) as
//! transactions over the real tier-1→tier-2 paths. Migration cost — and
//! the interference it inflicts on coherence and collective traffic —
//! emerges from link contention instead of being a free byte-counter
//! update.

use super::tiering::{MigrationRecord, TieringEngine};
use crate::fabric::NodeId;
use crate::sim::{Pull, SourcedTx, TrafficClass, TrafficSource, Transaction};
use crate::util::stats::Welford;
use crate::util::Rng;
use std::collections::VecDeque;

/// Workload knobs for [`TieringTraffic`].
#[derive(Clone, Copy, Debug)]
pub struct TieringTrafficConfig {
    /// Allocate/touch/free operations to issue.
    pub ops: u64,
    /// Mean op interarrival, ns (exponential).
    pub mean_interarrival_ns: f64,
    /// Fraction of ops that allocate a new object.
    pub alloc_frac: f64,
    /// Fraction of ops that free a live object (the rest touch).
    pub free_frac: f64,
    /// Object size range, bytes (log-uniform).
    pub min_bytes: f64,
    pub max_bytes: f64,
    /// Touches per touch-op (drives promotion heat).
    pub touch_burst: u64,
    /// Every `pressure_every` ops, demote the coldest tier-1 object if
    /// utilization sits above the relief threshold.
    pub pressure_every: u64,
    pub pressure_util: f64,
    /// Memory device service at the migration destination, ns.
    pub device_ns: f64,
}

impl Default for TieringTrafficConfig {
    fn default() -> Self {
        TieringTrafficConfig {
            ops: 2_000,
            mean_interarrival_ns: 2_000.0,
            alloc_frac: 0.45,
            free_frac: 0.15,
            min_bytes: 64.0 * 1024.0,
            max_bytes: 8.0 * 1024.0 * 1024.0,
            touch_burst: 4,
            pressure_every: 64,
            pressure_util: 0.8,
            device_ns: 130.0,
        }
    }
}

/// The tiering traffic source (see module docs).
pub struct TieringTraffic {
    engine: TieringEngine,
    /// Accelerators issuing allocations; a spill's payload source (and
    /// the fallback endpoint when a pool region has no node).
    agents: Vec<NodeId>,
    cfg: TieringTrafficConfig,
    rng: Rng,
    issued: u64,
    next_issue_at: f64,
    live: Vec<u64>,
    pending: VecDeque<(f64, Transaction)>,
    fabric_inflight: usize,
    migration_latency: Welford,
    migrated_bytes: f64,
}

impl TieringTraffic {
    /// `engine` should be freshly built over pools whose regions carry
    /// real fabric node ids; the migration log is enabled here.
    pub fn new(mut engine: TieringEngine, agents: Vec<NodeId>, cfg: TieringTrafficConfig, seed: u64) -> TieringTraffic {
        assert!(!agents.is_empty(), "need at least one issuing agent");
        engine.record_migrations(true);
        TieringTraffic {
            engine,
            agents,
            cfg,
            rng: Rng::new(seed),
            issued: 0,
            next_issue_at: 0.0,
            live: Vec::new(),
            pending: VecDeque::new(),
            fabric_inflight: 0,
            migration_latency: Welford::new(),
            migrated_bytes: 0.0,
        }
    }

    /// End-to-end migration transfer latency, ns.
    pub fn migration_latency(&self) -> &Welford {
        &self.migration_latency
    }

    pub fn migrated_bytes(&self) -> f64 {
        self.migrated_bytes
    }

    /// The engine (for stats and invariant checks after a run).
    pub fn engine(&self) -> &TieringEngine {
        &self.engine
    }

    fn log_uniform_bytes(&mut self) -> f64 {
        let lo = self.cfg.min_bytes.ln();
        let hi = self.cfg.max_bytes.ln();
        (lo + self.rng.f64() * (hi - lo)).exp()
    }

    /// Map a migration record onto a fabric transaction issued by
    /// `agent` at time `at`.
    fn stage(&mut self, rec: MigrationRecord, agent: NodeId, at: f64) {
        let src = rec.src.unwrap_or(agent);
        let dst = rec.dst.unwrap_or(agent);
        self.pending.push_back((
            at,
            Transaction { src, dst, at, bytes: rec.bytes, device_ns: self.cfg.device_ns },
        ));
    }

    /// Run one schedule op at time `t`; migrations it causes are staged.
    fn run_op(&mut self, t: f64) {
        let agent = self.agents[self.rng.below(self.agents.len() as u64) as usize];
        let r = self.rng.f64();
        if r < self.cfg.alloc_frac || self.live.is_empty() {
            let bytes = self.log_uniform_bytes();
            match self.engine.alloc(bytes) {
                Ok(id) => self.live.push(id),
                Err(_) => {
                    // full: retire the oldest live object and move on
                    if !self.live.is_empty() {
                        let id = self.live.remove(0);
                        let _ = self.engine.free(id);
                    }
                }
            }
        } else if r < self.cfg.alloc_frac + self.cfg.free_frac {
            let i = self.rng.below(self.live.len() as u64) as usize;
            let id = self.live.swap_remove(i);
            let _ = self.engine.free(id);
        } else {
            let i = self.rng.below(self.live.len() as u64) as usize;
            let id = self.live[i];
            for _ in 0..self.cfg.touch_burst {
                self.engine.touch(id);
            }
            // the deterministic promotion scan picks up other hot
            // spilled objects the touch path could not move yet
            self.engine.promote_ready(2);
        }
        if self.cfg.pressure_every > 0 && self.issued % self.cfg.pressure_every == 0 {
            let util = self.engine.tier1.used() / self.engine.tier1.capacity().max(1.0);
            if util > self.cfg.pressure_util {
                self.engine.demote_coldest();
            }
        }
        for rec in self.engine.take_migrations() {
            self.stage(rec, agent, t);
        }
    }
}

impl TrafficSource for TieringTraffic {
    fn class(&self) -> TrafficClass {
        TrafficClass::Tiering
    }

    fn pull(&mut self, now: f64) -> Pull {
        loop {
            if let Some((at, mut tx)) = self.pending.pop_front() {
                tx.at = at.max(now);
                self.fabric_inflight += 1;
                self.migrated_bytes += tx.bytes;
                // the issue time rides in the token so on_complete can
                // measure transfer latency without a side table
                return Pull::Tx(SourcedTx::new(tx, at.max(now).to_bits()));
            }
            if self.issued >= self.cfg.ops {
                // emissions never wait on completions (on_complete is
                // latency telemetry only), so the source is Done as soon
                // as the op budget drains — never Blocked, upholding the
                // open-loop contract below
                return Pull::Done;
            }
            // open loop: ops fire on the schedule regardless of fabric
            // state (migrations are asynchronous writebacks/fills)
            let t = self.next_issue_at;
            self.next_issue_at += self.rng.exp(1.0 / self.cfg.mean_interarrival_ns);
            self.issued += 1;
            self.run_op(t);
        }
    }

    fn on_complete(&mut self, token: u64, now: f64) {
        self.fabric_inflight -= 1;
        self.migration_latency.push(now - f64::from_bits(token));
    }

    /// Migrations are asynchronous writebacks/fills on a fixed schedule:
    /// emission never depends on a completion, so the source can be
    /// staged ahead by the sharded coordinator.
    fn open_loop(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiering::TieringPolicy;
    use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
    use crate::memory::pool::MemoryPool;
    use crate::memory::Tier;
    use crate::sim::MemSim;

    fn build(seed: u64, ops: u64) -> (Fabric, TieringTraffic) {
        let t = Topology::single_hop(8, LinkKind::CxlCoherent, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        let fabric = Fabric::new(t);
        // tier-1 = HBM carve-outs on the first 6 accelerators, tier-2 =
        // the last two endpoints standing in as memory nodes
        let mut t1 = MemoryPool::new();
        for &a in &accs[..6] {
            t1.add_region(a, Tier::Tier1Local, 32.0 * 1024.0 * 1024.0);
        }
        let mut t2 = MemoryPool::new();
        for &m in &accs[6..] {
            t2.add_region(m, Tier::Tier2Pool, 4096.0 * 1024.0 * 1024.0);
        }
        let engine = TieringEngine::new(t1, t2, TieringPolicy::default());
        let cfg = TieringTrafficConfig { ops, ..Default::default() };
        let src = TieringTraffic::new(engine, accs[..6].to_vec(), cfg, seed);
        (fabric, src)
    }

    #[test]
    fn migrations_flow_and_invariants_hold() {
        let (fabric, mut src) = build(5, 1500);
        let mut sim = MemSim::new(&fabric);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed(&mut sources)
        };
        let stats = src.engine().stats();
        assert!(stats.tier2_spills > 0, "workload must overflow tier-1");
        assert_eq!(
            rep.class(TrafficClass::Tiering).completed,
            rep.total.completed,
            "all traffic is tiering-class"
        );
        // every spill/promotion/demotion produced exactly one transfer
        // (rejected allocations count as spills but move no bytes)
        assert_eq!(
            rep.total.completed,
            stats.tier2_spills - stats.rejected + stats.promotions + stats.demotions,
        );
        assert!((src.migrated_bytes() - rep.class(TrafficClass::Tiering).bytes).abs() < 1e-6);
        assert_eq!(src.migration_latency().count(), rep.total.completed);
        src.engine().check_invariants().unwrap();
    }

    #[test]
    fn deterministic_across_runs() {
        let (fa, mut a) = build(9, 800);
        let (fb, mut b) = build(9, 800);
        let ra = {
            let mut sa: [&mut dyn TrafficSource; 1] = [&mut a];
            MemSim::new(&fa).run_streamed(&mut sa)
        };
        let rb = {
            let mut sb: [&mut dyn TrafficSource; 1] = [&mut b];
            MemSim::new(&fb).run_streamed(&mut sb)
        };
        assert_eq!(ra.total.completed, rb.total.completed);
        assert!((ra.total.makespan_ns - rb.total.makespan_ns).abs() < 1e-12);
        assert_eq!(a.engine().stats(), b.engine().stats());
    }
}
