//! Runtime memory tiering over the composable pools: allocations land in
//! tier-1 while it has headroom and spill to tier-2; hot spilled objects
//! are promoted back when tier-1 frees up (§5's operational story).
//!
//! Every tier crossing (spill, promotion, demotion) is a real data
//! movement over the tier-1→tier-2 fabric paths. With
//! [`record_migrations`](TieringEngine::record_migrations) enabled the
//! engine logs each one as a [`MigrationRecord`] with the region nodes
//! involved; [`TieringTraffic`](super::TieringTraffic) replays the log as
//! fabric transactions so migration cost emerges from link contention.
//!
//! Objects live in a `BTreeMap` keyed by object id: every scan
//! (promotion, coldest-victim selection) walks in ascending `obj_id`
//! order, so which objects land in tier-1 is identical run to run — a
//! `HashMap` walk here made promotion order, and therefore placement,
//! nondeterministic.

use crate::fabric::NodeId;
use crate::memory::pool::{AllocId, Allocation, MemoryPool, Placement, PoolError};
use crate::memory::Tier;
use std::collections::BTreeMap;

/// Tiering statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TieringStats {
    pub allocs: u64,
    pub tier1_allocs: u64,
    pub tier2_spills: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub rejected: u64,
}

/// Why bytes crossed a tier boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationKind {
    /// New allocation placed in tier-2 because tier-1 lacked headroom.
    Spill,
    /// Hot tier-2 object moved up to tier-1.
    Promotion,
    /// Cold tier-1 object pushed down to tier-2.
    Demotion,
}

/// One logged tier crossing. `src`/`dst` are the fabric nodes of the
/// first extent's region on each side; `None` when the movement
/// originates outside the pools (a spill's payload comes from the
/// allocating agent, which the pools cannot know — the traffic source
/// fills it in).
#[derive(Clone, Copy, Debug)]
pub struct MigrationRecord {
    pub kind: MigrationKind,
    pub obj: u64,
    pub bytes: f64,
    pub src: Option<NodeId>,
    pub dst: Option<NodeId>,
}

/// Where one object currently lives.
#[derive(Clone, Debug)]
struct Object {
    bytes: f64,
    tier: Tier,
    alloc: AllocId,
    /// touch counter since last decay (hotness proxy)
    heat: u64,
}

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct TieringPolicy {
    /// Keep tier-1 utilization below this watermark when placing new
    /// objects (leave room for bursts).
    pub t1_high_watermark: f64,
    /// Promote a tier-2 object when its heat exceeds this.
    pub promote_heat: u64,
}

impl Default for TieringPolicy {
    fn default() -> Self {
        TieringPolicy { t1_high_watermark: 0.9, promote_heat: 8 }
    }
}

/// Fabric node of an allocation's first extent.
fn primary_node(pool: &MemoryPool, alloc: &Allocation) -> Option<NodeId> {
    alloc.extents.first().map(|&(r, _)| pool.regions()[r].node)
}

/// The tiering engine over two pools.
pub struct TieringEngine {
    pub tier1: MemoryPool,
    pub tier2: MemoryPool,
    policy: TieringPolicy,
    objects: BTreeMap<u64, Object>,
    next_obj: u64,
    stats: TieringStats,
    record: bool,
    migrations: Vec<MigrationRecord>,
}

impl TieringEngine {
    pub fn new(tier1: MemoryPool, tier2: MemoryPool, policy: TieringPolicy) -> Self {
        TieringEngine {
            tier1,
            tier2,
            policy,
            objects: BTreeMap::new(),
            next_obj: 0,
            stats: TieringStats::default(),
            record: false,
            migrations: Vec::new(),
        }
    }

    pub fn stats(&self) -> TieringStats {
        self.stats
    }

    /// Enable/disable the migration log (off by default: callers that
    /// never drain it must not accumulate records).
    pub fn record_migrations(&mut self, on: bool) {
        self.record = on;
    }

    /// Drain the migration log (records since the last call).
    pub fn take_migrations(&mut self) -> Vec<MigrationRecord> {
        std::mem::take(&mut self.migrations)
    }

    fn log(&mut self, kind: MigrationKind, obj: u64, bytes: f64, src: Option<NodeId>, dst: Option<NodeId>) {
        if self.record {
            self.migrations.push(MigrationRecord { kind, obj, bytes, src, dst });
        }
    }

    fn t1_util_after(&self, bytes: f64) -> f64 {
        (self.tier1.used() + bytes) / self.tier1.capacity().max(1.0)
    }

    /// Allocate an object; returns its handle or an error if neither tier
    /// can hold it.
    pub fn alloc(&mut self, bytes: f64) -> Result<u64, PoolError> {
        self.stats.allocs += 1;
        let (tier, alloc) = if self.t1_util_after(bytes) <= self.policy.t1_high_watermark {
            match self.tier1.alloc(bytes, Placement::FirstFit) {
                Ok(a) => {
                    self.stats.tier1_allocs += 1;
                    (Tier::Tier1Local, a)
                }
                Err(_) => {
                    self.stats.tier2_spills += 1;
                    match self.tier2.alloc(bytes, Placement::WorstFit) {
                        Ok(a) => (Tier::Tier2Pool, a),
                        Err(e) => {
                            self.stats.rejected += 1;
                            return Err(e);
                        }
                    }
                }
            }
        } else {
            self.stats.tier2_spills += 1;
            match self.tier2.alloc(bytes, Placement::WorstFit) {
                Ok(a) => (Tier::Tier2Pool, a),
                Err(e) => {
                    self.stats.rejected += 1;
                    return Err(e);
                }
            }
        };
        let id = self.next_obj;
        self.next_obj += 1;
        if tier == Tier::Tier2Pool {
            let dst = primary_node(&self.tier2, &alloc);
            self.log(MigrationKind::Spill, id, bytes, None, dst);
        }
        self.objects.insert(id, Object { bytes, tier, alloc: alloc.id, heat: 0 });
        Ok(id)
    }

    /// Try to move object `id` (must be tier-2) up into tier-1; true on
    /// success. Respects the watermark.
    fn try_promote(&mut self, id: u64) -> bool {
        let Some(o) = self.objects.get(&id) else { return false };
        if o.tier != Tier::Tier2Pool {
            return false;
        }
        let (bytes, old) = (o.bytes, o.alloc);
        if self.t1_util_after(bytes) > self.policy.t1_high_watermark {
            return false;
        }
        let Ok(a1) = self.tier1.alloc(bytes, Placement::FirstFit) else { return false };
        let src = self.tier2.get(old).and_then(|al| primary_node(&self.tier2, al));
        let dst = primary_node(&self.tier1, &a1);
        let o = self.objects.get_mut(&id).unwrap();
        o.alloc = a1.id;
        o.tier = Tier::Tier1Local;
        o.heat = 0;
        self.tier2.free(old).expect("tier2 free");
        self.stats.promotions += 1;
        self.log(MigrationKind::Promotion, id, bytes, src, dst);
        true
    }

    /// Record an access to an object; may trigger promotion.
    pub fn touch(&mut self, id: u64) -> Option<Tier> {
        // split borrow: decide first, mutate after
        let needs_promote = {
            let o = self.objects.get_mut(&id)?;
            o.heat += 1;
            o.tier == Tier::Tier2Pool && o.heat >= self.policy.promote_heat
        };
        if needs_promote {
            self.try_promote(id);
        }
        self.objects.get(&id).map(|o| o.tier)
    }

    /// Promotion scan: walk tier-2 objects in ascending `obj_id` order
    /// (deterministic — see module docs) and promote every one whose
    /// heat crossed the threshold, while tier-1 headroom lasts. Returns
    /// the promoted ids, at most `limit`.
    pub fn promote_ready(&mut self, limit: usize) -> Vec<u64> {
        let candidates: Vec<u64> = self
            .objects
            .iter()
            .filter(|(_, o)| o.tier == Tier::Tier2Pool && o.heat >= self.policy.promote_heat)
            .map(|(&id, _)| id)
            .collect();
        let mut promoted = Vec::new();
        for id in candidates {
            if promoted.len() >= limit {
                break;
            }
            if self.try_promote(id) {
                promoted.push(id);
            }
        }
        promoted
    }

    /// Demote the coldest tier-1 object to tier-2 (called under
    /// pressure). Heat ties resolve to the smallest `obj_id`
    /// (deterministic: the `BTreeMap` walk is id-ordered and `min_by_key`
    /// keeps the first minimum).
    pub fn demote_coldest(&mut self) -> Option<u64> {
        let (&id, _) = self
            .objects
            .iter()
            .filter(|(_, o)| o.tier == Tier::Tier1Local)
            .min_by_key(|(_, o)| o.heat)?;
        let bytes = self.objects[&id].bytes;
        let a2 = self.tier2.alloc(bytes, Placement::WorstFit).ok()?;
        let old = self.objects[&id].alloc;
        let src = self.tier1.get(old).and_then(|al| primary_node(&self.tier1, al));
        let dst = primary_node(&self.tier2, &a2);
        let o = self.objects.get_mut(&id).unwrap();
        o.alloc = a2.id;
        o.tier = Tier::Tier2Pool;
        self.tier1.free(old).expect("tier1 free");
        self.stats.demotions += 1;
        self.log(MigrationKind::Demotion, id, bytes, src, dst);
        Some(id)
    }

    /// Free an object.
    pub fn free(&mut self, id: u64) -> Result<(), PoolError> {
        let o = self.objects.remove(&id).ok_or(PoolError::UnknownAlloc)?;
        match o.tier {
            Tier::Tier2Pool => self.tier2.free(o.alloc),
            _ => self.tier1.free(o.alloc),
        }
    }

    pub fn tier_of(&self, id: u64) -> Option<Tier> {
        self.objects.get(&id).map(|o| o.tier)
    }

    /// Live object ids, ascending.
    pub fn object_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.objects.keys().copied()
    }

    /// Cross-pool invariants: per-pool extent accounting plus byte
    /// conservation — the sum of each pool's `used` equals the live
    /// objects mapped to it, after any op sequence.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.tier1.check_invariants()?;
        self.tier2.check_invariants()?;
        let sum_tier = |t2: bool| -> f64 {
            self.objects
                .values()
                .filter(|o| (o.tier == Tier::Tier2Pool) == t2)
                .map(|o| o.bytes)
                .sum()
        };
        let t1 = sum_tier(false);
        let tol1 = 1e-6f64.max(1e-12 * self.tier1.used().abs());
        if (t1 - self.tier1.used()).abs() > tol1 {
            return Err(format!("tier1 accounting: objects {t1} vs pool {}", self.tier1.used()));
        }
        let t2 = sum_tier(true);
        let tol2 = 1e-6f64.max(1e-12 * self.tier2.used().abs());
        if (t2 - self.tier2.used()).abs() > tol2 {
            return Err(format!("tier2 accounting: objects {t2} vs pool {}", self.tier2.used()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(t1_cap: f64, t2_cap: f64) -> TieringEngine {
        let mut t1 = MemoryPool::new();
        t1.add_region(0, Tier::Tier1Local, t1_cap);
        let mut t2 = MemoryPool::new();
        t2.add_region(100, Tier::Tier2Pool, t2_cap);
        TieringEngine::new(t1, t2, TieringPolicy::default())
    }

    #[test]
    fn allocates_tier1_first() {
        let mut e = engine(100.0, 1000.0);
        let id = e.alloc(50.0).unwrap();
        assert_eq!(e.tier_of(id), Some(Tier::Tier1Local));
        e.check_invariants().unwrap();
    }

    #[test]
    fn spills_beyond_watermark() {
        let mut e = engine(100.0, 1000.0);
        let _a = e.alloc(85.0).unwrap();
        let b = e.alloc(20.0).unwrap(); // 105% > 90% watermark
        assert_eq!(e.tier_of(b), Some(Tier::Tier2Pool));
        assert_eq!(e.stats().tier2_spills, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn hot_object_promoted() {
        let mut e = engine(100.0, 1000.0);
        let a = e.alloc(85.0).unwrap();
        let b = e.alloc(20.0).unwrap();
        assert_eq!(e.tier_of(b), Some(Tier::Tier2Pool));
        e.free(a).unwrap(); // tier-1 frees up
        for _ in 0..8 {
            e.touch(b);
        }
        assert_eq!(e.tier_of(b), Some(Tier::Tier1Local));
        assert_eq!(e.stats().promotions, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn demote_coldest_picks_least_touched() {
        let mut e = engine(100.0, 1000.0);
        let hot = e.alloc(40.0).unwrap();
        let cold = e.alloc(40.0).unwrap();
        for _ in 0..5 {
            e.touch(hot);
        }
        let demoted = e.demote_coldest().unwrap();
        assert_eq!(demoted, cold);
        assert_eq!(e.tier_of(cold), Some(Tier::Tier2Pool));
        assert_eq!(e.tier_of(hot), Some(Tier::Tier1Local));
        e.check_invariants().unwrap();
    }

    #[test]
    fn demote_ties_resolve_to_smallest_id() {
        let mut e = engine(100.0, 1000.0);
        let first = e.alloc(30.0).unwrap();
        let _second = e.alloc(30.0).unwrap();
        let _third = e.alloc(30.0).unwrap();
        // all heat 0: the id-ordered scan must pick the first object
        assert_eq!(e.demote_coldest(), Some(first));
    }

    #[test]
    fn rejects_when_everything_full() {
        let mut e = engine(10.0, 10.0);
        assert!(e.alloc(8.0).is_ok());
        assert!(e.alloc(8.0).is_ok()); // spills
        assert!(e.alloc(8.0).is_err());
        assert_eq!(e.stats().rejected, 1);
    }

    #[test]
    fn free_unknown_rejected() {
        let mut e = engine(10.0, 10.0);
        assert!(e.free(99).is_err());
    }

    #[test]
    fn migration_log_records_tier_crossings() {
        let mut e = engine(100.0, 1000.0);
        e.record_migrations(true);
        let a = e.alloc(85.0).unwrap();
        let b = e.alloc(20.0).unwrap(); // spill
        e.free(a).unwrap();
        for _ in 0..8 {
            e.touch(b); // promotion
        }
        let _c = e.alloc(60.0).unwrap(); // fits tier-1 (80% < watermark)
        e.demote_coldest().unwrap(); // demotion
        let log = e.take_migrations();
        let kinds: Vec<MigrationKind> = log.iter().map(|m| m.kind).collect();
        assert_eq!(kinds, vec![MigrationKind::Spill, MigrationKind::Promotion, MigrationKind::Demotion]);
        // spill destination and promotion source are tier-2's node
        assert_eq!(log[0].dst, Some(100));
        assert_eq!(log[0].src, None, "spill payload comes from the agent");
        assert_eq!(log[1].src, Some(100));
        assert_eq!(log[1].dst, Some(0));
        assert!(e.take_migrations().is_empty(), "drained");
        e.check_invariants().unwrap();
    }

    #[test]
    fn promotion_scan_is_id_ordered_and_bounded() {
        let mut e = engine(100.0, 1000.0);
        let blocker = e.alloc(85.0).unwrap();
        // three spilled objects, all hot
        let ids: Vec<u64> = (0..3).map(|_| e.alloc(30.0).unwrap()).collect();
        for &id in &ids {
            for _ in 0..20 {
                e.touch(id);
            }
        }
        assert!(ids.iter().all(|&i| e.tier_of(i) == Some(Tier::Tier2Pool)));
        e.free(blocker).unwrap();
        let promoted = e.promote_ready(2);
        // id order, respecting the limit; the third stays in tier-2
        assert_eq!(promoted, vec![ids[0], ids[1]]);
        assert_eq!(e.tier_of(ids[2]), Some(Tier::Tier2Pool));
        e.check_invariants().unwrap();
    }

    #[test]
    fn log_disabled_by_default() {
        let mut e = engine(10.0, 1000.0);
        let _ = e.alloc(50.0).unwrap(); // spill, unrecorded
        assert!(e.take_migrations().is_empty());
    }
}
