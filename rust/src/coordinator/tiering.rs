//! Runtime memory tiering over the composable pools: allocations land in
//! tier-1 while it has headroom and spill to tier-2; hot spilled objects
//! are promoted back when tier-1 frees up (§5's operational story).

use crate::memory::pool::{AllocId, MemoryPool, Placement, PoolError};
use crate::memory::Tier;
use std::collections::HashMap;

/// Tiering statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TieringStats {
    pub allocs: u64,
    pub tier1_allocs: u64,
    pub tier2_spills: u64,
    pub promotions: u64,
    pub demotions: u64,
    pub rejected: u64,
}

/// Where one object currently lives.
#[derive(Clone, Debug)]
struct Object {
    bytes: f64,
    tier: Tier,
    alloc: AllocId,
    /// touch counter since last decay (hotness proxy)
    heat: u64,
}

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct TieringPolicy {
    /// Keep tier-1 utilization below this watermark when placing new
    /// objects (leave room for bursts).
    pub t1_high_watermark: f64,
    /// Promote a tier-2 object when its heat exceeds this.
    pub promote_heat: u64,
}

impl Default for TieringPolicy {
    fn default() -> Self {
        TieringPolicy { t1_high_watermark: 0.9, promote_heat: 8 }
    }
}

/// The tiering engine over two pools.
pub struct TieringEngine {
    pub tier1: MemoryPool,
    pub tier2: MemoryPool,
    policy: TieringPolicy,
    objects: HashMap<u64, Object>,
    next_obj: u64,
    stats: TieringStats,
}

impl TieringEngine {
    pub fn new(tier1: MemoryPool, tier2: MemoryPool, policy: TieringPolicy) -> Self {
        TieringEngine { tier1, tier2, policy, objects: HashMap::new(), next_obj: 0, stats: TieringStats::default() }
    }

    pub fn stats(&self) -> TieringStats {
        self.stats
    }

    fn t1_util_after(&self, bytes: f64) -> f64 {
        (self.tier1.used() + bytes) / self.tier1.capacity().max(1.0)
    }

    /// Allocate an object; returns its handle or an error if neither tier
    /// can hold it.
    pub fn alloc(&mut self, bytes: f64) -> Result<u64, PoolError> {
        self.stats.allocs += 1;
        let (tier, alloc) = if self.t1_util_after(bytes) <= self.policy.t1_high_watermark {
            match self.tier1.alloc(bytes, Placement::FirstFit) {
                Ok(a) => {
                    self.stats.tier1_allocs += 1;
                    (Tier::Tier1Local, a)
                }
                Err(_) => {
                    self.stats.tier2_spills += 1;
                    (Tier::Tier2Pool, self.tier2.alloc(bytes, Placement::WorstFit).inspect_err(|_| {}).map_err(|e| {
                        self.stats.rejected += 1;
                        e
                    })?)
                }
            }
        } else {
            self.stats.tier2_spills += 1;
            match self.tier2.alloc(bytes, Placement::WorstFit) {
                Ok(a) => (Tier::Tier2Pool, a),
                Err(e) => {
                    self.stats.rejected += 1;
                    return Err(e);
                }
            }
        };
        let id = self.next_obj;
        self.next_obj += 1;
        self.objects.insert(id, Object { bytes, tier, alloc: alloc.id, heat: 0 });
        Ok(id)
    }

    /// Record an access to an object; may trigger promotion.
    pub fn touch(&mut self, id: u64) -> Option<Tier> {
        // split borrow: decide first, mutate after
        let (needs_promote, bytes) = {
            let o = self.objects.get_mut(&id)?;
            o.heat += 1;
            (o.tier == Tier::Tier2Pool && o.heat >= self.policy.promote_heat, o.bytes)
        };
        if needs_promote && self.t1_util_after(bytes) <= self.policy.t1_high_watermark {
            if let Ok(a1) = self.tier1.alloc(bytes, Placement::FirstFit) {
                let o = self.objects.get_mut(&id).unwrap();
                let old = o.alloc;
                o.alloc = a1.id;
                o.tier = Tier::Tier1Local;
                o.heat = 0;
                self.tier2.free(old).expect("tier2 free");
                self.stats.promotions += 1;
            }
        }
        self.objects.get(&id).map(|o| o.tier)
    }

    /// Demote the coldest tier-1 object to tier-2 (called under pressure).
    pub fn demote_coldest(&mut self) -> Option<u64> {
        let (&id, _) = self
            .objects
            .iter()
            .filter(|(_, o)| o.tier == Tier::Tier1Local)
            .min_by_key(|(_, o)| o.heat)?;
        let bytes = self.objects[&id].bytes;
        let a2 = self.tier2.alloc(bytes, Placement::WorstFit).ok()?;
        let o = self.objects.get_mut(&id).unwrap();
        let old = o.alloc;
        o.alloc = a2.id;
        o.tier = Tier::Tier2Pool;
        self.tier1.free(old).expect("tier1 free");
        self.stats.demotions += 1;
        Some(id)
    }

    /// Free an object.
    pub fn free(&mut self, id: u64) -> Result<(), PoolError> {
        let o = self.objects.remove(&id).ok_or(PoolError::UnknownAlloc)?;
        match o.tier {
            Tier::Tier2Pool => self.tier2.free(o.alloc),
            _ => self.tier1.free(o.alloc),
        }
    }

    pub fn tier_of(&self, id: u64) -> Option<Tier> {
        self.objects.get(&id).map(|o| o.tier)
    }

    /// Cross-pool invariants.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.tier1.check_invariants()?;
        self.tier2.check_invariants()?;
        let t1: f64 = self
            .objects
            .values()
            .filter(|o| o.tier != Tier::Tier2Pool)
            .map(|o| o.bytes)
            .sum();
        let tol = 1e-6f64.max(1e-12 * self.tier1.used().abs());
        if (t1 - self.tier1.used()).abs() > tol {
            return Err(format!("tier1 accounting: objects {t1} vs pool {}", self.tier1.used()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(t1_cap: f64, t2_cap: f64) -> TieringEngine {
        let mut t1 = MemoryPool::new();
        t1.add_region(0, Tier::Tier1Local, t1_cap);
        let mut t2 = MemoryPool::new();
        t2.add_region(100, Tier::Tier2Pool, t2_cap);
        TieringEngine::new(t1, t2, TieringPolicy::default())
    }

    #[test]
    fn allocates_tier1_first() {
        let mut e = engine(100.0, 1000.0);
        let id = e.alloc(50.0).unwrap();
        assert_eq!(e.tier_of(id), Some(Tier::Tier1Local));
        e.check_invariants().unwrap();
    }

    #[test]
    fn spills_beyond_watermark() {
        let mut e = engine(100.0, 1000.0);
        let _a = e.alloc(85.0).unwrap();
        let b = e.alloc(20.0).unwrap(); // 105% > 90% watermark
        assert_eq!(e.tier_of(b), Some(Tier::Tier2Pool));
        assert_eq!(e.stats().tier2_spills, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn hot_object_promoted() {
        let mut e = engine(100.0, 1000.0);
        let a = e.alloc(85.0).unwrap();
        let b = e.alloc(20.0).unwrap();
        assert_eq!(e.tier_of(b), Some(Tier::Tier2Pool));
        e.free(a).unwrap(); // tier-1 frees up
        for _ in 0..8 {
            e.touch(b);
        }
        assert_eq!(e.tier_of(b), Some(Tier::Tier1Local));
        assert_eq!(e.stats().promotions, 1);
        e.check_invariants().unwrap();
    }

    #[test]
    fn demote_coldest_picks_least_touched() {
        let mut e = engine(100.0, 1000.0);
        let hot = e.alloc(40.0).unwrap();
        let cold = e.alloc(40.0).unwrap();
        for _ in 0..5 {
            e.touch(hot);
        }
        let demoted = e.demote_coldest().unwrap();
        assert_eq!(demoted, cold);
        assert_eq!(e.tier_of(cold), Some(Tier::Tier2Pool));
        assert_eq!(e.tier_of(hot), Some(Tier::Tier1Local));
        e.check_invariants().unwrap();
    }

    #[test]
    fn rejects_when_everything_full() {
        let mut e = engine(10.0, 10.0);
        assert!(e.alloc(8.0).is_ok());
        assert!(e.alloc(8.0).is_ok()); // spills
        assert!(e.alloc(8.0).is_err());
        assert_eq!(e.stats().rejected, 1);
    }

    #[test]
    fn free_unknown_rejected() {
        let mut e = engine(10.0, 10.0);
        assert!(e.free(99).is_err());
    }
}
