//! The coordinator's QoS authority: owns the fabric-wide arbitration
//! configuration ([`QosPolicy`], per [`LinkTier`]) and applies it to
//! simulators. The ROADMAP's "cross-class scheduling policies in the
//! coordinator" item: the coordinator decides how coherence, migration
//! and collective traffic share links, the [`ClassedServer`]s in the
//! simulation hot path enforce it, and the per-class telemetry in
//! [`StreamReport::qos`](crate::sim::StreamReport) closes the loop.

use crate::sim::qos::{ArbPolicy, LinkTier, QosPolicy};
use crate::sim::{MemSim, TrafficClass};

/// Owns and configures the per-tier arbitration policy.
#[derive(Clone, Debug, PartialEq)]
pub struct QosManager {
    policy: QosPolicy,
}

impl QosManager {
    pub fn new(policy: QosPolicy) -> QosManager {
        QosManager { policy }
    }

    /// The parity baseline: class-blind FCFS on every tier (exactly the
    /// pre-QoS fabric).
    pub fn fcfs() -> QosManager {
        QosManager::new(QosPolicy::fcfs())
    }

    /// One policy across every tier.
    pub fn uniform(p: ArbPolicy) -> QosManager {
        QosManager::new(QosPolicy::uniform(p))
    }

    /// Strict priority everywhere, with the given class order (highest
    /// first; must name every class once).
    pub fn strict_priority(order: [TrafficClass; 4]) -> QosManager {
        QosManager::uniform(ArbPolicy::StrictPriority(order))
    }

    /// Weighted-fair (deficit round-robin) everywhere, with per-class
    /// byte-share weights indexed by [`TrafficClass::index`].
    pub fn weighted_fair(weights: [f64; 4]) -> QosManager {
        QosManager::uniform(ArbPolicy::WeightedFair(weights))
    }

    /// Override one tier's policy (e.g. strict priority on the contended
    /// CXL spine, FCFS inside the racks).
    pub fn set_tier(&mut self, tier: LinkTier, p: ArbPolicy) -> &mut QosManager {
        self.policy.set(tier, p);
        self
    }

    pub fn tier(&self, tier: LinkTier) -> ArbPolicy {
        self.policy.tier(tier)
    }

    pub fn policy(&self) -> QosPolicy {
        self.policy
    }

    /// Push the configuration into a simulator (fresh [`ClassedServer`]s
    /// per link direction — call before running traffic).
    ///
    /// [`ClassedServer`]: crate::sim::ClassedServer
    pub fn apply(&self, sim: &mut MemSim) {
        sim.set_qos(self.policy);
    }

    /// Human-readable per-tier summary for CLI output and logs.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        for t in LinkTier::ALL {
            let p = self.policy.tier(t);
            let detail = match p {
                ArbPolicy::FcfsShared => String::new(),
                ArbPolicy::StrictPriority(order) => {
                    let names: Vec<&str> = order.iter().map(|c| c.name()).collect();
                    format!("({})", names.join(">"))
                }
                ArbPolicy::WeightedFair(w) => {
                    format!("({}:{}:{}:{})", w[0], w[1], w[2], w[3])
                }
            };
            parts.push(format!("{}={}{detail}", t.name(), p.name()));
        }
        parts.join(" ")
    }
}

impl Default for QosManager {
    fn default() -> QosManager {
        QosManager::fcfs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, Topology};

    #[test]
    fn per_tier_overrides_compose() {
        let mut m = QosManager::fcfs();
        m.set_tier(LinkTier::CxlSpine, ArbPolicy::strict_default())
            .set_tier(LinkTier::CxlLeaf, ArbPolicy::weighted_default());
        assert_eq!(m.tier(LinkTier::Xlink).name(), "fcfs");
        assert_eq!(m.tier(LinkTier::CxlSpine).name(), "strict");
        assert_eq!(m.tier(LinkTier::CxlLeaf).name(), "wfq");
        let d = m.describe();
        assert!(d.contains("xlink=fcfs") && d.contains("cxl-spine=strict"), "{d}");
    }

    #[test]
    fn apply_configures_the_simulator() {
        let t = Topology::single_hop(4, LinkKind::CxlCoherent, "c");
        let f = Fabric::new(t);
        let mut sim = MemSim::new(&f);
        assert_eq!(sim.qos_policy(), QosPolicy::fcfs());
        let m = QosManager::strict_priority([
            TrafficClass::Coherence,
            TrafficClass::Tiering,
            TrafficClass::Collective,
            TrafficClass::Generic,
        ]);
        m.apply(&mut sim);
        assert_eq!(sim.qos_policy(), m.policy());
        // single-hop CXL rack: every link is a leaf link, now strict
        assert_eq!(sim.link_tier(0), LinkTier::CxlLeaf);
    }
}
