//! Job admission and resource allocation: accelerators are granted in
//! whole XLink-domain chunks (gang scheduling inside racks), memory is
//! composed from the tier pools, and the manager enforces the
//! interoperability rules (a job's TP group never spans rack kinds).

use crate::cluster::ScalePoolSystem;
use crate::coordinator::metrics::Metrics;
use std::collections::HashMap;

/// Job identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobId(pub u64);

/// A resource request.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    /// Accelerators required.
    pub accelerators: usize,
    /// Tier-2 pool bytes required (0 = none).
    pub pool_bytes: f64,
}

/// An admitted job's grant.
#[derive(Clone, Debug)]
pub struct Grant {
    pub job: JobId,
    /// (rack index, accelerator indices within the rack).
    pub accelerators: Vec<(usize, Vec<usize>)>,
    pub pool_bytes: f64,
}

#[derive(Debug, PartialEq)]
pub enum AdmitError {
    Accelerators { requested: usize, free: usize },
    Pool { requested: f64, free: f64 },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Accelerators { requested, free } => {
                write!(f, "not enough accelerators: requested {requested}, {free} free")
            }
            AdmitError::Pool { requested, free } => {
                write!(f, "not enough tier-2 pool: requested {requested:.2e} B, {free:.2e} free")
            }
        }
    }
}

impl std::error::Error for AdmitError {}

/// The allocation manager.
pub struct ScalePoolManager<'s> {
    sys: &'s ScalePoolSystem,
    /// free accelerator indices per rack
    free: Vec<Vec<usize>>,
    pool_free: f64,
    grants: HashMap<JobId, Grant>,
    next: u64,
    pub metrics: Metrics,
}

impl<'s> ScalePoolManager<'s> {
    pub fn new(sys: &'s ScalePoolSystem) -> Self {
        let free = sys.racks.iter().map(|r| (0..r.acc_ids.len()).collect()).collect();
        ScalePoolManager {
            sys,
            free,
            pool_free: sys.tier2_capacity(),
            grants: HashMap::new(),
            next: 0,
            metrics: Metrics::new(),
        }
    }

    pub fn free_accelerators(&self) -> usize {
        self.free.iter().map(Vec::len).sum()
    }

    pub fn free_pool_bytes(&self) -> f64 {
        self.pool_free
    }

    /// Admit a job: rack-major packing (fill one rack before the next) to
    /// keep TP/PP groups XLink-local, as §4 prescribes.
    pub fn admit(&mut self, spec: &JobSpec) -> Result<Grant, AdmitError> {
        let free = self.free_accelerators();
        if spec.accelerators > free {
            self.metrics.inc("admit_rejected_accels");
            return Err(AdmitError::Accelerators { requested: spec.accelerators, free });
        }
        if spec.pool_bytes > self.pool_free {
            self.metrics.inc("admit_rejected_pool");
            return Err(AdmitError::Pool { requested: spec.pool_bytes, free: self.pool_free });
        }
        let mut need = spec.accelerators;
        let mut accelerators = Vec::new();
        for (rack, free) in self.free.iter_mut().enumerate() {
            if need == 0 {
                break;
            }
            let take = need.min(free.len());
            if take > 0 {
                let granted: Vec<usize> = free.drain(..take).collect();
                accelerators.push((rack, granted));
                need -= take;
            }
        }
        debug_assert_eq!(need, 0);
        self.pool_free -= spec.pool_bytes;
        let job = JobId(self.next);
        self.next += 1;
        let grant = Grant { job, accelerators, pool_bytes: spec.pool_bytes };
        self.grants.insert(job, grant.clone());
        self.metrics.inc("jobs_admitted");
        self.metrics.add("accels_granted", spec.accelerators as u64);
        Ok(grant)
    }

    /// Release a job's resources.
    pub fn release(&mut self, job: JobId) -> bool {
        if let Some(g) = self.grants.remove(&job) {
            for (rack, accs) in g.accelerators {
                self.free[rack].extend(accs);
                self.free[rack].sort_unstable();
            }
            self.pool_free += g.pool_bytes;
            self.metrics.inc("jobs_released");
            true
        } else {
            false
        }
    }

    /// How many racks a job's grant spans (locality metric).
    pub fn span(&self, job: JobId) -> Option<usize> {
        self.grants.get(&job).map(|g| g.accelerators.len())
    }

    /// Conservation invariant: free + granted == total, per rack.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, rack) in self.sys.racks.iter().enumerate() {
            let granted: usize = self
                .grants
                .values()
                .flat_map(|g| &g.accelerators)
                .filter(|(r, _)| *r == i)
                .map(|(_, a)| a.len())
                .sum();
            let total = rack.acc_ids.len();
            if self.free[i].len() + granted != total {
                return Err(format!(
                    "rack {i}: free {} + granted {granted} != {total}",
                    self.free[i].len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{InterCluster, Rack, ScalePoolBuilder, SystemConfig};
    use crate::fabric::TopologyKind;

    fn sys(racks: usize, per: usize) -> ScalePoolSystem {
        ScalePoolBuilder::new()
            .racks((0..racks).map(|i| {
                Rack::homogeneous(&format!("r{i}"), crate::cluster::Accelerator::b200(), per).unwrap()
            }))
            .config(SystemConfig {
                inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
                mem_nodes: 2,
                mem_node_capacity: 1e12,
                ..Default::default()
            })
            .build()
    }

    #[test]
    fn packs_rack_major() {
        let s = sys(3, 8);
        let mut m = ScalePoolManager::new(&s);
        let g = m.admit(&JobSpec { name: "j".into(), accelerators: 8, pool_bytes: 0.0 }).unwrap();
        assert_eq!(g.accelerators.len(), 1, "8 accs must fit one rack");
        let g2 = m.admit(&JobSpec { name: "k".into(), accelerators: 12, pool_bytes: 0.0 }).unwrap();
        assert_eq!(g2.accelerators.len(), 2, "12 accs span two racks");
        m.check_invariants().unwrap();
    }

    #[test]
    fn rejects_oversubscription() {
        let s = sys(2, 4);
        let mut m = ScalePoolManager::new(&s);
        assert!(m.admit(&JobSpec { name: "big".into(), accelerators: 9, pool_bytes: 0.0 }).is_err());
        assert_eq!(m.metrics.counter("admit_rejected_accels"), 1);
    }

    #[test]
    fn pool_accounting() {
        let s = sys(1, 4);
        let mut m = ScalePoolManager::new(&s);
        let cap = m.free_pool_bytes();
        let g = m.admit(&JobSpec { name: "p".into(), accelerators: 1, pool_bytes: cap / 2.0 }).unwrap();
        assert!((m.free_pool_bytes() - cap / 2.0).abs() < 1.0);
        assert!(m.admit(&JobSpec { name: "q".into(), accelerators: 1, pool_bytes: cap }).is_err());
        m.release(g.job);
        assert!((m.free_pool_bytes() - cap).abs() < 1.0);
    }

    #[test]
    fn release_returns_accelerators() {
        let s = sys(2, 4);
        let mut m = ScalePoolManager::new(&s);
        let g = m.admit(&JobSpec { name: "j".into(), accelerators: 6, pool_bytes: 0.0 }).unwrap();
        assert_eq!(m.free_accelerators(), 2);
        assert!(m.release(g.job));
        assert_eq!(m.free_accelerators(), 8);
        assert!(!m.release(g.job), "double release rejected");
        m.check_invariants().unwrap();
    }

    #[test]
    fn churn_preserves_invariants() {
        let s = sys(4, 8);
        let mut m = ScalePoolManager::new(&s);
        let mut rng = crate::util::Rng::new(5);
        let mut live = Vec::new();
        for _ in 0..200 {
            if rng.f64() < 0.6 || live.is_empty() {
                let n = 1 + rng.below(10) as usize;
                if let Ok(g) = m.admit(&JobSpec { name: "x".into(), accelerators: n, pool_bytes: 0.0 }) {
                    live.push(g.job);
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let job = live.swap_remove(idx);
                assert!(m.release(job));
            }
            m.check_invariants().unwrap();
        }
    }
}
