//! Data-movement routing over the hybrid fabric: picks the mechanism for
//! each transfer the way §4 prescribes — XLink for intra-cluster bulk,
//! CXL.cache for fine-grained coherent sharing, CXL.io/CXL.mem for bulk
//! inter-cluster and tier-2 traffic — and prices the decision with the
//! fabric model.

use crate::cluster::ScalePoolSystem;
use crate::fabric::NodeId;

/// Which protocol path a transfer takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteClass {
    /// Intra-cluster accelerator transfer over XLink.
    XlinkBulk,
    /// Instruction-granularity coherent access over CXL.cache.
    CxlCacheLine,
    /// Bulk transfer over CXL.io / CXL.mem (no CPU involvement).
    CxlBulk,
    /// Tier-2 memory node access (capacity-oriented CXL).
    CxlTier2,
}

/// A routing decision with its predicted cost.
#[derive(Clone, Debug)]
pub struct RouteDecision {
    pub class: RouteClass,
    pub est_latency_ns: f64,
    pub hops: usize,
}

/// Threshold below which coherent line-granularity access beats a bulk
/// transfer setup (bytes).
pub const CACHELINE_CUTOFF: f64 = 4096.0;

/// The router.
pub struct DataMovementRouter<'s> {
    sys: &'s ScalePoolSystem,
}

impl<'s> DataMovementRouter<'s> {
    pub fn new(sys: &'s ScalePoolSystem) -> Self {
        DataMovementRouter { sys }
    }

    fn rack_of(&self, node: NodeId) -> Option<usize> {
        self.sys.racks.iter().position(|r| r.acc_ids.contains(&node))
    }

    /// Route a transfer of `bytes` between two accelerators (or an
    /// accelerator and a memory node).
    pub fn route(&self, src: NodeId, dst: NodeId, bytes: f64) -> RouteDecision {
        let path = self.sys.fabric.path(src, dst).expect("connected fabric");
        let lat = self.sys.fabric.message_latency(&path, bytes).total_ns();
        let class = if self.sys.mem_nodes.contains(&dst) || self.sys.mem_nodes.contains(&src) {
            RouteClass::CxlTier2
        } else {
            match (self.rack_of(src), self.rack_of(dst)) {
                (Some(a), Some(b)) if a == b => RouteClass::XlinkBulk,
                _ if bytes <= CACHELINE_CUTOFF => RouteClass::CxlCacheLine,
                _ => RouteClass::CxlBulk,
            }
        };
        RouteDecision { class, est_latency_ns: lat, hops: path.hops() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{InterCluster, Rack, ScalePoolBuilder, SystemConfig};
    use crate::fabric::TopologyKind;

    fn sys() -> ScalePoolSystem {
        ScalePoolBuilder::new()
            .racks((0..2).map(|i| {
                Rack::homogeneous(&format!("r{i}"), crate::cluster::Accelerator::b200(), 4).unwrap()
            }))
            .config(SystemConfig {
                inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
                mem_nodes: 2,
                ..Default::default()
            })
            .build()
    }

    #[test]
    fn intra_rack_uses_xlink() {
        let s = sys();
        let r = DataMovementRouter::new(&s);
        let d = r.route(s.racks[0].acc_ids[0], s.racks[0].acc_ids[1], 1e6);
        assert_eq!(d.class, RouteClass::XlinkBulk);
        assert!(d.est_latency_ns > 0.0);
    }

    #[test]
    fn small_inter_rack_is_coherent_cacheline() {
        let s = sys();
        let r = DataMovementRouter::new(&s);
        let d = r.route(s.racks[0].acc_ids[0], s.racks[1].acc_ids[0], 64.0);
        assert_eq!(d.class, RouteClass::CxlCacheLine);
    }

    #[test]
    fn bulk_inter_rack_is_cxl_bulk() {
        let s = sys();
        let r = DataMovementRouter::new(&s);
        let d = r.route(s.racks[0].acc_ids[0], s.racks[1].acc_ids[0], 1e8);
        assert_eq!(d.class, RouteClass::CxlBulk);
    }

    #[test]
    fn memory_node_traffic_is_tier2() {
        let s = sys();
        let r = DataMovementRouter::new(&s);
        let d = r.route(s.racks[0].acc_ids[0], s.mem_nodes[0], 4096.0);
        assert_eq!(d.class, RouteClass::CxlTier2);
    }

    #[test]
    fn latency_scales_with_distance_class() {
        let s = sys();
        let r = DataMovementRouter::new(&s);
        let intra = r.route(s.racks[0].acc_ids[0], s.racks[0].acc_ids[1], 4096.0);
        let inter = r.route(s.racks[0].acc_ids[0], s.racks[1].acc_ids[0], 4096.0);
        assert!(intra.est_latency_ns < inter.est_latency_ns);
        assert!(intra.hops < inter.hops);
    }
}
