//! The flat-I/O ABI between the AOT python side and the rust runtime,
//! parsed from `artifacts/<preset>.manifest.json`.

use crate::util::error::{Context, Result};
use crate::util::Json;
use std::path::{Path, PathBuf};

/// Shape/dtype of one tensor in the flat I/O list.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "s32".
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").and_then(Json::as_str).context("tensor name")?.to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_u64().unwrap_or(0) as usize)
            .collect();
        let dtype = j.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string();
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One entry point (train_step / init / eval).
#[derive(Clone, Debug)]
pub struct EntryPoint {
    pub artifact: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntryPoint {
    fn from_json(dir: &Path, j: &Json) -> Result<EntryPoint> {
        let artifact = dir.join(j.get("artifact").and_then(Json::as_str).context("artifact path")?);
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("entry {key}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(EntryPoint { artifact, inputs: specs("inputs")?, outputs: specs("outputs")? })
    }
}

/// The whole per-preset manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub preset: String,
    pub param_count: u64,
    /// Parameter names, in flat order.
    pub params: Vec<String>,
    pub n_params: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub train_step: EntryPoint,
    pub init: EntryPoint,
    pub eval: EntryPoint,
}

impl ArtifactManifest {
    /// Load `<dir>/<preset>.manifest.json`.
    pub fn load(dir: &Path, preset: &str) -> Result<ArtifactManifest> {
        let path = dir.join(format!("{preset}.manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let hp = j.get("hyperparams").context("hyperparams")?;
        let u = |k: &str| hp.get(k).and_then(Json::as_u64).unwrap_or(0) as usize;
        Ok(ArtifactManifest {
            preset: j.get("preset").and_then(Json::as_str).context("preset")?.to_string(),
            param_count: j.get("param_count").and_then(Json::as_u64).unwrap_or(0),
            params: j
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .filter_map(|p| p.as_str().map(str::to_string))
                .collect(),
            n_params: j.get("n_params").and_then(Json::as_u64).unwrap_or(0) as usize,
            vocab: u("vocab"),
            seq: u("seq"),
            batch: u("batch"),
            train_step: EntryPoint::from_json(dir, j.get("train_step").context("train_step")?)?,
            init: EntryPoint::from_json(dir, j.get("init").context("init")?)?,
            eval: EntryPoint::from_json(dir, j.get("eval").context("eval")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_built() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("tiny.manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir, "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.params.len(), m.n_params);
        // train step IO: params*3 + [step, tokens, targets]
        assert_eq!(m.train_step.inputs.len(), 3 * m.n_params + 3);
        assert_eq!(m.train_step.outputs.len(), 3 * m.n_params + 2);
        // init: seed -> params*3 + step
        assert_eq!(m.init.inputs.len(), 1);
        assert_eq!(m.init.outputs.len(), 3 * m.n_params + 1);
        assert!(m.param_count > 0);
        assert!(m.train_step.artifact.exists());
        let toks = &m.train_step.inputs[3 * m.n_params + 1];
        assert_eq!(toks.name, "tokens");
        assert_eq!(toks.shape, vec![m.batch, m.seq]);
        assert_eq!(toks.dtype, "s32");
    }

    #[test]
    fn tensor_spec_from_json() {
        let j = Json::parse(r#"{"name":"w","shape":[2,3],"dtype":"f32"}"#).unwrap();
        let t = TensorSpec::from_json(&j).unwrap();
        assert_eq!(t.elements(), 6);
        assert_eq!(t.name, "w");
    }
}
