//! The training-loop state machine: owns the flat parameter/optimizer
//! state between steps and drives the AOT train/init/eval executables.

use super::manifest::ArtifactManifest;
use super::pjrt::{lit_i32, lit_i32_scalar, Executable, PjrtEngine};
use crate::util::error::{ensure, Context, Result};
use std::path::Path;
use std::time::Instant;

/// Result of one training step.
#[derive(Clone, Copy, Debug)]
pub struct StepResult {
    pub step: u64,
    pub loss: f32,
    /// Wall-clock time of the PJRT execution, ns.
    pub exec_ns: u64,
}

/// Training state over the AOT artifacts of one preset.
pub struct Trainer {
    engine: PjrtEngine,
    manifest: ArtifactManifest,
    train_exe: Executable,
    init_exe: Executable,
    eval_exe: Executable,
    /// Flat state: params + m + v (+ step scalar at the end), as returned
    /// by init / the previous step.
    state: Vec<xla::Literal>,
    step: u64,
}

impl Trainer {
    /// Load the three executables for `preset` from `dir` and compile.
    pub fn load(dir: &Path, preset: &str) -> Result<Trainer> {
        let engine = PjrtEngine::cpu()?;
        let manifest = ArtifactManifest::load(dir, preset)?;
        let train_exe = engine.load_hlo(&manifest.train_step.artifact)?;
        let init_exe = engine.load_hlo(&manifest.init.artifact)?;
        let eval_exe = engine.load_hlo(&manifest.eval.artifact)?;
        Ok(Trainer { engine, manifest, train_exe, init_exe, eval_exe, state: Vec::new(), step: 0 })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Initialize parameters and optimizer state on device.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let out = self.engine.run(&self.init_exe, &[lit_i32_scalar(seed)])?;
        ensure!(
            out.len() == 3 * self.manifest.n_params + 1,
            "init returned {} outputs, expected {}",
            out.len(),
            3 * self.manifest.n_params + 1
        );
        self.state = out;
        self.step = 0;
        Ok(())
    }

    /// One optimizer step on a (tokens, targets) batch, each `[batch*seq]`
    /// row-major i32.
    pub fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<StepResult> {
        ensure!(!self.state.is_empty(), "call init() first");
        let (b, s) = (self.manifest.batch, self.manifest.seq);
        let tok = lit_i32(tokens, &[b, s])?;
        let tgt = lit_i32(targets, &[b, s])?;
        // inputs: params+m+v, step, tokens, targets — state already holds
        // params+m+v+step in order
        let mut args: Vec<&xla::Literal> = self.state.iter().collect();
        args.push(&tok);
        args.push(&tgt);
        let t0 = Instant::now();
        let mut out = self.engine.run(&self.train_exe, &args).context("train step")?;
        let exec_ns = t0.elapsed().as_nanos() as u64;
        ensure!(
            out.len() == 3 * self.manifest.n_params + 2,
            "train step returned {} outputs",
            out.len()
        );
        let loss = out.pop().unwrap().get_first_element::<f32>()?;
        self.state = out; // params' + m' + v' + step'
        self.step += 1;
        Ok(StepResult { step: self.step, loss, exec_ns })
    }

    /// Evaluate loss on a batch without updating.
    pub fn eval(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        ensure!(!self.state.is_empty(), "call init() first");
        let (b, s) = (self.manifest.batch, self.manifest.seq);
        let tok = lit_i32(tokens, &[b, s])?;
        let tgt = lit_i32(targets, &[b, s])?;
        let n = self.manifest.n_params;
        let mut args: Vec<&xla::Literal> = self.state[..n].iter().collect();
        args.push(&tok);
        args.push(&tgt);
        let out = self.engine.run(&self.eval_exe, &args)?;
        Ok(out[0].get_first_element::<f32>()?)
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Total bytes of resident training state (params + moments).
    pub fn state_bytes(&self) -> u64 {
        self.manifest.param_count * 3 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SyntheticCorpus;

    #[test]
    fn tiny_preset_trains_and_loss_decreases() {
        let dir = crate::runtime::default_artifacts_dir();
        if !crate::runtime::artifacts_available("tiny") {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut t = Trainer::load(&dir, "tiny").unwrap();
        t.init(0).unwrap();
        let m = t.manifest().clone();
        let mut corpus = SyntheticCorpus::new(m.vocab, 42);
        let mut first = None;
        let mut last = 0.0;
        for i in 0..30 {
            let (toks, tgts) = corpus.batch(m.batch, m.seq);
            let r = t.step(&toks, &tgts).unwrap();
            assert_eq!(r.step, i + 1);
            assert!(r.loss.is_finite());
            if first.is_none() {
                first = Some(r.loss);
            }
            last = r.loss;
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.9,
            "loss did not decrease: first {first} last {last}"
        );
        // eval path agrees in magnitude
        let (toks, tgts) = corpus.batch(m.batch, m.seq);
        let ev = t.eval(&toks, &tgts).unwrap();
        assert!(ev.is_finite() && ev > 0.0 && ev < first * 1.5);
    }
}
