//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from rust. Python never runs
//! here — the interchange is `artifacts/*.hlo.txt` + `*.manifest.json`.
//!
//! * [`pjrt`] — thin wrapper over the `xla` crate (client, compile, exec).
//! * [`manifest`] — the flat-I/O ABI descriptor parsed from the manifest.
//! * [`trainer`] — training-loop state machine over the train/init/eval
//!   executables (weights held as XLA literals between steps).
//! * [`data`] — deterministic synthetic tiny-corpus token pipeline.

// The PJRT client and trainer need the external `xla` crate, which is
// not in the offline vendor set; they are gated behind the `pjrt`
// feature. The manifest/data layers are pure rust and always built.
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod data;

pub use data::SyntheticCorpus;
pub use manifest::{ArtifactManifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;

/// Default artifacts directory relative to the repo root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env_or("SCALEPOOL_ARTIFACTS", "artifacts"))
}

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// True if the artifacts for `preset` exist (used by tests to skip
/// gracefully when `make artifacts` has not run).
pub fn artifacts_available(preset: &str) -> bool {
    default_artifacts_dir().join(format!("{preset}.manifest.json")).exists()
}
