//! Synthetic tiny-corpus data pipeline: a deterministic pseudo-natural
//! token stream with learnable structure (Zipf unigrams + bigram
//! transitions + sentence template), batched for the train step.
//!
//! The stream has real sequential dependencies, so next-token loss on it
//! decreases well below the unigram entropy as the model learns the
//! transitions — giving the E2E run a meaningful loss curve without
//! shipping a dataset.

use crate::util::Rng;

/// Deterministic synthetic corpus over a closed vocabulary.
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    vocab: usize,
    /// bigram successor table: token -> candidate successors
    successors: Vec<Vec<u32>>,
    rng: Rng,
    state: u32,
    /// sentence-position counter driving the template
    pos: u32,
    period: u32,
}

impl SyntheticCorpus {
    /// Build with a vocabulary of `vocab` tokens (ids [0, vocab)).
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        assert!(vocab >= 16, "vocab too small");
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        // each token gets 2-4 likely successors, drawn Zipf so low ids are
        // common (word-frequency realism)
        let successors = (0..vocab)
            .map(|_| {
                let k = 2 + (rng.below(3) as usize);
                (0..k).map(|_| rng.zipf(vocab as u64, 0.8) as u32).collect()
            })
            .collect();
        SyntheticCorpus { vocab, successors, rng: Rng::new(seed), state: 0, pos: 0, period: 17 }
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> u32 {
        self.pos += 1;
        if self.pos % self.period == 0 {
            // sentence boundary: token 0 acts as "."
            self.state = 0;
            return 0;
        }
        let cands = &self.successors[self.state as usize];
        let tok = if self.rng.f64() < 0.85 {
            // follow the bigram structure (learnable)
            cands[self.rng.below(cands.len() as u64) as usize]
        } else {
            // noise
            self.rng.zipf(self.vocab as u64, 0.8) as u32
        };
        self.state = tok;
        tok
    }

    /// Produce one (tokens, targets) batch: targets are tokens shifted by
    /// one (next-token prediction), both `[batch, seq]` row-major i32.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut row = Vec::with_capacity(seq + 1);
            for _ in 0..=seq {
                row.push(self.next_token() as i32);
            }
            tokens.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..=seq]);
        }
        (tokens, targets)
    }

    /// Unigram entropy estimate of the stream (nats) over `n` samples —
    /// an upper bound a learned model should beat.
    pub fn unigram_entropy(&mut self, n: usize) -> f64 {
        let mut counts = vec![0u64; self.vocab];
        for _ in 0..n {
            counts[self.next_token() as usize] += 1;
        }
        let total = n as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut c = SyntheticCorpus::new(256, 1);
        for _ in 0..10_000 {
            assert!((c.next_token() as usize) < 256);
        }
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut c = SyntheticCorpus::new(256, 1);
        let (toks, tgts) = c.batch(2, 64);
        assert_eq!(toks.len(), 128);
        assert_eq!(tgts.len(), 128);
        // within a row, target[i] == token[i+1]
        assert_eq!(&toks[1..64], &tgts[0..63]);
        assert_eq!(&toks[65..128], &tgts[64..127]);
    }

    #[test]
    fn deterministic() {
        let mut a = SyntheticCorpus::new(256, 42);
        let mut b = SyntheticCorpus::new(256, 42);
        assert_eq!(a.batch(2, 32), b.batch(2, 32));
    }

    #[test]
    fn stream_has_structure() {
        // bigram structure -> conditional entropy well below uniform ln(V)
        let mut c = SyntheticCorpus::new(256, 7);
        let h = c.unigram_entropy(200_000);
        assert!(h < (256f64).ln() * 0.95, "unigram entropy {h} too close to uniform");
        assert!(h > 1.0, "stream degenerated");
    }

    #[test]
    fn sentence_period_appears() {
        let mut c = SyntheticCorpus::new(256, 3);
        let mut zeros = 0;
        for _ in 0..17_000 {
            if c.next_token() == 0 {
                zeros += 1;
            }
        }
        assert!(zeros >= 1000, "period token underrepresented: {zeros}");
    }
}
