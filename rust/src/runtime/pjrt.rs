//! Thin PJRT wrapper: CPU client + HLO-text loading + execution.
//!
//! Interchange is HLO *text* (see aot.py and /opt/xla-example/README.md:
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's
//! proto path rejects; the text parser reassigns ids).

use crate::util::error::{ensure, Context, Result};
use std::path::Path;

/// A PJRT client plus compilation cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl PjrtEngine {
    /// CPU client (the only backend in this environment).
    pub fn cpu() -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.file_name().unwrap().to_string_lossy().into_owned() })
    }

    /// Execute with literal inputs; the artifact returns one tuple, which
    /// is decomposed into element literals.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal path): its C shim leaks every input device buffer
    /// (`buffer.release()` with no matching free — xla_rs.cc:900), which
    /// is ~1.3 GB/step for the base100m preset. We upload to buffers we
    /// own and go through `execute_b`, so inputs are freed on drop.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        exe: &Executable,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let mut buffers = Vec::with_capacity(args.len());
        for a in args {
            buffers.push(
                self.client
                    .buffer_from_host_literal(None, a.borrow())
                    .context("uploading input")?,
            );
        }
        let out = exe
            .exe
            .execute_b(&buffers)
            .with_context(|| format!("executing {}", exe.name))?;
        drop(buffers);
        let tuple = out[0][0].to_literal_sync().context("fetching result")?;
        tuple.to_tuple().context("untupling result")
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    ensure!(n == data.len(), "shape {shape:?} wants {n} elements, got {}", data.len());
    let l = xla::Literal::vec1(data);
    if shape.is_empty() {
        // scalar: reshape to rank 0
        Ok(l.reshape(&[])?)
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(l.reshape(&dims)?)
    }
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    ensure!(n == data.len(), "shape {shape:?} wants {n} elements, got {}", data.len());
    let l = xla::Literal::vec1(data);
    if shape.is_empty() {
        Ok(l.reshape(&[])?)
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(l.reshape(&dims)?)
    }
}

/// i32 scalar.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::from(v)
}

/// Extract an f32 scalar from a literal.
pub fn get_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let e = PjrtEngine::cpu().unwrap();
        assert!(e.device_count() >= 1);
        assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
    }

    #[test]
    fn literal_shapes() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let s = lit_i32_scalar(7);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn smoke_artifact_end_to_end() {
        // the Pallas-matmul smoke artifact: fn(x, y) = (x @ y + 2,)
        let dir = crate::runtime::default_artifacts_dir();
        let path = dir.join("smoke.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built (run `make artifacts`)", path.display());
            return;
        }
        let e = PjrtEngine::cpu().unwrap();
        let exe = e.load_hlo(&path).unwrap();
        let x = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let y = lit_f32(&[1.0, 1.0, 1.0, 1.0], &[2, 2]).unwrap();
        let out = e.run(&exe, &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(v, vec![5.0, 5.0, 9.0, 9.0]);
    }
}
