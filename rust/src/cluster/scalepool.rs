//! The full ScalePool system: racks joined by an inter-cluster fabric
//! (hierarchical CXL for ScalePool; InfiniBand for the RDMA baseline),
//! plus tier-2 memory nodes on the CXL side (Figure 2 / Figure 4).

use super::rack::Rack;
use crate::fabric::{Fabric, LinkKind, NodeId, NodeKind, Topology, TopologyKind};

/// How clusters are joined.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InterCluster {
    /// Scale-out baseline: InfiniBand NDR + RDMA software stack.
    RdmaInfiniBand,
    /// ScalePool: hierarchical CXL fabric of the given shape.
    Cxl(TopologyKind),
}

/// System construction parameters.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub inter: InterCluster,
    /// Tier-2 memory nodes attached to the CXL fabric.
    pub mem_nodes: usize,
    /// Capacity per memory node, bytes.
    pub mem_node_capacity: f64,
    /// CXL spine switches (Clos) / torus dims / dragonfly groups.
    pub fabric_width: usize,
    /// Give every accelerator its own CXL port into the fabric (the
    /// paper's Figure 2/5b: CXL logic embedded in accelerators beside the
    /// XLink controller). When false, only the rack switch uplinks.
    pub direct_cxl_ports: bool,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 8,
            mem_node_capacity: 8.0 * 512e9, // 8 modules of 512 GB per node
            fabric_width: 4,
            direct_cxl_ports: true,
        }
    }
}

/// A rack materialized in the system topology.
#[derive(Clone, Debug)]
pub struct RackView {
    pub rack: Rack,
    pub acc_ids: Vec<NodeId>,
    pub switch_id: NodeId,
    /// The rack's uplink bridge port into the inter-cluster fabric.
    pub uplink_id: NodeId,
}

/// The assembled system.
#[derive(Debug)]
pub struct ScalePoolSystem {
    pub fabric: Fabric,
    pub racks: Vec<RackView>,
    pub mem_nodes: Vec<NodeId>,
    pub config: SystemConfig,
}

/// Builder.
#[derive(Default)]
pub struct ScalePoolBuilder {
    racks: Vec<Rack>,
    config: Option<SystemConfig>,
}

impl ScalePoolBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn rack(mut self, rack: Rack) -> Self {
        self.racks.push(rack);
        self
    }

    pub fn racks(mut self, racks: impl IntoIterator<Item = Rack>) -> Self {
        self.racks.extend(racks);
        self
    }

    pub fn config(mut self, config: SystemConfig) -> Self {
        self.config = Some(config);
        self
    }

    /// Assemble the topology and routing.
    pub fn build(self) -> ScalePoolSystem {
        let config = self.config.unwrap_or_default();
        let mut topo = Topology::new();

        // 1. racks (intra-cluster XLink domains)
        let mut views: Vec<RackView> = Vec::new();
        for rack in self.racks {
            let (acc_ids, switch_id) = rack.materialize(&mut topo);
            views.push(RackView { rack, acc_ids, switch_id, uplink_id: switch_id });
        }

        // 2. inter-cluster fabric
        let inter_kind = match config.inter {
            InterCluster::RdmaInfiniBand => LinkKind::InfiniBandNdr,
            InterCluster::Cxl(_) => LinkKind::CxlCoherent,
        };
        let leafs: Vec<NodeId> = match config.inter {
            InterCluster::RdmaInfiniBand => {
                // two-level IB fat tree: one leaf per rack + spines
                let spines: Vec<NodeId> = (0..config.fabric_width.max(1))
                    .map(|i| {
                        topo.add_switch(
                            crate::fabric::SwitchParams::for_link(inter_kind),
                            format!("ib/spine{i}"),
                        )
                    })
                    .collect();
                views
                    .iter()
                    .enumerate()
                    .map(|(i, _)| {
                        let leaf = topo.add_switch(
                            crate::fabric::SwitchParams::for_link(inter_kind),
                            format!("ib/leaf{i}"),
                        );
                        for &s in &spines {
                            topo.connect(leaf, s, inter_kind);
                        }
                        leaf
                    })
                    .collect()
            }
            InterCluster::Cxl(TopologyKind::MultiLevelClos) | InterCluster::Cxl(TopologyKind::SingleHop) => {
                let spines: Vec<NodeId> = (0..config.fabric_width.max(1))
                    .map(|i| {
                        topo.add_switch(
                            crate::fabric::SwitchParams::for_link(inter_kind),
                            format!("cxl/spine{i}"),
                        )
                    })
                    .collect();
                views
                    .iter()
                    .enumerate()
                    .map(|(i, _)| {
                        let leaf = topo.add_switch(
                            crate::fabric::SwitchParams::for_link(inter_kind),
                            format!("cxl/leaf{i}"),
                        );
                        for &s in &spines {
                            topo.connect(leaf, s, inter_kind);
                        }
                        leaf
                    })
                    .collect()
            }
            InterCluster::Cxl(TopologyKind::Torus3d) => {
                let n = views.len().max(config.mem_nodes);
                let x = (n as f64).cbrt().ceil() as usize;
                let (sub, ids) = Topology::torus3d((x.max(2), x.max(2), x.max(1)), inter_kind, "cxl");
                let off = topo.merge(&sub);
                ids.iter().map(|&i| i + off).collect()
            }
            InterCluster::Cxl(TopologyKind::DragonFly) => {
                let groups = config.fabric_width.max(2);
                let per = ((views.len() + config.mem_nodes) as f64 / groups as f64).ceil() as usize;
                let (sub, gids) = Topology::dragonfly(groups, per.max(2), inter_kind, "cxl");
                let off = topo.merge(&sub);
                gids.into_iter().flatten().map(|i| i + off).collect()
            }
        };

        // 3. attach rack uplinks round-robin over fabric edge switches;
        // with direct_cxl_ports every accelerator also gets its own CXL
        // port into its rack's edge switch (Figure 2: per-accelerator CXL
        // logic beside the XLink controller)
        let direct = config.direct_cxl_ports && matches!(config.inter, InterCluster::Cxl(_));
        for (i, v) in views.iter_mut().enumerate() {
            let leaf = leafs[i % leafs.len()];
            topo.connect(v.switch_id, leaf, inter_kind);
            v.uplink_id = leaf;
            if direct {
                for &acc in &v.acc_ids {
                    topo.connect(acc, leaf, LinkKind::CxlCoherent);
                }
            }
        }

        // 4. tier-2 memory nodes on the CXL fabric (capacity-oriented
        // links); the RDMA baseline gets none — its overflow path is
        // remote CPU memory over IB
        let mut mem_nodes = Vec::new();
        if matches!(config.inter, InterCluster::Cxl(_)) {
            for m in 0..config.mem_nodes {
                let id = topo.add_node(NodeKind::MemoryNode, format!("memnode{m}"));
                let leaf = leafs[(views.len() + m) % leafs.len()];
                topo.connect(id, leaf, LinkKind::CxlCapacity);
                mem_nodes.push(id);
            }
        }

        let fabric = Fabric::new(topo);
        ScalePoolSystem { fabric, racks: views, mem_nodes, config }
    }
}

impl ScalePoolSystem {
    /// Total accelerators.
    pub fn accelerator_count(&self) -> usize {
        self.racks.iter().map(|r| r.acc_ids.len()).sum()
    }

    /// All accelerator node ids, rack-major order.
    pub fn accelerators(&self) -> Vec<NodeId> {
        self.racks.iter().flat_map(|r| r.acc_ids.iter().copied()).collect()
    }

    /// Accelerator node ids grouped per rack (the hierarchical-collective
    /// group structure).
    pub fn rack_groups(&self) -> Vec<Vec<NodeId>> {
        self.racks.iter().map(|r| r.acc_ids.clone()).collect()
    }

    /// Build the two tiering pools with regions on real fabric nodes:
    /// tier-1 is an HBM carve-out of `t1_bytes_per_acc` on every
    /// accelerator, tier-2 spreads `config.mem_node_capacity` across the
    /// CXL memory nodes — so migrations between them route over the
    /// actual tier-1→tier-2 paths.
    pub fn tier_pools(&self, t1_bytes_per_acc: f64) -> (crate::memory::pool::MemoryPool, crate::memory::pool::MemoryPool) {
        use crate::memory::pool::MemoryPool;
        use crate::memory::Tier;
        let mut t1 = MemoryPool::new();
        for acc in self.accelerators() {
            t1.add_region(acc, Tier::Tier1Local, t1_bytes_per_acc);
        }
        let mut t2 = MemoryPool::new();
        for &m in &self.mem_nodes {
            t2.add_region(m, Tier::Tier2Pool, self.config.mem_node_capacity);
        }
        (t1, t2)
    }

    /// Tier-1 capacity of one rack (bytes) — the Fig 7 "cluster" threshold.
    pub fn rack_hbm_capacity(&self, rack: usize) -> f64 {
        self.racks[rack].rack.hbm_capacity()
    }

    /// Total tier-2 pool capacity, bytes.
    pub fn tier2_capacity(&self) -> f64 {
        self.mem_nodes.len() as f64 * self.config.mem_node_capacity
    }

    /// One-way latency between accelerator `a` of rack `i` and accelerator
    /// `b` of rack `j` for a message of `bytes`.
    pub fn acc_latency_ns(&self, (i, a): (usize, usize), (j, b): (usize, usize), bytes: f64) -> f64 {
        self.fabric
            .latency_ns(self.racks[i].acc_ids[a], self.racks[j].acc_ids[b], bytes)
            .expect("connected system")
    }

    /// Round-trip latency from an accelerator to the nearest tier-2 memory
    /// node for a 64 B transaction (request + data).
    pub fn tier2_rt_ns(&self, rack: usize) -> Option<f64> {
        let src = self.racks[rack].acc_ids[0];
        self.mem_nodes
            .iter()
            .filter_map(|&m| self.fabric.latency_ns(src, m, 64.0))
            .map(|l| 2.0 * l)
            .fold(None, |acc: Option<f64>, l| Some(acc.map_or(l, |a| a.min(l))))
    }

    /// Round-trip latency to a peer accelerator in another rack (64 B,
    /// coherent access pattern).
    pub fn inter_rack_rt_ns(&self) -> Option<f64> {
        if self.racks.len() < 2 {
            return None;
        }
        Some(2.0 * self.acc_latency_ns((0, 0), (1, 0), 64.0))
    }

    /// Effective inter-rack bandwidth per rack uplink for large messages,
    /// bytes/ns.
    pub fn inter_rack_bw(&self) -> Option<f64> {
        if self.racks.len() < 2 {
            return None;
        }
        let p = self.fabric.path(self.racks[0].acc_ids[0], self.racks[1].acc_ids[0])?;
        Some(self.fabric.path_bandwidth(&p, 1e9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys(inter: InterCluster, racks: usize) -> ScalePoolSystem {
        let mut b = ScalePoolBuilder::new();
        for i in 0..racks {
            b = b.rack(Rack::homogeneous(&format!("rack{i}"), super::super::Accelerator::b200(), 8).unwrap());
        }
        b.config(SystemConfig { inter, ..Default::default() }).build()
    }

    #[test]
    fn cxl_clos_system_connected() {
        let s = sys(InterCluster::Cxl(TopologyKind::MultiLevelClos), 4);
        assert!(s.fabric.topo.is_connected());
        assert_eq!(s.accelerator_count(), 32);
        assert_eq!(s.mem_nodes.len(), 8);
        assert!(s.fabric.topo.validate_radix().is_ok());
    }

    #[test]
    fn rdma_baseline_has_no_memory_nodes() {
        let s = sys(InterCluster::RdmaInfiniBand, 4);
        assert!(s.mem_nodes.is_empty());
        assert!(s.fabric.topo.is_connected());
    }

    #[test]
    fn intra_rack_beats_inter_rack() {
        let s = sys(InterCluster::Cxl(TopologyKind::MultiLevelClos), 2);
        let intra = s.acc_latency_ns((0, 0), (0, 1), 4096.0);
        let inter = s.acc_latency_ns((0, 0), (1, 0), 4096.0);
        assert!(intra < inter, "intra {intra} !< inter {inter}");
    }

    #[test]
    fn cxl_inter_rack_beats_ib_inter_rack() {
        // hardware path only; RDMA software overhead comes on top in
        // collective::rdma — even the raw wires favor CXL here
        let c = sys(InterCluster::Cxl(TopologyKind::MultiLevelClos), 2);
        let r = sys(InterCluster::RdmaInfiniBand, 2);
        let lc = c.acc_latency_ns((0, 0), (1, 0), 4096.0);
        let lr = r.acc_latency_ns((0, 0), (1, 0), 4096.0);
        assert!(lc < lr, "cxl {lc} !< ib {lr}");
    }

    #[test]
    fn tier2_reachable_and_fast() {
        let s = sys(InterCluster::Cxl(TopologyKind::MultiLevelClos), 2);
        let rt = s.tier2_rt_ns(0).unwrap();
        // "tens to hundreds of nanoseconds" plus fabric: must be < 2 µs
        assert!(rt < 2_000.0, "tier-2 RT {rt} ns");
    }

    #[test]
    fn torus_and_dragonfly_build_connected() {
        for kind in [TopologyKind::Torus3d, TopologyKind::DragonFly] {
            let s = sys(InterCluster::Cxl(kind), 4);
            assert!(s.fabric.topo.is_connected(), "{kind:?} disconnected");
            assert!(s.inter_rack_rt_ns().unwrap() > 0.0);
        }
    }

    #[test]
    fn tier2_capacity_scales_with_nodes() {
        let s = sys(InterCluster::Cxl(TopologyKind::MultiLevelClos), 2);
        assert!((s.tier2_capacity() - 8.0 * 8.0 * 512e9).abs() < 1.0);
    }
}
