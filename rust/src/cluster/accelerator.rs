//! Accelerator device presets — the heterogeneous XPU population of §4
//! (NVIDIA GPUs on NVLink; AMD GPUs, MTIA, Trainium, Inferentia, Maia,
//! Gaudi on UALink).

use crate::fabric::LinkKind;

/// Device vendor (drives XLink interoperability rules).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Vendor {
    Nvidia,
    Amd,
    Meta,
    Amazon,
    Microsoft,
    Intel,
}

/// An accelerator model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Accelerator {
    pub name: &'static str,
    pub vendor: Vendor,
    /// Dense bf16 throughput, TFLOP/s.
    pub bf16_tflops: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: f64,
    /// HBM bandwidth, bytes/ns (GB/s).
    pub hbm_bw: f64,
    /// Native XLink technology.
    pub xlink: LinkKind,
    /// Aggregate XLink bandwidth per device (one direction), bytes/ns.
    pub xlink_bw: f64,
}

impl Accelerator {
    /// NVIDIA B200 (one GPU of a GB200 superchip): the paper's baseline
    /// rack is "36 GB200 modules, with 72 GPUs interconnected via NVLink 5".
    pub const fn b200() -> Accelerator {
        Accelerator {
            name: "B200",
            vendor: Vendor::Nvidia,
            bf16_tflops: 2_250.0,
            hbm_bytes: 192e9,
            hbm_bw: 8_000.0,
            xlink: LinkKind::NvLink5,
            xlink_bw: 900.0,
        }
    }

    pub const fn mi300x() -> Accelerator {
        Accelerator {
            name: "MI300X",
            vendor: Vendor::Amd,
            bf16_tflops: 1_300.0,
            hbm_bytes: 192e9,
            hbm_bw: 5_300.0,
            xlink: LinkKind::UaLink,
            xlink_bw: 448.0,
        }
    }

    pub const fn gaudi3() -> Accelerator {
        Accelerator {
            name: "Gaudi3",
            vendor: Vendor::Intel,
            bf16_tflops: 1_800.0,
            hbm_bytes: 128e9,
            hbm_bw: 3_700.0,
            xlink: LinkKind::UaLink,
            xlink_bw: 600.0,
        }
    }

    pub const fn trainium2() -> Accelerator {
        Accelerator {
            name: "Trainium2",
            vendor: Vendor::Amazon,
            bf16_tflops: 650.0,
            hbm_bytes: 96e9,
            hbm_bw: 2_900.0,
            xlink: LinkKind::UaLink,
            xlink_bw: 400.0,
        }
    }

    pub const fn mtia2() -> Accelerator {
        Accelerator {
            name: "MTIA-2",
            vendor: Vendor::Meta,
            bf16_tflops: 354.0,
            hbm_bytes: 128e9,
            hbm_bw: 1_300.0,
            xlink: LinkKind::UaLink,
            xlink_bw: 300.0,
        }
    }

    pub const fn maia100() -> Accelerator {
        Accelerator {
            name: "Maia-100",
            vendor: Vendor::Microsoft,
            bf16_tflops: 800.0,
            hbm_bytes: 64e9,
            hbm_bw: 1_800.0,
            xlink: LinkKind::UaLink,
            xlink_bw: 400.0,
        }
    }

    /// Effective achievable fraction of peak FLOPs for transformer layers
    /// (model FLOP utilization ceiling used by the calculon model).
    pub fn mfu_ceiling(&self) -> f64 {
        match self.vendor {
            Vendor::Nvidia => 0.55,
            Vendor::Amd => 0.50,
            _ => 0.45,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvidia_is_nvlink_everyone_else_ualink() {
        assert_eq!(Accelerator::b200().xlink, LinkKind::NvLink5);
        for a in [
            Accelerator::mi300x(),
            Accelerator::gaudi3(),
            Accelerator::trainium2(),
            Accelerator::mtia2(),
            Accelerator::maia100(),
        ] {
            assert_eq!(a.xlink, LinkKind::UaLink, "{} must be UALink", a.name);
        }
    }

    #[test]
    fn b200_matches_gb200_specs() {
        let b = Accelerator::b200();
        assert_eq!(b.hbm_bytes, 192e9);
        assert_eq!(b.xlink_bw, 900.0); // NVLink5: 1.8 TB/s bidirectional
    }

    #[test]
    fn mfu_ceiling_sane() {
        for a in [Accelerator::b200(), Accelerator::mi300x(), Accelerator::mtia2()] {
            let c = a.mfu_ceiling();
            assert!(c > 0.2 && c < 0.8);
        }
    }
}
