//! Rack = one accelerator-centric cluster: an XLink domain plus its fabric
//! subgraph (accelerators hanging off the rack's XLink switch complex) and
//! a CXL uplink port count for joining the inter-cluster fabric.

use super::accelerator::Accelerator;
use super::xlink::{XlinkDomain, XlinkError};
use crate::fabric::{LinkKind, NodeId, NodeKind, Topology};

/// A rack-scale accelerator cluster.
#[derive(Clone, Debug)]
pub struct Rack {
    pub name: String,
    pub domain: XlinkDomain,
    /// Number of CXL ports the rack exposes to the inter-cluster fabric.
    pub cxl_uplinks: usize,
}

impl Rack {
    /// Homogeneous rack of `n` copies of `acc`.
    pub fn homogeneous(name: &str, acc: Accelerator, n: usize) -> Result<Rack, XlinkError> {
        let mut domain = XlinkDomain::new(acc.xlink);
        for _ in 0..n {
            domain.add(acc)?;
        }
        domain.validate()?;
        Ok(Rack { name: name.to_string(), domain, cxl_uplinks: 8 })
    }

    /// The paper's baseline rack: GB200 NVL72 (36 GB200 modules = 72 GPUs).
    pub fn nvl72(name: &str) -> Rack {
        Rack::homogeneous(name, Accelerator::b200(), 72).expect("NVL72 construction")
    }

    pub fn size(&self) -> usize {
        self.domain.members.len()
    }

    /// Materialize this rack into a topology: accelerators around the
    /// XLink switch, plus `cxl_uplinks` CXL bridge ports on the switch.
    /// Returns (accelerator node ids, xlink switch id).
    pub fn materialize(&self, topo: &mut Topology) -> (Vec<NodeId>, NodeId) {
        let sw = topo.add_switch(
            crate::fabric::SwitchParams::for_link(self.domain.kind),
            format!("{}/xswitch", self.name),
        );
        let mut ids = Vec::with_capacity(self.size());
        for (i, a) in self.domain.members.iter().enumerate() {
            let id = topo.add_node(NodeKind::Accelerator, format!("{}/{}{}", self.name, a.name, i));
            topo.connect(id, sw, self.domain.kind);
            ids.push(id);
        }
        (ids, sw)
    }

    /// Tier-1 local capacity visible inside the rack, bytes.
    pub fn hbm_capacity(&self) -> f64 {
        self.domain.total_hbm()
    }

    /// Is this rack reachable over a given inter-cluster technology?
    /// (Everything speaks CXL through the abstraction layer; XLink does
    /// not cross rack boundaries.)
    pub fn supports_uplink(&self, kind: LinkKind) -> bool {
        kind.is_cxl() || kind == LinkKind::InfiniBandNdr || kind == LinkKind::PcieGen5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvl72_has_72_gpus_and_13_8tb() {
        let r = Rack::nvl72("rack0");
        assert_eq!(r.size(), 72);
        assert!((r.hbm_capacity() - 72.0 * 192e9).abs() < 1.0);
    }

    #[test]
    fn materialize_produces_single_hop() {
        let r = Rack::nvl72("rack0");
        let mut t = Topology::new();
        let (accs, sw) = r.materialize(&mut t);
        assert_eq!(accs.len(), 72);
        assert_eq!(t.degree(sw), 72);
        assert!(t.is_connected());
        assert!(t.validate_radix().is_ok());
    }

    #[test]
    fn xlink_never_uplinks_between_racks() {
        let r = Rack::nvl72("rack0");
        assert!(!r.supports_uplink(LinkKind::NvLink5));
        assert!(!r.supports_uplink(LinkKind::UaLink));
        assert!(r.supports_uplink(LinkKind::CxlCoherent));
        assert!(r.supports_uplink(LinkKind::InfiniBandNdr));
    }

    #[test]
    fn heterogeneous_ualink_rack() {
        let mut domain = XlinkDomain::new(LinkKind::UaLink);
        domain.add(Accelerator::mi300x()).unwrap();
        domain.add(Accelerator::gaudi3()).unwrap();
        domain.validate().unwrap();
        let r = Rack { name: "ua0".into(), domain, cxl_uplinks: 4 };
        assert_eq!(r.size(), 2);
    }
}
