//! XLink domain rules (§2/§4): single-hop scalability limits and the
//! NVLink/UALink interoperability wall that CXL resolves at the
//! inter-cluster layer.

use super::accelerator::{Accelerator, Vendor};
use crate::fabric::LinkKind;

/// Why a device cannot join an XLink domain.
#[derive(Debug, PartialEq)]
pub enum XlinkError {
    MixedLink(LinkKind, LinkKind),
    NvlinkNeedsNvidia,
    DomainFull(usize),
}

impl std::fmt::Display for XlinkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XlinkError::MixedLink(a, b) => {
                write!(f, "mixing {a:?} and {b:?} in one XLink domain: incompatible PHY/flit formats")
            }
            XlinkError::NvlinkNeedsNvidia => {
                write!(f, "NVLink domain requires at least one NVIDIA component (NVLink Fusion policy)")
            }
            XlinkError::DomainFull(n) => {
                write!(f, "domain full: {n} accelerators is the practical per-rack limit")
            }
        }
    }
}

impl std::error::Error for XlinkError {}

/// A single-hop XLink domain (one rack-scale cluster's interconnect).
#[derive(Clone, Debug)]
pub struct XlinkDomain {
    pub kind: LinkKind,
    pub members: Vec<Accelerator>,
    /// Practical per-rack limit (72 for both NVLink and UALink racks per
    /// §4, despite UALink's theoretical 1,024).
    pub max_members: usize,
}

impl XlinkDomain {
    pub fn new(kind: LinkKind) -> XlinkDomain {
        assert!(kind.is_xlink(), "XLink domain over a non-XLink technology");
        XlinkDomain { kind, members: Vec::new(), max_members: 72 }
    }

    /// UALink's theoretical single-hop scale.
    pub const UALINK_THEORETICAL_MAX: usize = 1024;

    /// Try to add an accelerator, enforcing the §4 rules.
    pub fn add(&mut self, acc: Accelerator) -> Result<(), XlinkError> {
        if acc.xlink != self.kind {
            return Err(XlinkError::MixedLink(self.kind, acc.xlink));
        }
        if self.members.len() >= self.max_members {
            return Err(XlinkError::DomainFull(self.max_members));
        }
        self.members.push(acc);
        Ok(())
    }

    /// Validate vendor policy: an NVLink domain must include >= 1 NVIDIA
    /// component ("NVIDIA's strategic policy still mandates inclusion of at
    /// least one NVIDIA component within NVLink-connected system").
    pub fn validate(&self) -> Result<(), XlinkError> {
        if self.kind == LinkKind::NvLink5
            && !self.members.iter().any(|a| a.vendor == Vendor::Nvidia)
            && !self.members.is_empty()
        {
            return Err(XlinkError::NvlinkNeedsNvidia);
        }
        Ok(())
    }

    /// Aggregate HBM capacity of the domain, bytes (the cluster's tier-1
    /// local capacity).
    pub fn total_hbm(&self) -> f64 {
        self.members.iter().map(|a| a.hbm_bytes).sum()
    }

    /// Aggregate bf16 compute, TFLOP/s.
    pub fn total_tflops(&self) -> f64 {
        self.members.iter().map(|a| a.bf16_tflops).sum()
    }

    /// Per-device XLink bandwidth (bottleneck member), bytes/ns.
    pub fn per_device_bw(&self) -> f64 {
        self.members.iter().map(|a| a.xlink_bw).fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvlink_rack_of_72_b200() {
        let mut d = XlinkDomain::new(LinkKind::NvLink5);
        for _ in 0..72 {
            d.add(Accelerator::b200()).unwrap();
        }
        assert!(d.validate().is_ok());
        assert_eq!(d.total_hbm(), 72.0 * 192e9);
        assert_eq!(d.add(Accelerator::b200()), Err(XlinkError::DomainFull(72)));
    }

    #[test]
    fn cannot_mix_nvlink_and_ualink() {
        let mut d = XlinkDomain::new(LinkKind::NvLink5);
        d.add(Accelerator::b200()).unwrap();
        assert_eq!(
            d.add(Accelerator::mi300x()),
            Err(XlinkError::MixedLink(LinkKind::NvLink5, LinkKind::UaLink))
        );
    }

    #[test]
    fn ualink_mixes_vendors_freely() {
        let mut d = XlinkDomain::new(LinkKind::UaLink);
        d.add(Accelerator::mi300x()).unwrap();
        d.add(Accelerator::gaudi3()).unwrap();
        d.add(Accelerator::trainium2()).unwrap();
        d.add(Accelerator::mtia2()).unwrap();
        assert!(d.validate().is_ok());
    }

    #[test]
    #[should_panic]
    fn cxl_is_not_an_xlink_domain() {
        XlinkDomain::new(LinkKind::CxlCoherent);
    }

    #[test]
    fn bottleneck_bandwidth() {
        let mut d = XlinkDomain::new(LinkKind::UaLink);
        d.add(Accelerator::mi300x()).unwrap(); // 448
        d.add(Accelerator::mtia2()).unwrap(); // 300
        assert_eq!(d.per_device_bw(), 300.0);
    }
}
