//! Accelerator-centric clusters (§4) and the full ScalePool system builder:
//! accelerator presets, rack-scale XLink domains with interoperability
//! rules (NVLink needs an NVIDIA component; NVLink+UALink cannot share a
//! domain), and the CXL fabric joining clusters + tier-2 memory nodes.

pub mod accelerator;
pub mod xlink;
pub mod rack;
pub mod scalepool;

pub use accelerator::{Accelerator, Vendor};
pub use rack::Rack;
pub use scalepool::{InterCluster, ScalePoolBuilder, ScalePoolSystem, SystemConfig};
pub use xlink::{XlinkDomain, XlinkError};
