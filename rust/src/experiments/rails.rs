//! The `rails` experiment: sweep multi-rail routing policies over the
//! pod-scale mixed scenario and report per-class solo-vs-mixed latency
//! inflation per policy, plus the realized path diversity and the
//! link-utilization imbalance the steering achieves. The `mixed`
//! experiment measures cross-class interference on deterministic
//! single-path routes; this one shows the fabric routing *around* it
//! (the DFabric/Octopus direction): ECMP hash-spray spreads each pair's
//! transactions over every equal-cost rail, and adaptive steering picks
//! the least-backlogged candidate path from the live QoS link state.
//!
//! Workloads are rebuilt identically-seeded for every policy, and the
//! solo baselines are measured once under deterministic rail-0 routing
//! (exactly the `mixed` experiment's solos), so the only difference
//! between sweep points is how the *mixed* run steers — the `det` point
//! reproduces `mixed` string-exactly (asserted by the CI smoke).

use super::mixed::{
    as_dyn_sources, build_system, coherence_sources, collective_sources, horizon_estimate,
    solo_baselines, tiering_source, MixedConfig,
};
use super::qos::QosClassRow;
use crate::coordinator::RoutingManager;
use crate::sim::{
    MemSim, RailSelector, StreamReport, TraceConfig, TraceData, TrafficClass, TrafficSource,
};

/// One policy point of the sweep.
#[derive(Clone, Debug)]
pub struct RailSpec {
    /// Short name used in RESULT lines ("det" / "spray" / "adaptive").
    pub name: String,
    /// Applied uniformly across link tiers by the [`RoutingManager`].
    pub selector: RailSelector,
}

impl RailSpec {
    pub fn det() -> RailSpec {
        RailSpec { name: "det".into(), selector: RailSelector::Deterministic }
    }

    pub fn spray() -> RailSpec {
        RailSpec { name: "spray".into(), selector: RailSelector::HashSpray }
    }

    pub fn adaptive() -> RailSpec {
        RailSpec { name: "adaptive".into(), selector: RailSelector::Adaptive }
    }
}

/// Sweep configuration: the mixed scenario, the rail fan-out `K` the
/// PBR table is built with, and the policy list.
#[derive(Clone, Debug)]
pub struct RailsSweepConfig {
    pub mixed: MixedConfig,
    /// Equal-cost rails per PBR cell ([`Fabric::enable_multipath`]).
    ///
    /// [`Fabric::enable_multipath`]: crate::fabric::Fabric::enable_multipath
    pub rails: usize,
    pub policies: Vec<RailSpec>,
}

impl Default for RailsSweepConfig {
    fn default() -> RailsSweepConfig {
        RailsSweepConfig {
            mixed: MixedConfig::default(),
            rails: 4,
            policies: vec![RailSpec::det(), RailSpec::spray(), RailSpec::adaptive()],
        }
    }
}

/// One policy's full outcome. Class rows share the
/// [`QosClassRow`] shape (solo vs mixed mean/p50/p99), so the RESULT
/// keys line up with the `qos` sweep's.
#[derive(Clone, Debug)]
pub struct RailsPolicyRow {
    pub name: String,
    pub rows: Vec<QosClassRow>,
    pub makespan_ns: f64,
    pub events: u64,
    pub peak_utilization: f64,
    /// Hops express dispatch admitted inline (ISSUE 10) — 0 when the
    /// dense mixed traffic never cleared the peek gate.
    pub fused_hops: u64,
    /// Fraction of hop-level events that were fused.
    pub fusion_rate: f64,
    /// Distinct physical paths transactions actually rode in the mixed
    /// run (adaptive probes and aliased rail indices do not count).
    pub used_paths: usize,
    /// Distinct (src, dst) pairs that carried traffic.
    pub used_pairs: usize,
    /// Busiest link direction's busy time over the fabric-wide mean
    /// (every link direction, idle ones included — a policy-independent
    /// denominator). Equal-cost rails have equal hop counts, so total
    /// busy time is conserved across policies and this is directly
    /// comparable between them: deterministic routing concentrates load
    /// (higher peak), spreading flattens it.
    pub util_imbalance: f64,
}

impl RailsPolicyRow {
    /// Largest per-class mean-latency inflation — same definition as
    /// `MixedReport::max_tx_inflation`, so the `det` row is directly
    /// comparable to the `mixed` baseline (asserted by CI).
    pub fn max_tx_inflation(&self) -> f64 {
        self.rows.iter().map(QosClassRow::tx_inflation).fold(1.0, f64::max)
    }

    /// Realized path diversity: physical paths ridden per (src, dst)
    /// pair (1.0 = strictly single-path).
    pub fn path_diversity(&self) -> f64 {
        if self.used_pairs == 0 {
            1.0
        } else {
            self.used_paths as f64 / self.used_pairs as f64
        }
    }

    pub fn row(&self, class: TrafficClass) -> Option<&QosClassRow> {
        self.rows.iter().find(|r| r.class == class)
    }
}

/// The sweep result.
#[derive(Clone, Debug)]
pub struct RailsReport {
    pub policies: Vec<RailsPolicyRow>,
    /// Flight recording of the sweep's *last* policy point, when
    /// [`MixedConfig::trace`] was set (the adaptive point under the
    /// default policy list — the steering whose per-link behavior the
    /// trace is usually wanted for).
    pub trace: Option<TraceData>,
}

impl RailsReport {
    pub fn policy(&self, name: &str) -> Option<&RailsPolicyRow> {
        self.policies.iter().find(|p| p.name == name)
    }
}

/// Busiest link direction's busy time over the fabric-wide mean busy
/// time (from the per-link [`StreamReport::qos`] telemetry). The
/// denominator spans every link direction of the fabric — idle ones
/// included — so it is independent of which directions a routing policy
/// happens to touch; since equal-cost rails have equal hop counts, the
/// total busy time is conserved across policies and spreading strictly
/// lowers this ratio by lowering the peak.
fn util_imbalance(rep: &StreamReport, total_dirs: usize) -> f64 {
    let mut dir_busy: std::collections::HashMap<(u32, u8), f64> = std::collections::HashMap::new();
    for s in &rep.qos {
        *dir_busy.entry((s.link, s.dir)).or_insert(0.0) += s.busy_ns;
    }
    let total: f64 = dir_busy.values().sum();
    if total_dirs == 0 || total <= 0.0 {
        return 1.0;
    }
    let peak = dir_busy.values().fold(0.0f64, |a, &b| a.max(b));
    peak / (total / total_dirs as f64)
}

/// One mixed run under a routing policy on a fork of the master,
/// returning the report plus the simulator-side steering telemetry
/// (paths/pairs actually ridden). A spreading selector changes the
/// fork's spread mask, so its path state resets and it interns its own
/// rail-aware paths; the deterministic point keeps the master's warmed
/// arena (see [`MemSim::set_routing`]).
fn run_point(
    master: &MemSim,
    sources: &mut [&mut dyn TrafficSource],
    mgr: &RoutingManager,
    trace: Option<TraceConfig>,
) -> (StreamReport, f64, usize, usize, Option<TraceData>) {
    let mut sim = master.fork();
    mgr.apply(&mut sim);
    if let Some(tcfg) = trace {
        sim.set_trace(tcfg);
    }
    let rep = sim.run_streamed(sources);
    let util = sim.peak_utilization(rep.total.makespan_ns);
    let (paths, pairs) = (sim.used_path_count(), sim.used_pair_count());
    let data = sim.take_trace();
    (rep, util, paths, pairs, data)
}

/// Run the sweep: one set of solo baselines (deterministic rail-0
/// routing — the `mixed` experiment's solos), then the mixed scenario
/// once per policy with identically-seeded workloads and the selector
/// applied via the coordinator's [`RoutingManager`].
pub fn run_rails(cfg: &RailsSweepConfig) -> RailsReport {
    let mcfg = &cfg.mixed;
    let mut sys = build_system(mcfg);
    sys.fabric.enable_multipath(cfg.rails);
    let horizon = horizon_estimate(&sys, mcfg);

    // --- solo baselines (shared by every policy point) -------------------
    // build once (after enable_multipath, so forks share the K-rail
    // table), fork per point
    let mut master = MemSim::new(&sys.fabric);
    let [coh_solo, tier_solo, col_solo] = solo_baselines(&sys, mcfg, horizon, &mut master);

    // --- one mixed run per policy ----------------------------------------
    let mut policies = Vec::new();
    let mut trace: Option<TraceData> = None;
    let last = cfg.policies.len().saturating_sub(1);
    for (pi, spec) in cfg.policies.iter().enumerate() {
        let mgr = RoutingManager::uniform(spec.selector);
        let mut coh = coherence_sources(&sys, mcfg, horizon);
        let mut tier = tiering_source(&sys, mcfg, horizon);
        let mut col = collective_sources(&sys, mcfg);
        // only the last policy point records (one trace per sweep file)
        let tcfg = if pi == last { mcfg.trace } else { None };
        let (rep, util, paths, pairs, tr) = {
            let mut sources = as_dyn_sources(&mut coh, &mut tier, &mut col);
            run_point(&master, &mut sources, &mgr, tcfg)
        };
        if tr.is_some() {
            trace = tr;
        }
        let row = |class: TrafficClass, (solo_tx, solo_p50, solo_p99): (f64, f64, f64)| {
            let c = rep.class(class);
            QosClassRow {
                class,
                completed: c.completed,
                bytes: c.bytes,
                solo_tx_ns: solo_tx,
                mixed_tx_ns: c.mean_ns(),
                solo_p50_ns: solo_p50,
                mixed_p50_ns: c.p50_ns(),
                solo_p99_ns: solo_p99,
                mixed_p99_ns: c.p99_ns(),
            }
        };
        policies.push(RailsPolicyRow {
            name: spec.name.clone(),
            rows: vec![
                row(TrafficClass::Coherence, coh_solo),
                row(TrafficClass::Tiering, tier_solo),
                row(TrafficClass::Collective, col_solo),
            ],
            makespan_ns: rep.total.makespan_ns,
            events: rep.total.events,
            peak_utilization: util,
            fused_hops: rep.fused_hops,
            fusion_rate: rep.fusion_rate(),
            used_paths: paths,
            used_pairs: pairs,
            util_imbalance: util_imbalance(&rep, sys.fabric.topo.links.len() * 2),
        });
    }
    RailsReport { policies, trace }
}

/// Paper-style report plus the machine-readable RESULT lines.
pub fn render(r: &RailsReport, rails: usize) -> String {
    use crate::util::units::{fmt_bytes, fmt_ns};
    let mut out = String::new();
    for p in &r.policies {
        out.push_str(&format!("=== policy {} (K={rails} rails) ===\n", p.name));
        out.push_str(&format!(
            "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>8}\n",
            "class", "txns", "bytes", "solo tx", "mixed tx", "infl", "solo p99", "mixed p99", "p99 infl"
        ));
        out.push_str(&"-".repeat(104));
        out.push('\n');
        for row in &p.rows {
            out.push_str(&format!(
                "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>6.2}x | {:>10} {:>10} {:>7.2}x\n",
                row.class.name(),
                row.completed,
                fmt_bytes(row.bytes),
                fmt_ns(row.solo_tx_ns),
                fmt_ns(row.mixed_tx_ns),
                row.tx_inflation(),
                fmt_ns(row.solo_p99_ns),
                fmt_ns(row.mixed_p99_ns),
                row.p99_inflation(),
            ));
        }
        out.push_str(&format!(
            "makespan {} | {} events | peak link utilization {:.1}%\n",
            fmt_ns(p.makespan_ns),
            p.events,
            100.0 * p.peak_utilization
        ));
        // zero keeps the sweep output (and CI greps) byte-identical
        if p.fused_hops > 0 {
            out.push_str(&format!(
                "express dispatch: {} hops fused inline ({:.1}% of hop events)\n",
                p.fused_hops,
                100.0 * p.fusion_rate,
            ));
        }
        out.push_str(&format!(
            "  steering: {} paths ridden over {} pairs (diversity {:.2}x), link-utilization imbalance {:.2}x\n",
            p.used_paths,
            p.used_pairs,
            p.path_diversity(),
            p.util_imbalance,
        ));
    }
    // machine-readable: one line per (policy, class) for CI greps, one
    // summary line per policy for the BENCH_figs.json capture
    for p in &r.policies {
        for row in &p.rows {
            out.push_str(&format!(
                "RESULT rails policy={} class={} p99_inflation={:.3} tx_inflation={:.3}\n",
                p.name,
                row.class.name(),
                row.p99_inflation(),
                row.tx_inflation(),
            ));
        }
    }
    for p in &r.policies {
        let g = |class: TrafficClass, f: fn(&QosClassRow) -> f64| p.row(class).map(f).unwrap_or(1.0);
        out.push_str(&format!(
            "RESULT rails_{} max_tx_inflation={:.3} coherence_p99_inflation={:.3} tiering_p99_inflation={:.3} collective_p99_inflation={:.3} path_diversity={:.3} util_imbalance={:.3}\n",
            p.name,
            p.max_tx_inflation(),
            g(TrafficClass::Coherence, QosClassRow::p99_inflation),
            g(TrafficClass::Tiering, QosClassRow::p99_inflation),
            g(TrafficClass::Collective, QosClassRow::p99_inflation),
            p.path_diversity(),
            p.util_imbalance,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RailsSweepConfig {
        RailsSweepConfig {
            mixed: MixedConfig {
                coherence_ops: 800,
                tiering_ops: 200,
                collective_bytes: 8.0 * 1024.0 * 1024.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn sweep_runs_every_policy() {
        let r = run_rails(&small());
        assert_eq!(r.policies.len(), 3);
        for p in &r.policies {
            for row in &p.rows {
                assert!(row.completed > 0, "{}/{} moved nothing", p.name, row.class.name());
                assert!(row.solo_tx_ns > 0.0 && row.mixed_tx_ns > 0.0);
                assert!(row.mixed_p99_ns > 0.0);
            }
            assert!(p.makespan_ns > 0.0);
            assert!(p.path_diversity() >= 1.0);
            assert!(p.util_imbalance >= 1.0, "{}: imbalance below 1", p.name);
        }
        // deterministic rides one path per pair; spray actually spreads
        let det = r.policy("det").unwrap();
        assert_eq!(det.used_paths, det.used_pairs);
        let spray = r.policy("spray").unwrap();
        assert!(
            spray.path_diversity() > 1.0,
            "spray realized no path diversity: {} paths / {} pairs",
            spray.used_paths,
            spray.used_pairs
        );
        // spreading flattens the (policy-independent-denominator) peak
        assert!(
            spray.util_imbalance <= det.util_imbalance,
            "spray must not concentrate load harder than det: {} vs {}",
            spray.util_imbalance,
            det.util_imbalance
        );
    }

    #[test]
    fn det_point_reproduces_the_mixed_experiment() {
        // the parity anchor the CI smoke also checks end to end: the
        // rails sweep's deterministic mixed run (multipath table, rail-0
        // policy) is byte-identical to the mixed experiment's mixed run
        // on the single-path table
        let cfg = small();
        let r = run_rails(&cfg);
        let m = super::super::mixed::run_mixed(&cfg.mixed);
        let det = r.policy("det").unwrap();
        assert_eq!(det.events, m.mixed_events);
        assert!((det.makespan_ns - m.mixed_makespan_ns).abs() < 1e-9);
        assert!((det.max_tx_inflation() - m.max_tx_inflation()).abs() < 1e-12);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_rails(&small());
        let b = run_rails(&small());
        for (pa, pb) in a.policies.iter().zip(&b.policies) {
            assert_eq!(pa.events, pb.events);
            assert!((pa.makespan_ns - pb.makespan_ns).abs() < 1e-12);
            assert_eq!(pa.used_paths, pb.used_paths);
        }
    }

    #[test]
    fn render_emits_result_lines() {
        let r = run_rails(&small());
        let out = render(&r, 4);
        for p in ["det", "spray", "adaptive"] {
            assert!(out.contains(&format!("RESULT rails policy={p} class=coherence")), "{out}");
            assert!(out.contains(&format!("RESULT rails_{p} max_tx_inflation=")), "{out}");
        }
        assert!(out.contains("path_diversity="));
    }
}
