//! Experiment harnesses that regenerate every table and figure of the
//! paper's evaluation (§6), plus the cross-traffic interference scenario
//! (`mixed`) the closed-form figures cannot express. Shared by the CLI,
//! the examples and the bench binaries — one implementation, three entry
//! points.
//!
//! | id | paper artifact | harness |
//! |----|----------------|---------|
//! | T1 | Table 1 (link characteristics)            | [`table1`] |
//! | F6 | Figure 6 (LLM training, 5 models)         | [`fig6`]   |
//! | F7 | Figure 7 (tiered memory, working-set sweep)| [`fig7`]  |
//! | MX | §6 tier-2 traffic under interference      | [`mixed`]  |
//! | QS | QoS policy sweep over the mixed scenario  | [`qos`]    |
//! | RL | Multi-rail routing sweep over the mixed scenario | [`rails`] |

pub mod table1;
pub mod fig6;
pub mod fig7;
pub mod mixed;
pub mod qos;
pub mod rails;

pub use fig6::{run_fig6, Fig6Row};
pub use fig7::{run_fig7, run_fig7_detailed, Fig7DetailedConfig, Fig7Row};
pub use mixed::{run_mixed, CollectiveShape, MixedConfig, MixedReport};
pub use qos::{run_qos, PolicySpec, QosReport, QosSweepConfig};
pub use rails::{run_rails, RailSpec, RailsReport, RailsSweepConfig};
pub use table1::{run_table1, Table1Row};
