//! The `mixed` experiment: all three traffic classes — coherent CXL.cache
//! message flows, tier-2 migration streams, and collective all-reduce
//! chunk schedules — run *concurrently* on one [`ScalePoolSystem`] fabric,
//! and per-class latency is reported solo vs under interference.
//!
//! This is the scenario class the paper's §6 tier-2 claims are about and
//! that no closed-form figure can express: DFabric shows
//! hybrid-interconnect results hinge on cross-traffic interference on
//! shared links, and CXL-CCL shows collectives over a CXL pool contend
//! with memory traffic. Each class is simulated alone (its own
//! self-contention only) and then together; the inflation ratio is the
//! interference.

use crate::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, ScalePoolSystem, SystemConfig};
use crate::coherence::{CoherenceConfig, CoherenceTraffic};
use crate::collective::{Algorithm, CollectiveModel, EventDrivenCollective, Transport};
use crate::coordinator::{TieringEngine, TieringPolicy, TieringTraffic, TieringTrafficConfig};
use crate::fabric::TopologyKind;
use crate::sim::{
    MemSim, ShardMode, StreamReport, TraceConfig, TraceData, TrafficClass, TrafficSource,
};
use crate::util::stats::Welford;

/// Shape of the collective schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveShape {
    /// Rack-grouped reduce, inter-rack exchange, rack-local broadcast.
    Hierarchical,
    /// One flat ring over every accelerator in the pod.
    FlatRing,
    /// One independent flat ring per rack. Each ring's footprint stays
    /// inside its rack, so the sharded backend can pin every collective
    /// source to a distinct shard (the shape the reactive-sharding bench
    /// and CI parity smoke exercise).
    RackRings,
}

/// Scenario knobs.
#[derive(Clone, Debug)]
pub struct MixedConfig {
    pub racks: usize,
    pub accels: usize,
    pub mem_nodes: usize,
    /// Coherent operations issued by the sharing workloads (split evenly
    /// across the per-rack sharing domains).
    pub coherence_ops: u64,
    /// Allocate/touch/free ops driving the tiering engine.
    pub tiering_ops: u64,
    /// All-reduce buffer per rank, bytes.
    pub collective_bytes: f64,
    /// Back-to-back all-reduces.
    pub collective_repeats: usize,
    /// Collective schedule shape.
    pub shape: CollectiveShape,
    /// Tier-1 HBM carve-out per accelerator for the tiering pools, bytes.
    pub t1_bytes_per_acc: f64,
    /// Run the mixed point on the sharded backend
    /// ([`MemSim::run_streamed_sharded_with`]) instead of the serial
    /// streamed loop. Source schedules are identical either way; the two
    /// backends produce the same report (pinned by
    /// `rack_rings_sharded_matches_serial` and the CI parity smoke).
    pub sharded: bool,
    /// Shard-count cap when `sharded` (0 = one per hardware thread).
    pub shards: usize,
    /// Flight-recorder configuration for the mixed run (`None` = off; the
    /// off path is free). Solo baselines are never traced; the recording
    /// lands in [`MixedReport::trace`].
    pub trace: Option<TraceConfig>,
    pub seed: u64,
}

impl Default for MixedConfig {
    fn default() -> Self {
        MixedConfig {
            racks: 4,
            accels: 8,
            mem_nodes: 4,
            coherence_ops: 2_000,
            tiering_ops: 300,
            collective_bytes: 32.0 * 1024.0 * 1024.0,
            collective_repeats: 1,
            shape: CollectiveShape::Hierarchical,
            t1_bytes_per_acc: 2.0 * 1024.0 * 1024.0,
            sharded: false,
            shards: 0,
            trace: None,
            seed: 7,
        }
    }
}

/// Per-class outcome: transaction-level and domain-level latency, solo
/// vs mixed.
#[derive(Clone, Debug)]
pub struct MixedClassRow {
    pub class: TrafficClass,
    /// Transactions completed in the mixed run.
    pub completed: u64,
    /// Payload bytes moved in the mixed run.
    pub bytes: f64,
    /// Mean fabric transaction latency, alone on the fabric, ns.
    pub solo_tx_ns: f64,
    /// Same, under cross-traffic.
    pub mixed_tx_ns: f64,
    /// Median transaction latency alone, ns (log-binned histogram, ~±4%).
    pub solo_p50_ns: f64,
    /// Same, under cross-traffic.
    pub mixed_p50_ns: f64,
    /// 99th-percentile transaction latency alone, ns (log-binned
    /// histogram, ~±4%).
    pub solo_p99_ns: f64,
    /// Same, under cross-traffic — the tail the QoS policies act on.
    pub mixed_p99_ns: f64,
    /// Domain metric alone (coherent op / migration transfer / all-reduce
    /// repeat), ns.
    pub solo_domain_ns: f64,
    /// Same, under cross-traffic.
    pub mixed_domain_ns: f64,
}

impl MixedClassRow {
    /// Interference inflation of mean transaction latency.
    pub fn tx_inflation(&self) -> f64 {
        if self.solo_tx_ns > 0.0 {
            self.mixed_tx_ns / self.solo_tx_ns
        } else {
            1.0
        }
    }

    /// Interference inflation of the domain-level latency.
    pub fn domain_inflation(&self) -> f64 {
        if self.solo_domain_ns > 0.0 {
            self.mixed_domain_ns / self.solo_domain_ns
        } else {
            1.0
        }
    }

    /// Interference inflation of the p99 transaction latency (tail).
    pub fn p99_inflation(&self) -> f64 {
        if self.solo_p99_ns > 0.0 {
            self.mixed_p99_ns / self.solo_p99_ns
        } else {
            1.0
        }
    }
}

/// Full experiment result.
#[derive(Clone, Debug)]
pub struct MixedReport {
    pub rows: Vec<MixedClassRow>,
    pub mixed_makespan_ns: f64,
    pub mixed_events: u64,
    pub mixed_peak_utilization: f64,
    pub peak_inflight: usize,
    /// Hops express dispatch admitted inline (ISSUE 10) — 0 when fusion
    /// never fired (dense traffic) or was disabled.
    pub fused_hops: u64,
    /// Fraction of hop-level events that were fused (see
    /// [`StreamReport::fusion_rate`]).
    pub fusion_rate: f64,
    /// Backend the mixed run executed on (serial, sharded, or a sharded
    /// request that fell back — and why).
    pub mode: ShardMode,
    /// Reactive sources whose footprint spans the partition, run on the
    /// coordinator under the optimistic checkpoint/rollback protocol
    /// (0 on serial runs and on sharded runs where every source pins).
    pub optimistic_sources: usize,
    /// Epoch windows the optimistic protocol checkpointed.
    pub checkpoints: u64,
    /// Optimistic windows that mispredicted and re-executed.
    pub rollbacks: u64,
    /// Span/instant records the flight recorder dropped at its ring
    /// capacity (0 when tracing was off).
    pub dropped_spans: u64,
    /// Self-measured recording cost of the trace, wall-clock ns.
    pub trace_overhead_ns: f64,
    /// The mixed run's recording, when [`MixedConfig::trace`] was set.
    pub trace: Option<TraceData>,
}

impl MixedReport {
    /// Largest per-class transaction-latency inflation — the headline
    /// interference number.
    pub fn max_tx_inflation(&self) -> f64 {
        self.rows.iter().map(MixedClassRow::tx_inflation).fold(1.0, f64::max)
    }

    pub fn row(&self, class: TrafficClass) -> Option<&MixedClassRow> {
        self.rows.iter().find(|r| r.class == class)
    }
}

pub(crate) fn build_system(cfg: &MixedConfig) -> ScalePoolSystem {
    assert!(cfg.racks >= 2, "mixed experiment needs >= 2 racks");
    assert!(cfg.accels >= 2);
    ScalePoolBuilder::new()
        .racks(
            (0..cfg.racks)
                .map(|i| Rack::homogeneous(&format!("rack{i}"), Accelerator::b200(), cfg.accels).unwrap()),
        )
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: cfg.mem_nodes,
            ..Default::default()
        })
        .build()
}

/// Rough collective duration on an idle fabric — the shared horizon the
/// coherence and tiering schedules are paced against so all classes
/// overlap in time.
pub(crate) fn horizon_estimate(sys: &ScalePoolSystem, cfg: &MixedConfig) -> f64 {
    let n = sys.accelerator_count();
    let chunk = (cfg.collective_bytes / n.max(1) as f64).max(64.0);
    let a = sys.racks[0].acc_ids[0];
    let b = sys.racks[1].acc_ids[0];
    let t = Transport::from_sim_path(&sys.fabric, a, b, chunk).expect("connected system");
    let m = CollectiveModel::flat(t);
    (m.all_reduce(n, cfg.collective_bytes, Algorithm::Ring) * cfg.collective_repeats as f64)
        .max(50_000.0)
}

/// One coherence sharing domain per rack: the rack's accelerators cache
/// lines homed on one pool memory node (`mem_nodes[rack % M]`), and the
/// op budget is split evenly across racks (remainder to the low racks).
/// Keeping each domain's requester/home/sharer footprint inside one rack
/// lets the sharded backend pin every coherence source to the shard that
/// owns its rack — a pod-wide sharing domain would pull all shards into
/// one and force the serial fallback.
pub(crate) fn coherence_sources(
    sys: &ScalePoolSystem,
    cfg: &MixedConfig,
    horizon_ns: f64,
) -> Vec<CoherenceTraffic> {
    let racks = sys.racks.len() as u64;
    let base = cfg.coherence_ops / racks;
    let rem = cfg.coherence_ops % racks;
    (0..sys.racks.len())
        .map(|r| {
            let agents = sys.racks[r].acc_ids.clone();
            let ops = base + u64::from((r as u64) < rem);
            let ccfg = CoherenceConfig {
                ops,
                mean_interarrival_ns: (horizon_ns / ops.max(1) as f64).max(1.0),
                window: agents.len().max(8),
                ..Default::default()
            };
            let home = sys.mem_nodes[r % sys.mem_nodes.len()];
            CoherenceTraffic::new(agents, vec![home], ccfg, cfg.seed.wrapping_add(r as u64 * 7919))
        })
        .collect()
}

pub(crate) fn tiering_source(sys: &ScalePoolSystem, cfg: &MixedConfig, horizon_ns: f64) -> TieringTraffic {
    let (t1, t2) = sys.tier_pools(cfg.t1_bytes_per_acc);
    let engine = TieringEngine::new(t1, t2, TieringPolicy::default());
    let tcfg = TieringTrafficConfig {
        ops: cfg.tiering_ops,
        mean_interarrival_ns: (horizon_ns / cfg.tiering_ops.max(1) as f64).max(1.0),
        ..Default::default()
    };
    TieringTraffic::new(engine, sys.accelerators(), tcfg, cfg.seed.wrapping_add(1))
}

/// The collective schedule(s) for `cfg.shape` — one source except under
/// [`CollectiveShape::RackRings`], which emits an independent ring per
/// rack.
pub(crate) fn collective_sources(sys: &ScalePoolSystem, cfg: &MixedConfig) -> Vec<EventDrivenCollective> {
    match cfg.shape {
        CollectiveShape::Hierarchical => vec![EventDrivenCollective::hierarchical(
            sys.rack_groups(),
            cfg.collective_bytes,
            cfg.collective_repeats,
        )],
        CollectiveShape::FlatRing => vec![EventDrivenCollective::ring(
            sys.accelerators(),
            cfg.collective_bytes,
            cfg.collective_repeats,
        )],
        CollectiveShape::RackRings => sys
            .racks
            .iter()
            .map(|r| EventDrivenCollective::ring(r.acc_ids.clone(), cfg.collective_bytes, cfg.collective_repeats))
            .collect(),
    }
}

/// Assemble the canonical mixed source ordering — every per-rack
/// coherence domain, the tiering stream, then the collective
/// schedule(s) — as the trait-object vector the simulator consumes. Both
/// backends and every sweep use this order, so reports stay comparable
/// point to point.
pub(crate) fn as_dyn_sources<'a>(
    coh: &'a mut [CoherenceTraffic],
    tier: &'a mut TieringTraffic,
    col: &'a mut [EventDrivenCollective],
) -> Vec<&'a mut dyn TrafficSource> {
    let mut out: Vec<&mut dyn TrafficSource> = Vec::with_capacity(coh.len() + 1 + col.len());
    for c in coh.iter_mut() {
        out.push(c);
    }
    out.push(tier);
    for c in col.iter_mut() {
        out.push(c);
    }
    out
}

/// Run one point of a sweep on a fork of the prebuilt master simulator,
/// optionally applying a QoS configuration to the fork first (`None`
/// keeps the class-blind FCFS default — the parity baseline). The fork
/// shares the master's routing table and interned path arena and gets
/// fresh mutable state, so a sweep builds the fabric once and pays only
/// the per-point run — see [`MemSim::fork`].
pub(crate) fn run_fork(
    master: &MemSim,
    sources: &mut [&mut dyn TrafficSource],
    qos: Option<&crate::coordinator::QosManager>,
) -> (StreamReport, f64) {
    run_fork_with(master, sources, qos, false, 0)
}

/// As [`run_fork`], with backend selection: `sharded` routes the point
/// through the conservative parallel loop (capped at `max_shards`
/// shards; 0 means one per hardware thread), which falls back to serial
/// by itself when the plan is not profitable — the report's
/// [`ShardMode`](crate::sim::ShardMode) says what actually ran.
pub(crate) fn run_fork_with(
    master: &MemSim,
    sources: &mut [&mut dyn TrafficSource],
    qos: Option<&crate::coordinator::QosManager>,
    sharded: bool,
    max_shards: usize,
) -> (StreamReport, f64) {
    let (rep, util, _) = run_fork_traced(master, sources, qos, sharded, max_shards, None);
    (rep, util)
}

/// As [`run_fork_with`], with the flight recorder armed on the fork when
/// `trace` is set; the recording comes back as the third element.
pub(crate) fn run_fork_traced(
    master: &MemSim,
    sources: &mut [&mut dyn TrafficSource],
    qos: Option<&crate::coordinator::QosManager>,
    sharded: bool,
    max_shards: usize,
    trace: Option<TraceConfig>,
) -> (StreamReport, f64, Option<TraceData>) {
    let mut sim = master.fork();
    if let Some(mgr) = qos {
        mgr.apply(&mut sim);
    }
    if let Some(tcfg) = trace {
        sim.set_trace(tcfg);
    }
    let rep = if sharded && max_shards > 0 {
        sim.run_streamed_sharded_with(sources, max_shards)
    } else if sharded {
        sim.run_streamed_sharded(sources)
    } else {
        sim.run_streamed(sources)
    };
    let util = sim.peak_utilization(rep.total.makespan_ns);
    let data = sim.take_trace();
    (rep, util, data)
}

/// `(mean, p50, p99)` of `class` transactions in `rep`.
pub(crate) fn class_triple(class: TrafficClass, rep: &StreamReport) -> (f64, f64, f64) {
    let c = rep.class(class);
    (c.mean_ns(), c.p50_ns(), c.p99_ns())
}

/// The three per-class solo baselines of the mixed scenario, in class
/// order `[Coherence, Tiering, Collective]` — shared by the `qos` and
/// `rails` sweeps (solos are policy-invariant: a class alone on the
/// fabric serves FIFO within its one virtual channel under every
/// arbitration policy, and rides rail 0 under the master's default
/// deterministic routing).
///
/// The first solo runs on `master` itself to warm its path arena; the
/// arena is then frozen ([`MemSim::freeze_paths`]) so the remaining
/// solos — and every policy point the caller forks afterwards — start
/// with the full interned-path cache.
pub(crate) fn solo_baselines(
    sys: &ScalePoolSystem,
    mcfg: &MixedConfig,
    horizon: f64,
    master: &mut MemSim,
) -> [(f64, f64, f64); 3] {
    let coh = {
        let mut srcs = coherence_sources(sys, mcfg, horizon);
        let mut s: Vec<&mut dyn TrafficSource> =
            srcs.iter_mut().map(|x| x as &mut dyn TrafficSource).collect();
        let rep = master.run_streamed(&mut s);
        class_triple(TrafficClass::Coherence, &rep)
    };
    master.freeze_paths();
    let tier = {
        let mut src = tiering_source(sys, mcfg, horizon);
        let mut s: [&mut dyn TrafficSource; 1] = [&mut src];
        let (rep, _) = run_fork(master, &mut s, None);
        class_triple(TrafficClass::Tiering, &rep)
    };
    let col = {
        let mut srcs = collective_sources(sys, mcfg);
        let mut s: Vec<&mut dyn TrafficSource> =
            srcs.iter_mut().map(|x| x as &mut dyn TrafficSource).collect();
        let (rep, _) = run_fork(master, &mut s, None);
        class_triple(TrafficClass::Collective, &rep)
    };
    [coh, tier, col]
}

pub(crate) fn mean_or_zero(w: &Welford) -> f64 {
    if w.count() == 0 {
        0.0
    } else {
        w.mean()
    }
}

/// Count-weighted mean across the per-source domain-latency accumulators
/// of one class (per-rack coherence domains, per-rack collective rings):
/// `sum(count * mean) / sum(count)`, 0 when nothing completed.
pub(crate) fn merged_mean<'a>(ws: impl Iterator<Item = &'a Welford>) -> f64 {
    let (mut n, mut sum) = (0u64, 0.0f64);
    for w in ws {
        if w.count() > 0 {
            n += w.count();
            sum += w.count() as f64 * w.mean();
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Run the experiment: three solo runs (per-class baselines) plus the
/// mixed run, all forks of one build-once simulator over
/// identically-seeded workloads.
pub fn run_mixed(cfg: &MixedConfig) -> MixedReport {
    let sys = build_system(cfg);
    let horizon = horizon_estimate(&sys, cfg);
    // build-once master: the first solo runs on it directly to warm the
    // path arena, freeze_paths publishes the arena behind the shared
    // Arc, and every later run is a cheap fork (fresh servers, shared
    // routing + paths — parity pinned by
    // prop_forked_sim_matches_fresh_build)
    let mut master = MemSim::new(&sys.fabric);

    // --- solo baselines --------------------------------------------------
    let (coh_solo, coh_solo_op) = {
        let mut srcs = coherence_sources(&sys, cfg, horizon);
        let mut solo: Vec<&mut dyn TrafficSource> =
            srcs.iter_mut().map(|x| x as &mut dyn TrafficSource).collect();
        let rep = master.run_streamed(&mut solo);
        let c = rep.class(TrafficClass::Coherence);
        ((c.mean_ns(), c.p50_ns(), c.p99_ns()), merged_mean(srcs.iter().map(|s| s.op_latency())))
    };
    master.freeze_paths();
    let (tier_solo, tier_solo_mig) = {
        let mut src = tiering_source(&sys, cfg, horizon);
        let mut solo: [&mut dyn TrafficSource; 1] = [&mut src];
        let (rep, _) = run_fork(&master, &mut solo, None);
        let c = rep.class(TrafficClass::Tiering);
        ((c.mean_ns(), c.p50_ns(), c.p99_ns()), mean_or_zero(src.migration_latency()))
    };
    let (col_solo, col_solo_rep) = {
        let mut srcs = collective_sources(&sys, cfg);
        let mut solo: Vec<&mut dyn TrafficSource> =
            srcs.iter_mut().map(|x| x as &mut dyn TrafficSource).collect();
        let (rep, _) = run_fork(&master, &mut solo, None);
        let c = rep.class(TrafficClass::Collective);
        ((c.mean_ns(), c.p50_ns(), c.p99_ns()), merged_mean(srcs.iter().map(|s| s.repeat_latency())))
    };

    // --- mixed run -------------------------------------------------------
    let mut coh = coherence_sources(&sys, cfg, horizon);
    let mut tier = tiering_source(&sys, cfg, horizon);
    let mut col = collective_sources(&sys, cfg);
    let (mixed, util, trace) = {
        let mut sources = as_dyn_sources(&mut coh, &mut tier, &mut col);
        run_fork_traced(&master, &mut sources, None, cfg.sharded, cfg.shards, cfg.trace)
    };

    let row = |class: TrafficClass,
               (solo_tx, solo_p50, solo_p99): (f64, f64, f64),
               solo_domain: f64,
               mixed_domain: f64| {
        let c = mixed.class(class);
        MixedClassRow {
            class,
            completed: c.completed,
            bytes: c.bytes,
            solo_tx_ns: solo_tx,
            mixed_tx_ns: c.mean_ns(),
            solo_p50_ns: solo_p50,
            mixed_p50_ns: c.p50_ns(),
            solo_p99_ns: solo_p99,
            mixed_p99_ns: c.p99_ns(),
            solo_domain_ns: solo_domain,
            mixed_domain_ns: mixed_domain,
        }
    };
    let rows = vec![
        row(TrafficClass::Coherence, coh_solo, coh_solo_op, merged_mean(coh.iter().map(|s| s.op_latency()))),
        row(TrafficClass::Tiering, tier_solo, tier_solo_mig, mean_or_zero(tier.migration_latency())),
        row(TrafficClass::Collective, col_solo, col_solo_rep, merged_mean(col.iter().map(|s| s.repeat_latency()))),
    ];
    MixedReport {
        rows,
        mixed_makespan_ns: mixed.total.makespan_ns,
        mixed_events: mixed.total.events,
        mixed_peak_utilization: util,
        peak_inflight: mixed.peak_inflight,
        fused_hops: mixed.fused_hops,
        fusion_rate: mixed.fusion_rate(),
        mode: mixed.mode.clone(),
        optimistic_sources: mixed.optimistic_sources,
        checkpoints: mixed.checkpoints,
        rollbacks: mixed.rollbacks,
        dropped_spans: mixed.dropped_spans,
        trace_overhead_ns: mixed.trace_overhead_ns,
        trace,
    }
}

/// Paper-style table.
pub fn render(r: &MixedReport) -> String {
    use crate::util::units::{fmt_bytes, fmt_ns};
    let mut out = String::new();
    out.push_str(&format!(
        "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>8} | {:>10} {:>10} {:>7}\n",
        "class", "txns", "bytes", "solo tx", "mixed tx", "infl", "solo p99", "mixed p99", "p99 infl",
        "solo dom", "mixed dom", "infl"
    ));
    out.push_str(&"-".repeat(132));
    out.push('\n');
    for row in &r.rows {
        out.push_str(&format!(
            "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>6.2}x | {:>10} {:>10} {:>7.2}x | {:>10} {:>10} {:>6.2}x\n",
            row.class.name(),
            row.completed,
            fmt_bytes(row.bytes),
            fmt_ns(row.solo_tx_ns),
            fmt_ns(row.mixed_tx_ns),
            row.tx_inflation(),
            fmt_ns(row.solo_p99_ns),
            fmt_ns(row.mixed_p99_ns),
            row.p99_inflation(),
            fmt_ns(row.solo_domain_ns),
            fmt_ns(row.mixed_domain_ns),
            row.domain_inflation(),
        ));
    }
    out.push_str(&format!(
        "mixed makespan {} | {} events | peak link utilization {:.1}% | peak in-flight {}\n",
        fmt_ns(r.mixed_makespan_ns),
        r.mixed_events,
        100.0 * r.mixed_peak_utilization,
        r.peak_inflight
    ));
    // only printed when express dispatch actually fired: dense mixed
    // traffic rarely clears the peek gate, and the zero case keeps the
    // output (and the CI parity greps) byte-identical to pre-PR-10
    if r.fused_hops > 0 {
        out.push_str(&format!(
            "express dispatch: {} hops fused inline ({:.1}% of hop events)\n",
            r.fused_hops,
            100.0 * r.fusion_rate,
        ));
    }
    match &r.mode {
        // serial output stays byte-identical to what it always was
        ShardMode::Serial => {}
        ShardMode::Sharded { shards, pinned_sources } => {
            if r.optimistic_sources > 0 {
                out.push_str(&format!(
                    "backend: sharded ({shards} shards, {pinned_sources} pinned reactive \
                     sources, {} optimistic spanning sources, {} rollbacks)\n",
                    r.optimistic_sources, r.rollbacks
                ));
            } else {
                out.push_str(&format!(
                    "backend: sharded ({shards} shards, {pinned_sources} pinned reactive sources)\n"
                ));
            }
        }
        ShardMode::SerialFallback { reason } => {
            out.push_str(&format!("backend: serial fallback ({reason})\n"));
        }
    }
    // only a traced run mentions the recorder at all: untraced output
    // (including the RESULT line below) stays byte-identical
    if let Some(t) = &r.trace {
        out.push_str(&format!(
            "trace: {} spans ({} dropped), {} instants, {} gauges, overhead {:.3} ms\n",
            t.spans.len(),
            r.dropped_spans,
            t.instants.len(),
            t.gauges.len(),
            r.trace_overhead_ns / 1e6,
        ));
    }
    let p99 = |class: TrafficClass| r.row(class).map(MixedClassRow::p99_inflation).unwrap_or(1.0);
    out.push_str(&format!(
        "RESULT mixed max_tx_inflation={:.3} coherence_p99_inflation={:.3} tiering_p99_inflation={:.3} collective_p99_inflation={:.3}\n",
        r.max_tx_inflation(),
        p99(TrafficClass::Coherence),
        p99(TrafficClass::Tiering),
        p99(TrafficClass::Collective),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> MixedConfig {
        MixedConfig {
            coherence_ops: 800,
            tiering_ops: 200,
            collective_bytes: 8.0 * 1024.0 * 1024.0,
            ..Default::default()
        }
    }

    #[test]
    fn all_classes_complete_traffic() {
        let r = run_mixed(&small());
        for row in &r.rows {
            assert!(row.completed > 0, "{} moved no transactions", row.class.name());
            assert!(row.solo_tx_ns > 0.0 && row.mixed_tx_ns > 0.0);
            // tail percentiles populated, and p99 >= mean within histogram
            // bin resolution (~±4%)
            assert!(row.solo_p99_ns > 0.0 && row.mixed_p99_ns > 0.0);
            assert!(row.mixed_p99_ns > 0.9 * row.mixed_tx_ns, "{} p99 below mean", row.class.name());
        }
        assert!(r.mixed_makespan_ns > 0.0);
    }

    #[test]
    fn interference_is_measurable() {
        // the acceptance bar: concurrent classes on shared links must
        // inflate someone's latency — the effect the silo models
        // structurally could not produce
        let r = run_mixed(&small());
        assert!(
            r.max_tx_inflation() > 1.02,
            "no interference visible: max inflation {:.3}",
            r.max_tx_inflation()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_mixed(&small());
        let b = run_mixed(&small());
        assert_eq!(a.mixed_events, b.mixed_events);
        assert!((a.mixed_makespan_ns - b.mixed_makespan_ns).abs() < 1e-12);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert!((ra.mixed_tx_ns - rb.mixed_tx_ns).abs() < 1e-12);
        }
    }

    #[test]
    fn flat_ring_variant_runs() {
        let cfg = MixedConfig { shape: CollectiveShape::FlatRing, ..small() };
        let r = run_mixed(&cfg);
        assert!(r.row(TrafficClass::Collective).unwrap().completed > 0);
    }

    #[test]
    fn rack_rings_variant_runs() {
        let cfg = MixedConfig { shape: CollectiveShape::RackRings, ..small() };
        let r = run_mixed(&cfg);
        assert!(r.row(TrafficClass::Collective).unwrap().completed > 0);
        assert_eq!(r.mode, ShardMode::Serial);
    }

    /// The CI parity smoke in unit-test form: the rack-rings mixed point
    /// on the sharded backend reproduces the serial report — and with
    /// per-rack sharing domains and per-rack rings it must actually
    /// shard, pinning every reactive source, not fall back.
    #[test]
    fn rack_rings_sharded_matches_serial() {
        let base = MixedConfig { shape: CollectiveShape::RackRings, ..small() };
        let ser = run_mixed(&base);
        // explicit shard cap: independent of host core count
        let shr = run_mixed(&MixedConfig { sharded: true, shards: 4, ..base });
        match &shr.mode {
            ShardMode::Sharded { shards, pinned_sources } => {
                assert!(*shards >= 2, "rack-rings point collapsed to {shards} shard(s)");
                // 4 coherence domains + 4 rack rings, all closed-loop
                assert_eq!(*pinned_sources, 8);
            }
            m => panic!("rack-rings mixed point must shard, got {m:?}"),
        }
        assert_eq!(ser.mixed_events, shr.mixed_events);
        assert!((ser.mixed_makespan_ns - shr.mixed_makespan_ns).abs() < 1e-9);
        for (a, b) in ser.rows.iter().zip(&shr.rows) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.completed, b.completed);
            assert!((a.bytes - b.bytes).abs() < 1e-6);
            assert!(
                (a.mixed_tx_ns - b.mixed_tx_ns).abs() <= 1e-6 * a.mixed_tx_ns.max(1.0),
                "{}: mixed tx {} vs {}",
                a.class.name(),
                a.mixed_tx_ns,
                b.mixed_tx_ns
            );
            assert!((a.mixed_p99_ns - b.mixed_p99_ns).abs() <= 1e-6 * a.mixed_p99_ns.max(1.0));
            assert!((a.mixed_domain_ns - b.mixed_domain_ns).abs() <= 1e-6 * a.mixed_domain_ns.max(1.0));
        }
        // the line the CI smoke greps must be byte-identical
        let result_line = |s: &str| {
            s.lines().find(|l| l.starts_with("RESULT mixed")).map(String::from).unwrap()
        };
        assert_eq!(result_line(&render(&ser)), result_line(&render(&shr)));
        assert!(render(&shr).contains("backend: sharded ("));
    }

    /// The optimistic twin of `rack_rings_sharded_matches_serial`: a flat
    /// ring over every accelerator declares a pod-wide footprint, so the
    /// sharded backend must run it optimistically on the coordinator —
    /// not fall back to serial — and still reproduce the serial report.
    #[test]
    fn flat_ring_sharded_matches_serial_optimistically() {
        let base = MixedConfig { shape: CollectiveShape::FlatRing, ..small() };
        let ser = run_mixed(&base);
        let shr = run_mixed(&MixedConfig { sharded: true, shards: 4, ..base });
        match &shr.mode {
            ShardMode::Sharded { shards, .. } => {
                assert!(*shards >= 2, "flat-ring point collapsed to {shards} shard(s)");
            }
            m => panic!("flat-ring mixed point must shard optimistically, got {m:?}"),
        }
        assert_eq!(shr.optimistic_sources, 1, "the pod-wide ring must span");
        assert!(shr.checkpoints > 0, "spanning ring never gated a window");
        assert_eq!(ser.optimistic_sources, 0);
        assert_eq!(ser.mixed_events, shr.mixed_events);
        assert!((ser.mixed_makespan_ns - shr.mixed_makespan_ns).abs() < 1e-9);
        for (a, b) in ser.rows.iter().zip(&shr.rows) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.completed, b.completed);
            assert!((a.bytes - b.bytes).abs() < 1e-6);
            assert!(
                (a.mixed_tx_ns - b.mixed_tx_ns).abs() <= 1e-6 * a.mixed_tx_ns.max(1.0),
                "{}: mixed tx {} vs {}",
                a.class.name(),
                a.mixed_tx_ns,
                b.mixed_tx_ns
            );
            assert!((a.mixed_p99_ns - b.mixed_p99_ns).abs() <= 1e-6 * a.mixed_p99_ns.max(1.0));
        }
        let result_line = |s: &str| {
            s.lines().find(|l| l.starts_with("RESULT mixed")).map(String::from).unwrap()
        };
        assert_eq!(result_line(&render(&ser)), result_line(&render(&shr)));
        let rendered = render(&shr);
        assert!(rendered.contains("backend: sharded ("));
        assert!(rendered.contains("optimistic"), "render must flag the optimistic backend");
    }
}
