//! Figure 7: mean access latency of memory-intensive workloads vs working
//! set size, for the three configurations of §6:
//!
//! * **baseline** — XLink intra-rack, RDMA/InfiniBand beyond the rack;
//! * **accelerator clusters** — inter-cluster CXL replaces RDMA, but
//!   intra-cluster sharing is still non-coherent XLink;
//! * **tiered memory (ScalePool)** — coherence-centric CXL inside the
//!   cluster (tier-1) plus capacity-oriented CXL memory nodes (tier-2).
//!
//! Paper targets (shape): identical while the WS fits in one accelerator;
//! ~1.4x for ScalePool once the WS exceeds one accelerator; ~4.5x over
//! baseline and ~1.6x over accelerator-clusters once it exceeds a cluster.
//!
//! Latency parameters are *derived from the fabric model* (hop-counted
//! round trips on a built ScalePool topology), not hand-entered.

use crate::cluster::{InterCluster, Rack, ScalePoolBuilder, ScalePoolSystem, SystemConfig};
use crate::coherence::SoftwareCopyModel;
use crate::fabric::TopologyKind;
use crate::memory::access::{AccessPath, MemoryConfig};
use crate::memory::tier::TierSpec;
use crate::util::units::GB;
use crate::workloads::WorkingSetSweep;

/// Capacity anchors (full-scale GB200 NVL72 deployment).
pub const ACCEL_HBM: f64 = 192.0 * GB;
pub const CLUSTER_HBM: f64 = 72.0 * ACCEL_HBM;
/// Clusters in the modeled deployment (capacity of the "remote tier-1"
/// level in the baseline / accelerator-clusters configs).
pub const CLUSTERS: usize = 8;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub working_set: f64,
    pub baseline_ns: f64,
    pub acc_clusters_ns: f64,
    pub tiered_ns: f64,
}

impl Fig7Row {
    pub fn speedup_vs_baseline(&self) -> f64 {
        self.baseline_ns / self.tiered_ns
    }
    pub fn speedup_vs_acc_clusters(&self) -> f64 {
        self.acc_clusters_ns / self.tiered_ns
    }
}

/// Fabric-derived latency parameters for the three configurations.
#[derive(Clone, Debug)]
pub struct Fig7Params {
    /// Round trip acc -> peer acc in the same rack (64 B), ns.
    pub intra_rack_rt: f64,
    /// Round trip acc -> acc in another cluster over the CXL fabric, ns.
    pub inter_cluster_rt: f64,
    /// Round trip acc -> tier-2 memory node, ns.
    pub tier2_rt: f64,
    /// Amortized CXL.cache protocol overhead per access, ns.
    pub coherence_ns: f64,
}

impl Fig7Params {
    /// Derive from a built system (hop counts from real routed paths).
    /// Intra-rack tier-1 coherent access moves data on the XLink path
    /// (§5: "bulk data movements occur via XLink, while optimized
    /// implementations of CXL.cache handle only coherence transactions"),
    /// so its round trip is measured on a pure XLink rack.
    pub fn from_system(sys: &ScalePoolSystem) -> Fig7Params {
        use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
        let xrack = Topology::single_hop(8, LinkKind::NvLink5, "xrack");
        let accs = xrack.nodes_of(NodeKind::Accelerator);
        let xfab = Fabric::new(xrack);
        let intra = 2.0 * xfab.latency_ns(accs[0], accs[1], 64.0).unwrap();
        let inter = sys.inter_rack_rt_ns().expect(">= 2 racks");
        let tier2 = sys.tier2_rt_ns(0).expect("memory nodes present");
        Fig7Params {
            intra_rack_rt: intra,
            inter_cluster_rt: inter,
            tier2_rt: tier2,
            coherence_ns: 80.0,
        }
    }

    /// The reference system used for Figure 7 (4 clusters is enough to fix
    /// hop counts; capacities are taken at full scale via the constants).
    pub fn reference() -> Fig7Params {
        let sys = ScalePoolBuilder::new()
            .racks((0..4).map(|i| {
                Rack::homogeneous(&format!("rack{i}"), crate::cluster::Accelerator::b200(), 8).unwrap()
            }))
            .config(SystemConfig {
                inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
                mem_nodes: 4,
                mem_node_capacity: 64.0 * CLUSTER_HBM / 4.0,
                fabric_width: 2,
                direct_cxl_ports: true,
            })
            .build();
        Fig7Params::from_system(&sys)
    }
}

/// Build the three [`MemoryConfig`]s from fabric-derived parameters.
pub fn configs(p: &Fig7Params) -> [MemoryConfig; 3] {
    let remote_t1 = (CLUSTERS - 1) as f64 * CLUSTER_HBM;
    let xlink_sw = AccessPath::XlinkSwCopy(SoftwareCopyModel::xlink_intra_rack());

    let baseline = MemoryConfig {
        name: "baseline".into(),
        levels: vec![
            (TierSpec::tier1_local(ACCEL_HBM), AccessPath::LocalHbm),
            (TierSpec::tier1_remote(CLUSTER_HBM - ACCEL_HBM), xlink_sw),
            (
                TierSpec::tier1_remote(remote_t1),
                AccessPath::Rdma(SoftwareCopyModel::rdma_inter_cluster()),
            ),
        ],
    };

    let acc_clusters = MemoryConfig {
        name: "accelerator-clusters".into(),
        levels: vec![
            (TierSpec::tier1_local(ACCEL_HBM), AccessPath::LocalHbm),
            (TierSpec::tier1_remote(CLUSTER_HBM - ACCEL_HBM), xlink_sw),
            (
                TierSpec::tier1_remote(remote_t1),
                AccessPath::CxlCoherent {
                    fabric_rt_ns: p.inter_cluster_rt,
                    coherence_ns: p.coherence_ns,
                },
            ),
        ],
    };

    let tiered = MemoryConfig {
        name: "tiered-scalepool".into(),
        levels: vec![
            (TierSpec::tier1_local(ACCEL_HBM), AccessPath::LocalHbm),
            (
                TierSpec::tier1_remote(CLUSTER_HBM - ACCEL_HBM),
                AccessPath::CxlCoherent {
                    fabric_rt_ns: p.intra_rack_rt,
                    coherence_ns: p.coherence_ns,
                },
            ),
            (
                TierSpec::tier2(16.0 * CLUSTER_HBM),
                AccessPath::CxlTier2 { fabric_rt_ns: p.tier2_rt },
            ),
        ],
    };

    [baseline, acc_clusters, tiered]
}

/// Run the sweep.
pub fn run_fig7() -> Vec<Fig7Row> {
    let p = Fig7Params::reference();
    run_fig7_with(&p)
}

/// Sweep points are independent, so they are evaluated on scoped worker
/// threads (order-preserving — §Perf).
pub fn run_fig7_with(p: &Fig7Params) -> Vec<Fig7Row> {
    let [base, acc, tier] = configs(p);
    let points = WorkingSetSweep::sweep_points(ACCEL_HBM, CLUSTER_HBM, 8.0);
    crate::util::par::par_map(&points, |&ws| Fig7Row {
        working_set: ws,
        baseline_ns: base.mean_latency_ns(ws),
        acc_clusters_ns: acc.mean_latency_ns(ws),
        tiered_ns: tier.mean_latency_ns(ws),
    })
}

// ---------------------------------------------------------------------------
// detailed mode: the same sweep, event-driven on run_streamed
// ---------------------------------------------------------------------------

/// Knobs of the event-driven detailed mode: instead of the closed-form
/// waterfall, every access becomes a fabric transaction on a *built*
/// system for each of the three configurations (RDMA baseline /
/// CXL-joined accelerator clusters / ScalePool with tier-2 memory
/// nodes), streamed through [`MemSim::run_streamed`](crate::sim::MemSim)
/// — the working-set sweep and the traffic layer share one backend
/// end-to-end, and link-level queuing emerges instead of being assumed.
#[derive(Clone, Debug)]
pub struct Fig7DetailedConfig {
    pub racks: usize,
    pub accels: usize,
    /// Tier-2 memory nodes on the ScalePool system.
    pub mem_nodes: usize,
    /// Accesses per sweep point (per configuration).
    pub accesses: u64,
    /// Mean access interarrival, ns.
    pub interval_ns: f64,
    pub seed: u64,
    /// Run each point through the sharded conservative backend
    /// ([`MemSim::run_streamed_sharded`](crate::sim::MemSim::run_streamed_sharded)).
    pub sharded: bool,
}

impl Default for Fig7DetailedConfig {
    fn default() -> Self {
        Fig7DetailedConfig {
            racks: 4,
            accels: 8,
            mem_nodes: 4,
            accesses: 20_000,
            interval_ns: 10.0,
            seed: 7,
            sharded: false,
        }
    }
}

/// Event-driven Figure 7: sweep the same working-set points over three
/// built systems, measuring mean end-to-end access latency from the
/// streamed simulator. Points run on scoped worker threads (serial when
/// `sharded`, which parallelizes inside each point instead).
pub fn run_fig7_detailed(cfg: &Fig7DetailedConfig) -> Vec<Fig7Row> {
    use crate::memory::device::MemDevice;
    use crate::sim::{MemSim, TrafficSource};
    use crate::workloads::{WorkingSetTraffic, WorkingSetTrafficConfig};

    let build = |inter: InterCluster, mem_nodes: usize| {
        ScalePoolBuilder::new()
            .racks((0..cfg.racks).map(|i| {
                Rack::homogeneous(&format!("rack{i}"), crate::cluster::Accelerator::b200(), cfg.accels)
                    .unwrap()
            }))
            .config(SystemConfig { inter, mem_nodes, ..Default::default() })
            .build()
    };
    let base_sys = build(InterCluster::RdmaInfiniBand, 0);
    let acc_sys = build(InterCluster::Cxl(TopologyKind::MultiLevelClos), 0);
    let tier_sys = build(InterCluster::Cxl(TopologyKind::MultiLevelClos), cfg.mem_nodes);

    let hbm = MemDevice::Hbm3e.access_ns();
    let xlink_sw = SoftwareCopyModel::xlink_intra_rack().per_access_ns();
    let rdma_sw = SoftwareCopyModel::rdma_inter_cluster().per_access_ns();
    let coherence_ns = 80.0; // matches Fig7Params::reference()

    // (system, beyond-cluster targets, remote device ns, mid adder, far adder)
    let remote_accs = |sys: &ScalePoolSystem| -> Vec<usize> {
        sys.racks[1..].iter().flat_map(|r| r.acc_ids.iter().copied()).collect()
    };
    let shapes: [(&ScalePoolSystem, Vec<usize>, f64, f64, f64); 3] = [
        (&base_sys, remote_accs(&base_sys), MemDevice::Ddr5.access_ns(), xlink_sw, rdma_sw),
        (&acc_sys, remote_accs(&acc_sys), hbm, xlink_sw, coherence_ns),
        (&tier_sys, tier_sys.mem_nodes.clone(), MemDevice::CxlDram.access_ns(), coherence_ns, 0.0),
    ];

    // one sweep point of one configuration on an already-built simulator
    let run_one =
        |sim: &mut MemSim, shape: &(&ScalePoolSystem, Vec<usize>, f64, f64, f64), ws: f64| -> f64 {
            let (sys, remote, remote_dev, mid, far) = shape;
            let wcfg = WorkingSetTrafficConfig {
                working_set: ws,
                accel_capacity: ACCEL_HBM,
                cluster_capacity: CLUSTER_HBM,
                line_bytes: 64,
                interval_ns: cfg.interval_ns,
                accesses: cfg.accesses,
                seed: cfg.seed,
                hbm_ns: hbm,
                remote_device_ns: *remote_dev,
                mid_extra_ns: *mid,
                far_extra_ns: *far,
            };
            let mut src = WorkingSetTraffic::new(wcfg, sys.racks[0].acc_ids.clone(), remote.clone());
            let rep = {
                let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                if cfg.sharded {
                    sim.run_streamed_sharded(&mut sources)
                } else {
                    sim.run_streamed(&mut sources)
                }
            };
            assert_eq!(rep.total.completed, cfg.accesses, "detailed point dropped accesses");
            rep.total.latency.mean()
        };

    let points = WorkingSetSweep::sweep_points(ACCEL_HBM, CLUSTER_HBM, 8.0);

    // build each configuration's simulator ONCE; the largest working set
    // runs on the masters directly (it touches the most (src, dst) pairs,
    // warming the shared path arena), then freeze_paths publishes the
    // arena and every other sweep point is a cheap fork — the MemSim
    // masters are Sync, so forks happen on the worker threads
    let mut masters: [MemSim; 3] = [
        MemSim::new(&base_sys.fabric),
        MemSim::new(&acc_sys.fabric),
        MemSim::new(&tier_sys.fabric),
    ];
    let last_ws = *points.last().expect("sweep has points");
    let mut last_lat = [0.0f64; 3];
    for (k, shape) in shapes.iter().enumerate() {
        last_lat[k] = run_one(&mut masters[k], shape, last_ws);
        masters[k].freeze_paths();
    }
    let last_row = Fig7Row {
        working_set: last_ws,
        baseline_ns: last_lat[0],
        acc_clusters_ns: last_lat[1],
        tiered_ns: last_lat[2],
    };

    let point = |ws: f64| -> Fig7Row {
        let mut lat = [0.0f64; 3];
        for (k, shape) in shapes.iter().enumerate() {
            let mut sim = masters[k].fork();
            lat[k] = run_one(&mut sim, shape, ws);
        }
        Fig7Row { working_set: ws, baseline_ns: lat[0], acc_clusters_ns: lat[1], tiered_ns: lat[2] }
    };

    let rest = &points[..points.len() - 1];
    let mut rows: Vec<Fig7Row> = if cfg.sharded {
        rest.iter().map(|&ws| point(ws)).collect()
    } else {
        crate::util::par::par_map(rest, |&ws| point(ws))
    };
    rows.push(last_row);
    rows
}

/// Render the paper-style series.
pub fn render(rows: &[Fig7Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>14} | {:>12} {:>14} {:>12} | {:>12} {:>14}\n",
        "working set", "baseline", "acc-clusters", "tiered", "vs baseline", "vs acc-clusters"
    ));
    out.push_str(&"-".repeat(92));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:>14} | {:>10.0}ns {:>12.0}ns {:>10.0}ns | {:>10.2}x {:>12.2}x\n",
            crate::util::units::fmt_bytes(r.working_set),
            r.baseline_ns,
            r.acc_clusters_ns,
            r.tiered_ns,
            r.speedup_vs_baseline(),
            r.speedup_vs_acc_clusters(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_accelerator_all_equal() {
        let rows = run_fig7();
        for r in rows.iter().filter(|r| r.working_set <= ACCEL_HBM) {
            assert!((r.baseline_ns - r.tiered_ns).abs() < 1.0, "equal below HBM capacity");
            assert!((r.acc_clusters_ns - r.tiered_ns).abs() < 1.0);
        }
    }

    #[test]
    fn region2_scalepool_wins_about_1_4x() {
        // beyond one accelerator, within the cluster
        let rows = run_fig7();
        let r = rows.iter().find(|r| r.working_set == 16.0 * ACCEL_HBM).unwrap();
        let s = r.speedup_vs_baseline();
        assert!((1.15..=1.70).contains(&s), "region-2 speedup {s:.2} (paper 1.4)");
        // baseline and acc-clusters identical here (both XLink)
        assert!((r.baseline_ns - r.acc_clusters_ns).abs() < 1.0);
    }

    #[test]
    fn region3_speedups_match_paper_shape() {
        let rows = run_fig7();
        let r = rows.iter().find(|r| r.working_set == 8.0 * CLUSTER_HBM).unwrap();
        let vs_base = r.speedup_vs_baseline();
        let vs_acc = r.speedup_vs_acc_clusters();
        // measured: 4.07x / 2.10x at 8x cluster (paper: 4.5x / 1.6x)
        assert!((3.3..=5.5).contains(&vs_base), "vs baseline {vs_base:.2} (paper 4.5)");
        assert!((1.3..=2.6).contains(&vs_acc), "vs acc-clusters {vs_acc:.2} (paper 1.6)");
        assert!(vs_base > vs_acc, "ordering: baseline worst");
    }

    #[test]
    fn latency_monotone_per_config() {
        let rows = run_fig7();
        for w in rows.windows(2) {
            assert!(w[1].baseline_ns >= w[0].baseline_ns - 1e-9);
            assert!(w[1].acc_clusters_ns >= w[0].acc_clusters_ns - 1e-9);
            assert!(w[1].tiered_ns >= w[0].tiered_ns - 1e-9);
        }
    }

    #[test]
    fn detailed_mode_matches_paper_shape() {
        // the event-driven sweep must reproduce the closed-form figure's
        // structure: identical below one accelerator's HBM (all three
        // configs are local hits of the same access stream), ScalePool
        // ordering beyond the cluster boundary
        let cfg = Fig7DetailedConfig { accesses: 4_000, ..Default::default() };
        let rows = run_fig7_detailed(&cfg);
        assert_eq!(rows.len(), WorkingSetSweep::sweep_points(ACCEL_HBM, CLUSTER_HBM, 8.0).len());
        for r in rows.iter().filter(|r| r.working_set <= ACCEL_HBM) {
            assert!((r.baseline_ns - r.tiered_ns).abs() < 1e-9, "region 1 must be identical");
            assert!((r.acc_clusters_ns - r.tiered_ns).abs() < 1e-9);
        }
        let last = rows.last().unwrap();
        assert!(
            last.tiered_ns < last.acc_clusters_ns && last.acc_clusters_ns < last.baseline_ns,
            "region-3 ordering violated: {} / {} / {}",
            last.baseline_ns,
            last.acc_clusters_ns,
            last.tiered_ns
        );
        assert!(last.speedup_vs_baseline() > 1.5, "tier-2 win too small: {:.2}x", last.speedup_vs_baseline());
    }

    #[test]
    fn detailed_mode_deterministic_given_seed() {
        let cfg = Fig7DetailedConfig { accesses: 1_500, ..Default::default() };
        let a = run_fig7_detailed(&cfg);
        let b = run_fig7_detailed(&cfg);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.working_set, rb.working_set);
            assert!((ra.baseline_ns - rb.baseline_ns).abs() < 1e-12);
            assert!((ra.tiered_ns - rb.tiered_ns).abs() < 1e-12);
        }
    }

    #[test]
    fn params_derived_from_fabric_are_sane() {
        let p = Fig7Params::reference();
        assert!(p.intra_rack_rt < p.inter_cluster_rt);
        assert!(p.tier2_rt < p.inter_cluster_rt, "tier-2 is closer than a remote cluster");
        // "tens to hundreds of nanoseconds" fabric scale
        assert!(p.tier2_rt > 100.0 && p.tier2_rt < 5_000.0, "tier2 rt {}", p.tier2_rt);
    }
}
