//! Table 1: key differences among CXL, UALink and NVLink — regenerated
//! from the link models rather than hand-written, so the table stays
//! consistent with what the simulator actually does.

use crate::fabric::{LinkKind, SwitchParams};

/// One row (column in the paper's transposed layout) of Table 1.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub feature: &'static str,
    pub cxl: String,
    pub ualink: String,
    pub nvlink: String,
}

/// Endpoint-to-endpoint latency through each technology's *typical*
/// topology (Table 1 compares deployed latency classes, not raw wires):
/// XLink = one crossbar hop; CXL = a two-level PBR fabric + coherence.
fn typical_latency_ns(kind: LinkKind) -> f64 {
    let p = kind.params();
    let sw = SwitchParams::for_link(kind);
    match kind {
        LinkKind::NvLink5 | LinkKind::UaLink => {
            2.0 * p.message_latency_ns(256.0) + sw.traversal_ns()
        }
        _ => 3.0 * p.message_latency_ns(256.0) + 2.0 * sw.traversal_ns() + 80.0, // + CXL.cache
    }
}

fn latency_class(kind: LinkKind) -> String {
    let ns = typical_latency_ns(kind);
    if ns < 500.0 {
        format!("Very low ({ns:.0} ns)")
    } else if ns < 800.0 {
        format!("Low (sub-µs, {ns:.0} ns)")
    } else {
        format!("Medium ({ns:.0} ns)")
    }
}

/// Regenerate Table 1 from the models.
pub fn run_table1() -> Vec<Table1Row> {
    let (cxl, ua, nv) = (LinkKind::CxlCoherent, LinkKind::UaLink, LinkKind::NvLink5);
    let purpose = |k: LinkKind| {
        if k.is_cxl() { "Memory sharing" } else { "Accelerator comm." }.to_string()
    };
    let topo = |k: LinkKind| {
        let s = SwitchParams::for_link(k);
        if s.cascadable && s.pbr_ns > 0.0 {
            "Flexible fabric (PBR, cascading)".to_string()
        } else {
            k.topology_class().to_string()
        }
    };
    vec![
        Table1Row {
            feature: "Main purpose",
            cxl: purpose(cxl),
            ualink: purpose(ua),
            nvlink: purpose(nv),
        },
        Table1Row {
            feature: "Latency (256 B msg)",
            cxl: latency_class(cxl),
            ualink: latency_class(ua),
            nvlink: latency_class(nv),
        },
        Table1Row {
            feature: "Coherence",
            cxl: cxl.coherence().to_string(),
            ualink: ua.coherence().to_string(),
            nvlink: nv.coherence().to_string(),
        },
        Table1Row {
            feature: "Topology",
            cxl: topo(cxl),
            ualink: topo(ua),
            nvlink: topo(nv),
        },
        Table1Row {
            feature: "Compatibility",
            cxl: "Open standard".to_string(),
            ualink: "Vendor-neutral".to_string(),
            nvlink: "NVIDIA-centric".to_string(),
        },
        Table1Row {
            feature: "PHY",
            cxl: cxl.params().phy.name().to_string(),
            ualink: ua.params().phy.name().to_string(),
            nvlink: nv.params().phy.name().to_string(),
        },
        Table1Row {
            feature: "BW per port (GB/s)",
            cxl: format!("{:.0}", cxl.params().raw_bw),
            ualink: format!("{:.0}", ua.params().raw_bw),
            nvlink: format!("{:.0}", nv.params().raw_bw),
        },
    ]
}

/// Render as an aligned text table.
pub fn render(rows: &[Table1Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<20} | {:<32} | {:<28} | {:<28}\n",
        "Feature", "CXL", "UALink", "NVLink"
    ));
    out.push_str(&"-".repeat(116));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:<20} | {:<32} | {:<28} | {:<28}\n",
            r.feature, r.cxl, r.ualink, r.nvlink
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_paper_qualitative_claims() {
        let rows = run_table1();
        let get = |f: &str| rows.iter().find(|r| r.feature == f).unwrap().clone();
        assert_eq!(get("Main purpose").cxl, "Memory sharing");
        assert_eq!(get("Main purpose").nvlink, "Accelerator comm.");
        assert!(get("Coherence").cxl.contains("coherent"));
        assert_eq!(get("Coherence").ualink, "Non-coherent");
        assert!(get("Topology").cxl.contains("fabric"));
        assert_eq!(get("Topology").nvlink, "Single-hop");
        assert!(get("PHY").ualink.contains("Ethernet"));
        assert!(get("PHY").cxl.contains("PCIe"));
        // latency classes match the paper's Table 1 rows
        assert!(get("Latency (256 B msg)").nvlink.contains("Very low"));
        assert!(get("Latency (256 B msg)").ualink.contains("Low"));
        assert!(get("Latency (256 B msg)").cxl.contains("Medium"));
        assert!(
            typical_latency_ns(LinkKind::NvLink5) < typical_latency_ns(LinkKind::UaLink)
                && typical_latency_ns(LinkKind::UaLink) < typical_latency_ns(LinkKind::CxlCoherent)
        );
    }

    #[test]
    fn render_contains_all_rows() {
        let rows = run_table1();
        let s = render(&rows);
        for r in &rows {
            assert!(s.contains(r.feature));
        }
    }
}
