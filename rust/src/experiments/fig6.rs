//! Figure 6: LLM training execution time on ScalePool, normalized to the
//! RDMA baseline, for the five paper workloads — with the {communication,
//! computation, other} breakdown.
//!
//! Paper targets (shape): average speedup 1.22x, max 1.84x; inter-cluster
//! communication speedup 3.79x on average; compute identical; "other"
//! roughly constant.

use crate::calculon::execution::SystemProfile;
use crate::calculon::presets::{paper_workloads, Workload};
use crate::calculon::{ExecutionModel, TrainingEstimate};

/// One workload's result pair.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub name: String,
    pub gpus: usize,
    pub baseline: TrainingEstimate,
    pub scalepool: TrainingEstimate,
}

impl Fig6Row {
    pub fn speedup(&self) -> f64 {
        self.baseline.total_ns() / self.scalepool.total_ns()
    }
    pub fn comm_speedup(&self) -> f64 {
        let b = self.baseline.inter_cluster_comm_ns();
        let s = self.scalepool.inter_cluster_comm_ns();
        if s <= 0.0 {
            1.0
        } else {
            b / s
        }
    }
    /// Normalized stacked bars (baseline total = 1.0), paper layout.
    pub fn normalized(&self) -> [(f64, f64, f64); 2] {
        let t = self.baseline.total_ns();
        let b = self.baseline.breakdown();
        let s = self.scalepool.breakdown();
        [
            (b.comm_ns / t, b.compute_ns / t, b.other_ns / t),
            (s.comm_ns / t, s.compute_ns / t, s.other_ns / t),
        ]
    }
}

/// Aggregate over all workloads.
#[derive(Clone, Debug)]
pub struct Fig6Result {
    pub rows: Vec<Fig6Row>,
}

impl Fig6Result {
    pub fn avg_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup()).sum::<f64>() / self.rows.len() as f64
    }
    pub fn max_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup()).fold(0.0, f64::max)
    }
    pub fn avg_comm_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.comm_speedup()).sum::<f64>() / self.rows.len() as f64
    }
}

/// Run Figure 6 with the canonical profiles.
pub fn run_fig6() -> Fig6Result {
    run_fig6_with(SystemProfile::baseline_rdma(), SystemProfile::scalepool_cxl(), &paper_workloads())
}

/// Run Figure 6 with custom profiles / workloads (used by ablation benches).
/// Workloads are independent, so each row is estimated on its own scoped
/// worker thread (order-preserving — §Perf).
pub fn run_fig6_with(
    baseline: SystemProfile,
    scalepool: SystemProfile,
    workloads: &[Workload],
) -> Fig6Result {
    let bm = ExecutionModel::new(baseline);
    let sm = ExecutionModel::new(scalepool);
    let rows = crate::util::par::par_map(workloads, |w| Fig6Row {
        name: w.model.name.clone(),
        gpus: w.par.gpus(),
        baseline: bm.estimate(&w.model, &w.par),
        scalepool: sm.estimate(&w.model, &w.par),
    });
    Fig6Result { rows }
}

/// Render the paper-style table.
pub fn render(result: &Fig6Result) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>6} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9} {:>9} {:>9} | {:>7} {:>9}\n",
        "Model", "GPUs", "b.comm", "b.comp", "b.other", "b.total", "s.comm", "s.comp", "s.other",
        "s.total", "speedup", "comm-spdup"
    ));
    out.push_str(&"-".repeat(132));
    out.push('\n');
    let s = |ns: f64| format!("{:.2}s", ns / 1e9);
    for r in &result.rows {
        let b = r.baseline.breakdown();
        let sp = r.scalepool.breakdown();
        out.push_str(&format!(
            "{:<16} {:>6} | {:>9} {:>9} {:>9} {:>9} | {:>9} {:>9} {:>9} {:>9} | {:>6.2}x {:>8.2}x\n",
            r.name,
            r.gpus,
            s(b.comm_ns),
            s(b.compute_ns),
            s(b.other_ns),
            s(r.baseline.total_ns()),
            s(sp.comm_ns),
            s(sp.compute_ns),
            s(sp.other_ns),
            s(r.scalepool.total_ns()),
            r.speedup(),
            r.comm_speedup(),
        ));
    }
    out.push_str(&format!(
        "\naverage speedup {:.2}x (paper: 1.22x)   max {:.2}x (paper: 1.84x)   avg inter-cluster comm speedup {:.2}x (paper: 3.79x)\n",
        result.avg_speedup(),
        result.max_speedup(),
        result.avg_comm_speedup()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_rows() {
        assert_eq!(run_fig6().rows.len(), 5);
    }

    #[test]
    fn every_model_speeds_up() {
        for r in run_fig6().rows {
            assert!(r.speedup() > 1.0, "{} slowed down: {:.3}", r.name, r.speedup());
            assert!(r.speedup() < 3.0, "{} implausibly fast: {:.3}", r.name, r.speedup());
        }
    }

    #[test]
    fn calibration_bands_match_paper_shape() {
        let res = run_fig6();
        let avg = res.avg_speedup();
        let max = res.max_speedup();
        let comm = res.avg_comm_speedup();
        // measured: avg 1.36, max 1.50, comm 3.93 — same ordering and
        // magnitude class as the paper's 1.22 / 1.84 / 3.79 (see
        // EXPERIMENTS.md for the delta discussion: our pipeline-overlap
        // model is more conservative than the paper's, compressing the
        // spread between the least and most comm-bound workloads)
        assert!((1.15..=1.45).contains(&avg), "avg speedup {avg:.3} (paper 1.22)");
        assert!((1.40..=2.20).contains(&max), "max speedup {max:.3} (paper 1.84)");
        assert!((3.00..=4.80).contains(&comm), "comm speedup {comm:.3} (paper 3.79)");
    }

    #[test]
    fn compute_and_other_roughly_constant() {
        for r in run_fig6().rows {
            assert!((r.baseline.compute_ns - r.scalepool.compute_ns).abs() < 1e-3);
            let ob = r.baseline.other_ns();
            let os = r.scalepool.other_ns();
            assert!(os <= ob * 1.05, "{}: other grew {os} vs {ob}", r.name);
            assert!(os >= ob * 0.4, "{}: other collapsed {os} vs {ob}", r.name);
        }
    }

    #[test]
    fn gains_come_from_comm() {
        for r in run_fig6().rows {
            let total_gain = r.baseline.total_ns() - r.scalepool.total_ns();
            let comm_gain = r.baseline.comm_ns() - r.scalepool.comm_ns();
            assert!(
                comm_gain > 0.6 * total_gain,
                "{}: comm gain {comm_gain:.2e} not dominant in {total_gain:.2e}",
                r.name
            );
        }
    }
}
