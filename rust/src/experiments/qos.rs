//! The `qos` experiment: sweep fabric arbitration policies over the
//! pod-scale mixed scenario and report per-class solo-vs-mixed latency
//! inflation per policy. The `mixed` experiment measures cross-class
//! interference; this one shows the coordinator *acting* on it — strict
//! priority shrinks the coherence tail at the bulk classes' expense,
//! weighted-fair bounds collective starvation, and class-blind FCFS is
//! the parity baseline (its numbers reproduce `mixed` exactly, which the
//! CI smoke asserts).
//!
//! Workloads are rebuilt identically-seeded for every policy, so the
//! only difference between sweep points is the arbitration configuration
//! applied through the coordinator's [`QosManager`].

use super::mixed::{
    as_dyn_sources, build_system, coherence_sources, collective_sources, horizon_estimate,
    run_fork_traced, solo_baselines, tiering_source, MixedConfig,
};
use crate::coordinator::QosManager;
use crate::sim::{ArbPolicy, LinkTier, MemSim, StreamReport, TraceData, TrafficClass};

/// One policy point of the sweep.
#[derive(Clone, Debug)]
pub struct PolicySpec {
    /// Short name used in RESULT lines ("fcfs" / "strict" / "wfq").
    pub name: String,
    /// Applied uniformly across link tiers by the [`QosManager`].
    pub policy: ArbPolicy,
}

impl PolicySpec {
    pub fn fcfs() -> PolicySpec {
        PolicySpec { name: "fcfs".into(), policy: ArbPolicy::FcfsShared }
    }

    pub fn strict(order: [TrafficClass; 4]) -> PolicySpec {
        PolicySpec { name: "strict".into(), policy: ArbPolicy::StrictPriority(order) }
    }

    pub fn weighted(weights: [f64; 4]) -> PolicySpec {
        PolicySpec { name: "wfq".into(), policy: ArbPolicy::WeightedFair(weights) }
    }
}

/// Sweep configuration: the mixed scenario plus the policy list.
#[derive(Clone, Debug)]
pub struct QosSweepConfig {
    pub mixed: MixedConfig,
    pub policies: Vec<PolicySpec>,
}

impl Default for QosSweepConfig {
    fn default() -> QosSweepConfig {
        QosSweepConfig {
            mixed: MixedConfig::default(),
            policies: vec![
                PolicySpec::fcfs(),
                PolicySpec::strict(match ArbPolicy::strict_default() {
                    ArbPolicy::StrictPriority(order) => order,
                    _ => unreachable!(),
                }),
                PolicySpec::weighted(match ArbPolicy::weighted_default() {
                    ArbPolicy::WeightedFair(w) => w,
                    _ => unreachable!(),
                }),
            ],
        }
    }
}

/// Per-class outcome under one policy (solo baselines are shared across
/// policies — a single class alone on the fabric serves FIFO within its
/// one virtual channel under every policy, so solos are policy-invariant
/// and measured once under FCFS).
#[derive(Clone, Debug)]
pub struct QosClassRow {
    pub class: TrafficClass,
    pub completed: u64,
    pub bytes: f64,
    pub solo_tx_ns: f64,
    pub mixed_tx_ns: f64,
    pub solo_p50_ns: f64,
    pub mixed_p50_ns: f64,
    pub solo_p99_ns: f64,
    pub mixed_p99_ns: f64,
}

impl QosClassRow {
    pub fn tx_inflation(&self) -> f64 {
        if self.solo_tx_ns > 0.0 {
            self.mixed_tx_ns / self.solo_tx_ns
        } else {
            1.0
        }
    }

    pub fn p50_inflation(&self) -> f64 {
        if self.solo_p50_ns > 0.0 {
            self.mixed_p50_ns / self.solo_p50_ns
        } else {
            1.0
        }
    }

    pub fn p99_inflation(&self) -> f64 {
        if self.solo_p99_ns > 0.0 {
            self.mixed_p99_ns / self.solo_p99_ns
        } else {
            1.0
        }
    }
}

/// Per-tier service summary under one policy (from the per-link
/// [`StreamReport::qos`] telemetry).
#[derive(Clone, Copy, Debug)]
pub struct TierSummary {
    pub tier: LinkTier,
    /// Utilization of the busiest link direction in the tier.
    pub peak_utilization: f64,
    /// Payload bytes served per class, indexed by [`TrafficClass::index`].
    pub class_bytes: [f64; 4],
    /// Mean queueing delay across the tier's served transactions, ns.
    pub mean_queue_delay_ns: f64,
}

/// One policy's full outcome.
#[derive(Clone, Debug)]
pub struct QosPolicyRow {
    pub name: String,
    pub rows: Vec<QosClassRow>,
    pub makespan_ns: f64,
    pub events: u64,
    pub peak_utilization: f64,
    /// Hops express dispatch admitted inline (ISSUE 10) — 0 when the
    /// dense mixed traffic never cleared the peek gate.
    pub fused_hops: u64,
    /// Fraction of hop-level events that were fused.
    pub fusion_rate: f64,
    pub tiers: Vec<TierSummary>,
}

impl QosPolicyRow {
    /// Largest per-class mean-latency inflation — the same definition as
    /// `MixedReport::max_tx_inflation`, so the FCFS row is directly
    /// comparable to the `mixed` baseline (asserted by CI).
    pub fn max_tx_inflation(&self) -> f64 {
        self.rows.iter().map(QosClassRow::tx_inflation).fold(1.0, f64::max)
    }

    pub fn row(&self, class: TrafficClass) -> Option<&QosClassRow> {
        self.rows.iter().find(|r| r.class == class)
    }
}

/// The sweep result.
#[derive(Clone, Debug)]
pub struct QosReport {
    pub policies: Vec<QosPolicyRow>,
    /// Flight recording of the sweep's *last* policy point, when
    /// [`MixedConfig::trace`] was set — the point whose tail the sweep's
    /// final row describes, so "where did the p99 queueing happen" can be
    /// answered for it.
    pub trace: Option<TraceData>,
}

impl QosReport {
    pub fn policy(&self, name: &str) -> Option<&QosPolicyRow> {
        self.policies.iter().find(|p| p.name == name)
    }
}

fn tier_summaries(rep: &StreamReport, makespan_ns: f64) -> Vec<TierSummary> {
    let mut out: Vec<TierSummary> = Vec::new();
    for t in LinkTier::ALL {
        // busiest direction: total busy per (link, dir) within the tier
        let mut peak = 0.0f64;
        let mut class_bytes = [0.0f64; 4];
        let mut queued = 0.0f64;
        let mut served = 0u64;
        let mut dir_busy: std::collections::HashMap<(u32, u8), f64> = std::collections::HashMap::new();
        for s in rep.qos.iter().filter(|s| s.tier == t) {
            class_bytes[s.class.index()] += s.bytes;
            queued += s.queue_delay_ns;
            served += s.served;
            *dir_busy.entry((s.link, s.dir)).or_insert(0.0) += s.busy_ns;
        }
        if served == 0 {
            continue;
        }
        for &busy in dir_busy.values() {
            if makespan_ns > 0.0 {
                peak = peak.max((busy / makespan_ns).min(1.0));
            }
        }
        out.push(TierSummary {
            tier: t,
            peak_utilization: peak,
            class_bytes,
            mean_queue_delay_ns: queued / served as f64,
        });
    }
    out
}

/// Run the sweep: one set of solo baselines (FCFS — solos are
/// policy-invariant), then the mixed scenario once per policy with
/// identically-seeded workloads and the policy applied via the
/// coordinator's [`QosManager`].
pub fn run_qos(cfg: &QosSweepConfig) -> QosReport {
    let mcfg = &cfg.mixed;
    let sys = build_system(mcfg);
    let horizon = horizon_estimate(&sys, mcfg);

    // --- solo baselines (shared by every policy point) -------------------
    // build once, fork per point: the master carries the routing table
    // and warmed path arena every policy run below shares
    let mut master = MemSim::new(&sys.fabric);
    let [coh_solo, tier_solo, col_solo] = solo_baselines(&sys, mcfg, horizon, &mut master);

    // --- one mixed run per policy ----------------------------------------
    let mut policies = Vec::new();
    let mut trace: Option<TraceData> = None;
    let last = cfg.policies.len().saturating_sub(1);
    for (pi, spec) in cfg.policies.iter().enumerate() {
        let mgr = QosManager::uniform(spec.policy);
        let mut coh = coherence_sources(&sys, mcfg, horizon);
        let mut tier = tiering_source(&sys, mcfg, horizon);
        let mut col = collective_sources(&sys, mcfg);
        // only the last policy point records (one trace per sweep file)
        let tcfg = if pi == last { mcfg.trace } else { None };
        let (rep, util, tr) = {
            let mut sources = as_dyn_sources(&mut coh, &mut tier, &mut col);
            run_fork_traced(&master, &mut sources, Some(&mgr), false, 0, tcfg)
        };
        if tr.is_some() {
            trace = tr;
        }
        let row = |class: TrafficClass, (solo_tx, solo_p50, solo_p99): (f64, f64, f64)| {
            let c = rep.class(class);
            QosClassRow {
                class,
                completed: c.completed,
                bytes: c.bytes,
                solo_tx_ns: solo_tx,
                mixed_tx_ns: c.mean_ns(),
                solo_p50_ns: solo_p50,
                mixed_p50_ns: c.p50_ns(),
                solo_p99_ns: solo_p99,
                mixed_p99_ns: c.p99_ns(),
            }
        };
        policies.push(QosPolicyRow {
            name: spec.name.clone(),
            rows: vec![
                row(TrafficClass::Coherence, coh_solo),
                row(TrafficClass::Tiering, tier_solo),
                row(TrafficClass::Collective, col_solo),
            ],
            makespan_ns: rep.total.makespan_ns,
            events: rep.total.events,
            peak_utilization: util,
            fused_hops: rep.fused_hops,
            fusion_rate: rep.fusion_rate(),
            tiers: tier_summaries(&rep, rep.total.makespan_ns),
        });
    }
    QosReport { policies, trace }
}

/// Paper-style report plus the machine-readable RESULT lines.
pub fn render(r: &QosReport, specs: &[PolicySpec]) -> String {
    use crate::util::units::{fmt_bytes, fmt_ns};
    let mut out = String::new();
    for p in &r.policies {
        let desc = specs
            .iter()
            .find(|s| s.name == p.name)
            .map(|s| QosManager::uniform(s.policy).describe())
            .unwrap_or_default();
        out.push_str(&format!("=== policy {} ({desc}) ===\n", p.name));
        out.push_str(&format!(
            "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>7} | {:>10} {:>10} {:>8}\n",
            "class", "txns", "bytes", "solo tx", "mixed tx", "infl", "solo p99", "mixed p99", "p99 infl"
        ));
        out.push_str(&"-".repeat(104));
        out.push('\n');
        for row in &p.rows {
            out.push_str(&format!(
                "{:>11} | {:>9} {:>10} | {:>10} {:>10} {:>6.2}x | {:>10} {:>10} {:>7.2}x\n",
                row.class.name(),
                row.completed,
                fmt_bytes(row.bytes),
                fmt_ns(row.solo_tx_ns),
                fmt_ns(row.mixed_tx_ns),
                row.tx_inflation(),
                fmt_ns(row.solo_p99_ns),
                fmt_ns(row.mixed_p99_ns),
                row.p99_inflation(),
            ));
        }
        out.push_str(&format!(
            "makespan {} | {} events | peak link utilization {:.1}%\n",
            fmt_ns(p.makespan_ns),
            p.events,
            100.0 * p.peak_utilization
        ));
        // zero keeps the sweep output (and CI greps) byte-identical
        if p.fused_hops > 0 {
            out.push_str(&format!(
                "express dispatch: {} hops fused inline ({:.1}% of hop events)\n",
                p.fused_hops,
                100.0 * p.fusion_rate,
            ));
        }
        for t in &p.tiers {
            out.push_str(&format!(
                "  tier {:>11}: peak dir util {:>5.1}%, mean queue delay {:>10}, bytes coh/tier/col/gen = {}/{}/{}/{}\n",
                t.tier.name(),
                100.0 * t.peak_utilization,
                fmt_ns(t.mean_queue_delay_ns),
                fmt_bytes(t.class_bytes[0]),
                fmt_bytes(t.class_bytes[1]),
                fmt_bytes(t.class_bytes[2]),
                fmt_bytes(t.class_bytes[3]),
            ));
        }
    }
    // machine-readable: one line per (policy, class) for CI greps, one
    // summary line per policy for the BENCH_figs.json capture
    for p in &r.policies {
        for row in &p.rows {
            out.push_str(&format!(
                "RESULT qos policy={} class={} p99_inflation={:.3} tx_inflation={:.3}\n",
                p.name,
                row.class.name(),
                row.p99_inflation(),
                row.tx_inflation(),
            ));
        }
    }
    for p in &r.policies {
        let g = |class: TrafficClass, f: fn(&QosClassRow) -> f64| {
            p.row(class).map(f).unwrap_or(1.0)
        };
        out.push_str(&format!(
            "RESULT qos_{} max_tx_inflation={:.3} coherence_p99_inflation={:.3} tiering_p99_inflation={:.3} collective_p99_inflation={:.3}\n",
            p.name,
            p.max_tx_inflation(),
            g(TrafficClass::Coherence, QosClassRow::p99_inflation),
            g(TrafficClass::Tiering, QosClassRow::p99_inflation),
            g(TrafficClass::Collective, QosClassRow::p99_inflation),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> QosSweepConfig {
        QosSweepConfig {
            mixed: MixedConfig {
                coherence_ops: 800,
                tiering_ops: 200,
                collective_bytes: 8.0 * 1024.0 * 1024.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn sweep_runs_every_policy() {
        let r = run_qos(&small());
        assert_eq!(r.policies.len(), 3);
        for p in &r.policies {
            for row in &p.rows {
                assert!(row.completed > 0, "{}/{} moved nothing", p.name, row.class.name());
                assert!(row.solo_tx_ns > 0.0 && row.mixed_tx_ns > 0.0);
                assert!(row.mixed_p99_ns > 0.0);
            }
            assert!(p.makespan_ns > 0.0);
            assert!(!p.tiers.is_empty(), "{}: no tier telemetry", p.name);
        }
    }

    #[test]
    fn fcfs_point_reproduces_the_mixed_experiment() {
        // the parity anchor the CI smoke also checks end to end: the qos
        // sweep's FCFS mixed run is the mixed experiment's mixed run
        let cfg = small();
        let r = run_qos(&cfg);
        let m = super::super::mixed::run_mixed(&cfg.mixed);
        let fcfs = r.policy("fcfs").unwrap();
        assert_eq!(fcfs.events, m.mixed_events);
        assert!((fcfs.makespan_ns - m.mixed_makespan_ns).abs() < 1e-9);
        assert!((fcfs.max_tx_inflation() - m.max_tx_inflation()).abs() < 1e-12);
    }

    #[test]
    fn strict_priority_protects_coherence() {
        let r = run_qos(&small());
        let fcfs = r.policy("fcfs").unwrap();
        let strict = r.policy("strict").unwrap();
        let f = fcfs.row(TrafficClass::Coherence).unwrap().mixed_tx_ns;
        let s = strict.row(TrafficClass::Coherence).unwrap().mixed_tx_ns;
        // coherence never waits behind bulk classes under strict priority:
        // its mean latency under interference must not exceed FCFS (small
        // tolerance: arrival interleavings shift self-contention)
        assert!(s <= f * 1.05, "strict coherence {s} vs fcfs {f}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_qos(&small());
        let b = run_qos(&small());
        for (pa, pb) in a.policies.iter().zip(&b.policies) {
            assert_eq!(pa.events, pb.events);
            assert!((pa.makespan_ns - pb.makespan_ns).abs() < 1e-12);
        }
    }
}
