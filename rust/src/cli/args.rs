//! Minimal argument parser: one positional command plus `--key value` /
//! `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    command: Option<String>,
    opts: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(), // bare flag
                };
                out.opts.insert(key.to_string(), val);
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        Ok(out)
    }

    pub fn command(&self) -> Option<&str> {
        self.command.as_deref()
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not a number")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not a number")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_options() {
        let a = Args::parse(v(&["train", "--preset", "tiny", "--steps", "30", "--verbose"])).unwrap();
        assert_eq!(a.command(), Some("train"));
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 30);
        assert!(a.flag("verbose"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(v(&["fig6"])).unwrap();
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.get_or("preset", "tiny"), "tiny");
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(v(&["x", "--steps", "lots"])).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(Args::parse(v(&["a", "b"])).is_err());
    }
}
