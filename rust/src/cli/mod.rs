//! The `scalepool` CLI: hand-rolled argument parsing (clap is not in the
//! offline vendor set) and the subcommands that drive the experiment
//! harnesses, the topology tools, the event simulator and the PJRT
//! training runtime.

pub mod args;
pub mod commands;

pub use args::Args;

const USAGE: &str = "\
scalepool — hybrid XLink-CXL fabric simulator + LLM co-design framework

USAGE:
    scalepool <COMMAND> [OPTIONS]

COMMANDS:
    table1                     Regenerate Table 1 (link characteristics)
    fig6                       Regenerate Figure 6 (LLM training, 5 models)
    fig7      [--detailed] [--racks <N>] [--accels <N>] [--mem-nodes <N>]
              [--accesses <N>] [--interval <ns>] [--seed <N>] [--sharded]
                               Regenerate Figure 7 (tiered-memory sweep);
                               --detailed replays the sweep event-driven
                               through the streamed simulator (--sharded:
                               multi-core conservative backend)
    mixed     [--racks <N>] [--accels <N>] [--mem-nodes <N>] [--coh-ops <N>]
              [--tier-ops <N>] [--bytes <N>] [--repeats <N>]
              [--algo <hier|ring|rackrings>] [--sharded [--shards <N>]]
              [--seed <N>] [--out <file>] [--trace <file>
              [--trace-cap <N>] [--trace-interval <ns>]]
                               Coherence + tiering + collective traffic
                               concurrently on one fabric; per-class
                               mean and p99 latency under interference.
                               Coherence runs as per-rack sharing domains;
                               --algo rackrings runs one collective ring
                               per rack; --sharded runs the mixed point on
                               the multi-core conservative backend with
                               reactive sources pinned to the shard owning
                               their footprint (identical RESULT line);
                               --trace records hop-level spans + telemetry
                               and writes Chrome trace_event JSON
    qos       [same scenario options as mixed]
              [--policies <fcfs,strict,wfq>] [--order <c1,c2,c3,c4>]
              [--weights <w1,w2,w3,w4>] [--out <file>] [--trace <file>]
                               Sweep link-arbitration policies over the
                               mixed scenario: fcfs (class-blind parity
                               baseline), strict (priority order, default
                               coherence>tiering>collective>generic) and
                               wfq (deficit-round-robin byte shares in
                               class order coherence,tiering,collective,
                               generic; default 4,2,2,1). Reports
                               per-class solo-vs-mixed mean and p99
                               inflation per policy (RESULT qos lines);
                               --trace records the last policy point
    rails     [same scenario options as mixed]
              [--policies <det,spray,adaptive>] [--rails <K>] [--out <file>]
              [--trace <file>]
                               Sweep multi-rail routing policies over the
                               mixed scenario on a K-rail (default 4)
                               equal-cost multipath PBR table: det (rail
                               0, the single-path parity baseline), spray
                               (ECMP hash over src,dst,tx_seq) and
                               adaptive (least-backlogged candidate path
                               from live link state). Reports per-class
                               solo-vs-mixed inflation, path diversity
                               and link-utilization imbalance per policy
                               (RESULT rails lines)
    trace     [same scenario options as mixed] [--shards <N>]
              [--trace-cap <N>] [--trace-interval <ns>] [--buckets <N>]
              [--out <chrome.json>] [--series <series.json>]
                               Flight-recorder run of the mixed scenario
                               (flat-ring collective, sharded backend):
                               hop-level spans for every transaction,
                               periodic per-tier utilization/queue-depth
                               gauges and backend epoch/checkpoint/
                               rollback instants. Writes Chrome
                               trace_event JSON (default trace_chrome
                               .json; open in Perfetto) and per-tier
                               time-series JSON (default trace_series
                               .json)
    topo      --kind <clos|torus|dragonfly|rdma> --racks <N> [--accels <N>]
                               Build a fabric and print its shape/latencies
    simulate  --racks <N> --accels <N> --txs <N> [--bytes <N>] [--seed <N>]
              [--streamed] [--sharded [--shards <N>]]
                               Event-driven memory-access simulation
                               (--streamed: pull-based injection, O(peak
                               in-flight) memory; --sharded: one engine
                               per fabric domain across cores)
    train     --preset <tiny|small25m|base100m> --steps <N> [--seed <N>]
              [--artifacts <dir>] [--log-every <N>] [--out <file>]
                               End-to-end PJRT training under the emulated
                               cluster (hybrid emulation)
    smoke     [--artifacts <dir>]
                               Load + run the Pallas smoke artifact
    help                       Show this message

NOTE: train/smoke need the PJRT runtime (build with --features pjrt).
";

/// Entry point: parse and dispatch. Returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let mut args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return 2;
        }
    };
    let cmd = match args.command() {
        Some(c) => c.to_string(),
        None => {
            println!("{USAGE}");
            return 0;
        }
    };
    let result = match cmd.as_str() {
        "table1" => commands::table1(),
        "fig6" => commands::fig6(&mut args),
        "fig7" => commands::fig7(&mut args),
        "mixed" => commands::mixed(&mut args),
        "qos" => commands::qos(&mut args),
        "rails" => commands::rails(&mut args),
        "trace" => commands::trace(&mut args),
        "topo" => commands::topo(&mut args),
        "simulate" => commands::simulate(&mut args),
        "train" => commands::train(&mut args),
        "smoke" => commands::smoke(&mut args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
