//! Subcommand implementations.

use super::args::Args;
#[cfg(feature = "pjrt")]
use crate::calculon::Parallelism;
use crate::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
#[cfg(feature = "pjrt")]
use crate::coordinator::{EmulatedCluster, TrainJobScheduler};
use crate::experiments;
use crate::fabric::TopologyKind;
#[cfg(feature = "pjrt")]
use crate::runtime::{PjrtEngine, Trainer};
use crate::sim::{chrome_trace, time_series, MemSim, TraceConfig, TraceData, TrafficSource, Transaction};
use crate::workloads::SyntheticTraffic;
#[cfg(feature = "pjrt")]
use crate::util::error::{ensure, Context};
use crate::util::error::{bail, Error, Result};
use crate::util::units::{fmt_bytes, fmt_ns};
use crate::util::{Json, Rng};

pub fn table1() -> Result<()> {
    let rows = experiments::run_table1();
    print!("{}", experiments::table1::render(&rows));
    Ok(())
}

pub fn fig6(args: &mut Args) -> Result<()> {
    let res = experiments::run_fig6();
    print!("{}", experiments::fig6::render(&res));
    if let Some(path) = args.get("out") {
        let rows: Vec<Json> = res
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::str(&r.name)),
                    ("gpus", Json::num(r.gpus as f64)),
                    ("baseline_total_s", Json::num(r.baseline.total_ns() / 1e9)),
                    ("scalepool_total_s", Json::num(r.scalepool.total_ns() / 1e9)),
                    ("speedup", Json::num(r.speedup())),
                    ("comm_speedup", Json::num(r.comm_speedup())),
                ])
            })
            .collect();
        std::fs::write(path, Json::arr(rows).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

pub fn fig7(args: &mut Args) -> Result<()> {
    if args.flag("detailed") {
        // event-driven mode: the working-set sweep rides run_streamed
        // (optionally sharded), sharing the traffic layer's backend
        let cfg = experiments::Fig7DetailedConfig {
            racks: args.usize_or("racks", 4).map_err(Error::msg)?,
            accels: args.usize_or("accels", 8).map_err(Error::msg)?,
            mem_nodes: args.usize_or("mem-nodes", 4).map_err(Error::msg)?,
            accesses: args.usize_or("accesses", 20_000).map_err(Error::msg)? as u64,
            interval_ns: args.f64_or("interval", 10.0).map_err(Error::msg)?,
            seed: args.usize_or("seed", 7).map_err(Error::msg)? as u64,
            sharded: args.flag("sharded"),
        };
        let t0 = std::time::Instant::now();
        let rows = experiments::run_fig7_detailed(&cfg);
        print!("{}", experiments::fig7::render(&rows));
        println!("wall {:?}", t0.elapsed());
        if let Some(last) = rows.last() {
            println!(
                "RESULT fig7_detailed vs_baseline={:.3} vs_acc_clusters={:.3}",
                last.speedup_vs_baseline(),
                last.speedup_vs_acc_clusters()
            );
        }
        return Ok(());
    }
    let rows = experiments::run_fig7();
    print!("{}", experiments::fig7::render(&rows));
    Ok(())
}

pub fn mixed(args: &mut Args) -> Result<()> {
    let cfg = mixed_config(args)?;
    let t0 = std::time::Instant::now();
    let rep = experiments::run_mixed(&cfg);
    print!("{}", experiments::mixed::render(&rep));
    println!("wall {:?}", t0.elapsed());
    if let (Some(path), Some(t)) = (args.get("trace"), rep.trace.as_ref()) {
        write_chrome(path, t)?;
    }
    if let Some(path) = args.get("out") {
        let rows: Vec<Json> = rep
            .rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("class", Json::str(r.class.name())),
                    ("completed", Json::num(r.completed as f64)),
                    ("bytes", Json::num(r.bytes)),
                    ("solo_tx_ns", Json::num(r.solo_tx_ns)),
                    ("mixed_tx_ns", Json::num(r.mixed_tx_ns)),
                    ("tx_inflation", Json::num(r.tx_inflation())),
                    ("solo_p50_ns", Json::num(r.solo_p50_ns)),
                    ("mixed_p50_ns", Json::num(r.mixed_p50_ns)),
                    ("solo_p99_ns", Json::num(r.solo_p99_ns)),
                    ("mixed_p99_ns", Json::num(r.mixed_p99_ns)),
                    ("p99_inflation", Json::num(r.p99_inflation())),
                    ("solo_domain_ns", Json::num(r.solo_domain_ns)),
                    ("mixed_domain_ns", Json::num(r.mixed_domain_ns)),
                    ("domain_inflation", Json::num(r.domain_inflation())),
                ])
            })
            .collect();
        let out = Json::obj(vec![
            ("makespan_ns", Json::num(rep.mixed_makespan_ns)),
            ("events", Json::num(rep.mixed_events as f64)),
            ("peak_utilization", Json::num(rep.mixed_peak_utilization)),
            ("max_tx_inflation", Json::num(rep.max_tx_inflation())),
            ("classes", Json::Arr(rows)),
        ]);
        std::fs::write(path, out.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Flight-recorder knobs: `Some` only when `--trace <path>` asks for a
/// recording, so untraced runs keep the zero-cost disabled path.
fn trace_opt(args: &Args) -> Result<Option<TraceConfig>> {
    if args.get("trace").is_none() {
        return Ok(None);
    }
    let d = TraceConfig::default();
    Ok(Some(TraceConfig {
        capacity: args.usize_or("trace-cap", d.capacity).map_err(Error::msg)?,
        gauge_interval_ns: args.f64_or("trace-interval", d.gauge_interval_ns).map_err(Error::msg)?,
    }))
}

/// Write a recording as Chrome `trace_event` JSON (load in Perfetto or
/// `chrome://tracing`).
fn write_chrome(path: &str, data: &TraceData) -> Result<()> {
    std::fs::write(path, chrome_trace(data).to_string())?;
    println!("wrote {path}");
    Ok(())
}

/// Parse the shared mixed-scenario knobs (used by `mixed` and `qos`).
fn mixed_config(args: &Args) -> Result<experiments::MixedConfig> {
    let shape = match args.get_or("algo", "hier").as_str() {
        "hier" => experiments::CollectiveShape::Hierarchical,
        "ring" | "flatring" => experiments::CollectiveShape::FlatRing,
        "rackrings" => experiments::CollectiveShape::RackRings,
        other => bail!("unknown collective algo '{other}' (hier|ring|flatring|rackrings)"),
    };
    Ok(experiments::MixedConfig {
        racks: args.usize_or("racks", 4).map_err(Error::msg)?,
        accels: args.usize_or("accels", 8).map_err(Error::msg)?,
        mem_nodes: args.usize_or("mem-nodes", 4).map_err(Error::msg)?,
        coherence_ops: args.usize_or("coh-ops", 2_000).map_err(Error::msg)? as u64,
        tiering_ops: args.usize_or("tier-ops", 300).map_err(Error::msg)? as u64,
        collective_bytes: args.f64_or("bytes", 32.0 * 1024.0 * 1024.0).map_err(Error::msg)?,
        collective_repeats: args.usize_or("repeats", 1).map_err(Error::msg)?,
        shape,
        t1_bytes_per_acc: args.f64_or("t1-bytes", 2.0 * 1024.0 * 1024.0).map_err(Error::msg)?,
        sharded: args.flag("sharded"),
        shards: args.usize_or("shards", 0).map_err(Error::msg)?,
        seed: args.usize_or("seed", 7).map_err(Error::msg)? as u64,
        trace: trace_opt(args)?,
    })
}

fn parse_class(name: &str) -> Result<crate::sim::TrafficClass> {
    use crate::sim::TrafficClass;
    TrafficClass::ALL
        .into_iter()
        .find(|c| c.name() == name)
        .ok_or_else(|| Error::msg(format!("unknown traffic class '{name}' (coherence|tiering|collective|generic)")))
}

pub fn qos(args: &mut Args) -> Result<()> {
    use crate::sim::TrafficClass;
    let mixed = mixed_config(args)?;

    // strict order: highest-priority first, all four classes
    let order: [TrafficClass; 4] = {
        let spec = args.get_or("order", "coherence,tiering,collective,generic");
        let names: Vec<&str> = spec.split(',').collect();
        if names.len() != 4 {
            bail!("--order needs 4 comma-separated classes, got '{spec}'");
        }
        let mut order = [TrafficClass::Generic; 4];
        for (i, n) in names.iter().enumerate() {
            order[i] = parse_class(n.trim())?;
        }
        for i in 0..4 {
            for j in i + 1..4 {
                if order[i] == order[j] {
                    bail!("--order must name each class exactly once, got '{spec}'");
                }
            }
        }
        order
    };
    // weighted-fair byte shares in class-index order
    let weights: [f64; 4] = {
        let spec = args.get_or("weights", "4,2,2,1");
        let parts: Vec<&str> = spec.split(',').collect();
        if parts.len() != 4 {
            bail!("--weights needs 4 comma-separated numbers (coherence,tiering,collective,generic), got '{spec}'");
        }
        let mut w = [1.0f64; 4];
        for (i, p) in parts.iter().enumerate() {
            w[i] = p.trim().parse().map_err(|_| Error::msg(format!("--weights: '{p}' is not a number")))?;
            if !w[i].is_finite() || w[i] < 0.0 {
                bail!("--weights must be finite and >= 0, got '{p}'");
            }
        }
        w
    };
    let policies: Vec<experiments::PolicySpec> = args
        .get_or("policies", "fcfs,strict,wfq")
        .split(',')
        .map(|p| match p.trim() {
            "fcfs" => Ok(experiments::PolicySpec::fcfs()),
            "strict" => Ok(experiments::PolicySpec::strict(order)),
            "wfq" | "weighted" => Ok(experiments::PolicySpec::weighted(weights)),
            other => Err(Error::msg(format!("unknown policy '{other}' (fcfs|strict|wfq)"))),
        })
        .collect::<Result<_>>()?;

    let cfg = experiments::QosSweepConfig { mixed, policies };
    let t0 = std::time::Instant::now();
    let rep = experiments::run_qos(&cfg);
    print!("{}", experiments::qos::render(&rep, &cfg.policies));
    println!("wall {:?}", t0.elapsed());
    if let (Some(path), Some(t)) = (args.get("trace"), rep.trace.as_ref()) {
        write_chrome(path, t)?;
    }

    if let Some(path) = args.get("out") {
        let policies: Vec<Json> = rep
            .policies
            .iter()
            .map(|p| {
                let rows: Vec<Json> = p
                    .rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("class", Json::str(r.class.name())),
                            ("completed", Json::num(r.completed as f64)),
                            ("bytes", Json::num(r.bytes)),
                            ("solo_tx_ns", Json::num(r.solo_tx_ns)),
                            ("mixed_tx_ns", Json::num(r.mixed_tx_ns)),
                            ("tx_inflation", Json::num(r.tx_inflation())),
                            ("solo_p50_ns", Json::num(r.solo_p50_ns)),
                            ("mixed_p50_ns", Json::num(r.mixed_p50_ns)),
                            ("p50_inflation", Json::num(r.p50_inflation())),
                            ("solo_p99_ns", Json::num(r.solo_p99_ns)),
                            ("mixed_p99_ns", Json::num(r.mixed_p99_ns)),
                            ("p99_inflation", Json::num(r.p99_inflation())),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("policy", Json::str(&p.name)),
                    ("makespan_ns", Json::num(p.makespan_ns)),
                    ("events", Json::num(p.events as f64)),
                    ("peak_utilization", Json::num(p.peak_utilization)),
                    ("max_tx_inflation", Json::num(p.max_tx_inflation())),
                    ("classes", Json::Arr(rows)),
                ])
            })
            .collect();
        std::fs::write(path, Json::arr(policies).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

pub fn rails(args: &mut Args) -> Result<()> {
    let mixed = mixed_config(args)?;
    let rails = args.usize_or("rails", 4).map_err(Error::msg)?;
    if !(1..=crate::fabric::routing::MAX_RAILS).contains(&rails) {
        bail!("--rails must be in 1..={}, got {rails}", crate::fabric::routing::MAX_RAILS);
    }
    let policies: Vec<experiments::RailSpec> = args
        .get_or("policies", "det,spray,adaptive")
        .split(',')
        .map(|p| match p.trim() {
            "det" | "deterministic" => Ok(experiments::RailSpec::det()),
            "spray" | "hash" | "ecmp" => Ok(experiments::RailSpec::spray()),
            "adaptive" | "adapt" => Ok(experiments::RailSpec::adaptive()),
            other => Err(Error::msg(format!("unknown rail policy '{other}' (det|spray|adaptive)"))),
        })
        .collect::<Result<_>>()?;

    let cfg = experiments::RailsSweepConfig { mixed, rails, policies };
    let t0 = std::time::Instant::now();
    let rep = experiments::run_rails(&cfg);
    print!("{}", experiments::rails::render(&rep, cfg.rails));
    println!("wall {:?}", t0.elapsed());
    if let (Some(path), Some(t)) = (args.get("trace"), rep.trace.as_ref()) {
        write_chrome(path, t)?;
    }

    if let Some(path) = args.get("out") {
        let policies: Vec<Json> = rep
            .policies
            .iter()
            .map(|p| {
                let rows: Vec<Json> = p
                    .rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("class", Json::str(r.class.name())),
                            ("completed", Json::num(r.completed as f64)),
                            ("bytes", Json::num(r.bytes)),
                            ("solo_tx_ns", Json::num(r.solo_tx_ns)),
                            ("mixed_tx_ns", Json::num(r.mixed_tx_ns)),
                            ("tx_inflation", Json::num(r.tx_inflation())),
                            ("solo_p99_ns", Json::num(r.solo_p99_ns)),
                            ("mixed_p99_ns", Json::num(r.mixed_p99_ns)),
                            ("p99_inflation", Json::num(r.p99_inflation())),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("policy", Json::str(&p.name)),
                    ("makespan_ns", Json::num(p.makespan_ns)),
                    ("events", Json::num(p.events as f64)),
                    ("peak_utilization", Json::num(p.peak_utilization)),
                    ("max_tx_inflation", Json::num(p.max_tx_inflation())),
                    ("path_diversity", Json::num(p.path_diversity())),
                    ("util_imbalance", Json::num(p.util_imbalance)),
                    ("classes", Json::Arr(rows)),
                ])
            })
            .collect();
        std::fs::write(path, Json::arr(policies).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Record a mixed-traffic run with the flight recorder on and export both
/// trace formats. The scenario is pinned to the flat-ring collective on
/// the sharded backend (4 shards unless overridden): that combination is
/// guaranteed to cross shard boundaries optimistically, so the trace
/// carries epoch *and* checkpoint instants alongside hop spans from all
/// three traffic classes.
pub fn trace(args: &mut Args) -> Result<()> {
    let mut cfg = mixed_config(args)?;
    cfg.shape = experiments::CollectiveShape::FlatRing;
    cfg.sharded = true;
    if args.get("shards").is_none() {
        cfg.shards = 4;
    }
    let d = TraceConfig::default();
    cfg.trace = Some(TraceConfig {
        capacity: args.usize_or("trace-cap", d.capacity).map_err(Error::msg)?,
        gauge_interval_ns: args.f64_or("trace-interval", d.gauge_interval_ns).map_err(Error::msg)?,
    });

    let t0 = std::time::Instant::now();
    let rep = experiments::run_mixed(&cfg);
    print!("{}", experiments::mixed::render(&rep));
    println!("wall {:?}", t0.elapsed());

    let data = rep
        .trace
        .as_ref()
        .ok_or_else(|| Error::msg("trace run produced no recording"))?;
    write_chrome(&args.get_or("out", "trace_chrome.json"), data)?;
    let buckets = args.usize_or("buckets", 64).map_err(Error::msg)?.max(1);
    let series = args.get_or("series", "trace_series.json");
    std::fs::write(&series, time_series(data, buckets).to_string())?;
    println!("wrote {series}");
    Ok(())
}

fn build_system(kind: &str, racks: usize, accels: usize) -> Result<crate::cluster::ScalePoolSystem> {
    let inter = match kind {
        "clos" => InterCluster::Cxl(TopologyKind::MultiLevelClos),
        "torus" => InterCluster::Cxl(TopologyKind::Torus3d),
        "dragonfly" => InterCluster::Cxl(TopologyKind::DragonFly),
        "rdma" => InterCluster::RdmaInfiniBand,
        other => bail!("unknown fabric kind '{other}' (clos|torus|dragonfly|rdma)"),
    };
    Ok(ScalePoolBuilder::new()
        .racks(
            (0..racks)
                .map(|i| Rack::homogeneous(&format!("rack{i}"), Accelerator::b200(), accels).unwrap()),
        )
        .config(SystemConfig { inter, ..Default::default() })
        .build())
}

pub fn topo(args: &mut Args) -> Result<()> {
    let kind = args.get_or("kind", "clos");
    let racks = args.usize_or("racks", 4).map_err(Error::msg)?;
    let accels = args.usize_or("accels", 8).map_err(Error::msg)?;
    let sys = build_system(&kind, racks, accels)?;
    println!(
        "fabric '{kind}': {} nodes, {} links, {} racks x {accels} accelerators, {} memory nodes",
        sys.fabric.topo.nodes.len(),
        sys.fabric.topo.links.len(),
        sys.racks.len(),
        sys.mem_nodes.len()
    );
    sys.fabric.topo.validate_radix().map_err(Error::msg)?;
    println!("radix check: ok; connected: {}", sys.fabric.topo.is_connected());
    if racks >= 2 {
        println!(
            "intra-rack 64 B p2p: {}",
            fmt_ns(sys.acc_latency_ns((0, 0), (0, 1), 64.0))
        );
        println!(
            "inter-rack 64 B p2p: {}",
            fmt_ns(sys.acc_latency_ns((0, 0), (1, 0), 64.0))
        );
        println!(
            "inter-rack 1 MiB p2p: {}",
            fmt_ns(sys.acc_latency_ns((0, 0), (1, 0), 1024.0 * 1024.0))
        );
        if let Some(rt) = sys.tier2_rt_ns(0) {
            println!("tier-2 round trip (64 B): {}", fmt_ns(rt));
        }
        if let Some(bw) = sys.inter_rack_bw() {
            println!("inter-rack path bandwidth: {:.1} GB/s", bw);
        }
    }
    Ok(())
}

pub fn simulate(args: &mut Args) -> Result<()> {
    let racks = args.usize_or("racks", 2).map_err(Error::msg)?;
    let accels = args.usize_or("accels", 8).map_err(Error::msg)?;
    let txs = args.usize_or("txs", 10_000).map_err(Error::msg)?;
    let bytes = args.f64_or("bytes", 4096.0).map_err(Error::msg)?;
    let seed = args.usize_or("seed", 7).map_err(Error::msg)? as u64;
    let sys = build_system("clos", racks, accels)?;
    let all = sys.accelerators();

    if args.flag("streamed") || args.flag("sharded") {
        // streamed injection: transactions are generated as the clock
        // reaches them — memory stays O(peak in-flight) however large
        // --txs gets. --sharded streams one calendar engine per fabric
        // domain on its own core (conservative lookahead; open-loop only)
        let sharded = args.flag("sharded");
        let shards = args.usize_or("shards", crate::util::par::shards_for(usize::MAX)).map_err(Error::msg)?;
        let mut src =
            SyntheticTraffic::new(all, sys.mem_nodes.clone(), txs as u64, bytes, 50.0, seed);
        let t0 = std::time::Instant::now();
        let mut sim = MemSim::new(&sys.fabric);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, shards)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let wall = t0.elapsed();
        println!(
            "{} {} transactions of {} in {} simulated time (peak in-flight {})",
            if sharded { "sharded-streamed" } else { "streamed" },
            rep.total.completed,
            fmt_bytes(bytes),
            fmt_ns(rep.total.makespan_ns),
            rep.peak_inflight
        );
        println!(
            "latency: mean {} min {} max {}",
            fmt_ns(rep.total.latency.mean()),
            fmt_ns(rep.total.latency.min()),
            fmt_ns(rep.total.latency.max())
        );
        println!(
            "engine: {} events in {:?} ({:.2} M events/s); peak link utilization {:.1}%",
            rep.total.events,
            wall,
            rep.total.events as f64 / wall.as_secs_f64() / 1e6,
            100.0 * sim.peak_utilization(rep.total.makespan_ns)
        );
        return Ok(());
    }

    let mut rng = Rng::new(seed);
    let mut at = 0.0;
    let txv: Vec<Transaction> = (0..txs)
        .map(|_| {
            at += rng.exp(1.0 / 50.0);
            let src = all[rng.below(all.len() as u64) as usize];
            let dst = if !sys.mem_nodes.is_empty() && rng.f64() < 0.3 {
                sys.mem_nodes[rng.below(sys.mem_nodes.len() as u64) as usize]
            } else {
                let mut d = all[rng.below(all.len() as u64) as usize];
                while d == src {
                    d = all[rng.below(all.len() as u64) as usize];
                }
                d
            };
            Transaction { src, dst, at, bytes, device_ns: 130.0 }
        })
        .collect();

    let t0 = std::time::Instant::now();
    let mut sim = MemSim::new(&sys.fabric);
    let rep = sim.run(txv);
    let wall = t0.elapsed();
    println!(
        "simulated {} transactions of {} in {} simulated time",
        rep.completed,
        fmt_bytes(bytes),
        fmt_ns(rep.makespan_ns)
    );
    println!(
        "latency: mean {} min {} max {}",
        fmt_ns(rep.latency.mean()),
        fmt_ns(rep.latency.min()),
        fmt_ns(rep.latency.max())
    );
    println!(
        "engine: {} events in {:?} ({:.2} M events/s); peak link utilization {:.1}%",
        rep.events,
        wall,
        rep.events as f64 / wall.as_secs_f64() / 1e6,
        100.0 * sim.peak_utilization(rep.makespan_ns)
    );
    Ok(())
}

/// `smoke`/`train` need the PJRT runtime; without the `pjrt` feature they
/// fail with an actionable message instead of not existing.
#[cfg(not(feature = "pjrt"))]
pub fn smoke(_args: &mut Args) -> Result<()> {
    bail!("the 'smoke' command needs the PJRT runtime: rebuild with --features pjrt (requires the xla crate)")
}

#[cfg(not(feature = "pjrt"))]
pub fn train(_args: &mut Args) -> Result<()> {
    bail!("the 'train' command needs the PJRT runtime: rebuild with --features pjrt (requires the xla crate)")
}

#[cfg(feature = "pjrt")]
pub fn smoke(args: &mut Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let engine = PjrtEngine::cpu()?;
    println!("PJRT platform: {} ({} devices)", engine.platform(), engine.device_count());
    let exe = engine.load_hlo(&dir.join("smoke.hlo.txt"))?;
    let x = crate::runtime::pjrt::lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2])?;
    let y = crate::runtime::pjrt::lit_f32(&[1.0, 1.0, 1.0, 1.0], &[2, 2])?;
    let out = engine.run(&exe, &[x, y])?;
    let v = out[0].to_vec::<f32>()?;
    ensure!(v == vec![5.0, 5.0, 9.0, 9.0], "smoke mismatch: {v:?}");
    println!("smoke (Pallas tiled matmul via AOT HLO): {v:?} — OK");
    Ok(())
}

#[cfg(feature = "pjrt")]
pub fn train(args: &mut Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny");
    let steps = args.usize_or("steps", 30).map_err(Error::msg)?;
    let seed = args.usize_or("seed", 0).map_err(Error::msg)? as i32;
    let log_every = args.usize_or("log-every", 10).map_err(Error::msg)?.max(1);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    let trainer = Trainer::load(&dir, &preset)
        .with_context(|| format!("loading preset '{preset}' from {}", dir.display()))?;
    let m = trainer.manifest().clone();
    println!(
        "preset {}: {:.1}M params, batch {} x seq {}, state {}",
        m.preset,
        m.param_count as f64 / 1e6,
        m.batch,
        m.seq,
        fmt_bytes((m.param_count * 12) as f64)
    );

    // emulate the paper-scale deployment this model would train on
    let cluster = EmulatedCluster::for_preset(
        m.vocab,
        768,
        12,
        12,
        m.seq,
        512,
        Parallelism { tp: 8, pp: 4, dp: 16, microbatch: 1 },
    );
    let mut sched = TrainJobScheduler::new(trainer, cluster, 42);
    sched.init(seed)?;

    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < steps {
        let chunk = log_every.min(steps - done);
        sched.run(chunk)?;
        done += chunk;
        let log = sched.log();
        let last = log.last().unwrap();
        let window = &log[log.len().saturating_sub(chunk)..];
        let avg_loss: f32 = window.iter().map(|l| l.loss).sum::<f32>() / window.len() as f32;
        println!(
            "step {:>5}  loss {:.4} (avg {:.4})  pjrt {}  emulated: baseline {} scalepool {}  speedup {:.2}x",
            last.step,
            last.loss,
            avg_loss,
            fmt_ns(last.compute_wall_ns as f64),
            fmt_ns(last.baseline_step_ns),
            fmt_ns(last.scalepool_step_ns),
            sched.emulated_speedup()
        );
    }
    let wall = t0.elapsed();
    let log = sched.log();
    println!(
        "\ntrained {} steps in {:.1}s wall ({:.2}s/step); loss {:.4} -> {:.4}; emulated ScalePool speedup {:.2}x",
        steps,
        wall.as_secs_f64(),
        wall.as_secs_f64() / steps as f64,
        log.first().unwrap().loss,
        log.last().unwrap().loss,
        sched.emulated_speedup()
    );

    if let Some(path) = args.get("out") {
        let rows: Vec<Json> = log
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("step", Json::num(l.step as f64)),
                    ("loss", Json::num(l.loss as f64)),
                    ("pjrt_ns", Json::num(l.compute_wall_ns as f64)),
                ])
            })
            .collect();
        std::fs::write(path, Json::arr(rows).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
