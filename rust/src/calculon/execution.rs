//! The per-step execution-time estimate: compute, TP/PP/DP communication,
//! pipeline bubble and offload exposure — the quantities behind Figure 6.
//!
//! Breakdown semantics follow the paper (§6): *"tensor parallelism
//! communication within clusters occurs through NVLink, whereas pipeline
//! and data parallelism communications across clusters utilize InfiniBand
//! or CXL. Computation time represents the sum of GPU execution times for
//! forward pass, backward pass, and optimizer steps. The other time
//! category ... includes pipeline bubble and offloading overheads."*

use super::llm::LlmModel;
use super::parallelism::Parallelism;
use crate::collective::{Algorithm, CollectiveModel, Transport};

/// Where communication happens for a system configuration.
#[derive(Clone, Debug)]
pub struct SystemProfile {
    pub name: String,
    pub rack_size: usize,
    /// Peak dense bf16 per accelerator, TFLOP/s.
    pub gpu_tflops: f64,
    /// Achieved model-FLOP utilization.
    pub mfu: f64,
    /// Intra-rack XLink transport (TP traffic and intra-rack PP/DP).
    pub intra_rack: Transport,
    /// Inter-rack transport (IB+RDMA for the baseline, CXL for ScalePool).
    pub inter_rack: Transport,
    /// Offload path bandwidth per GPU (weights/optimizer), bytes/ns.
    pub offload_bw: f64,
    /// Fixed software cost of the offload path per step, ns.
    pub offload_sw_ns: f64,
}

/// {comm, compute, other} in ns — Figure 6's three stacked categories.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub comm_ns: f64,
    pub compute_ns: f64,
    pub other_ns: f64,
}

impl Breakdown {
    pub fn total_ns(&self) -> f64 {
        self.comm_ns + self.compute_ns + self.other_ns
    }
}

/// Full estimate of one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainingEstimate {
    pub compute_ns: f64,
    /// TP all-reduces (always intra-rack XLink).
    pub tp_comm_ns: f64,
    /// Pipeline boundary sends, split by locality.
    pub pp_intra_ns: f64,
    pub pp_inter_ns: f64,
    /// Data-parallel gradient reduction.
    pub dp_comm_ns: f64,
    /// Pipeline fill/drain bubble.
    pub bubble_ns: f64,
    /// Offload traffic not hidden behind compute.
    pub offload_ns: f64,
}

impl TrainingEstimate {
    pub fn comm_ns(&self) -> f64 {
        self.tp_comm_ns + self.pp_intra_ns + self.pp_inter_ns + self.dp_comm_ns
    }
    /// Inter-cluster communication only (the paper's 3.79x claim is on
    /// this component).
    pub fn inter_cluster_comm_ns(&self) -> f64 {
        self.pp_inter_ns + self.dp_comm_ns
    }
    pub fn other_ns(&self) -> f64 {
        self.bubble_ns + self.offload_ns
    }
    pub fn total_ns(&self) -> f64 {
        self.compute_ns + self.comm_ns() + self.other_ns()
    }
    pub fn breakdown(&self) -> Breakdown {
        Breakdown { comm_ns: self.comm_ns(), compute_ns: self.compute_ns, other_ns: self.other_ns() }
    }
}

/// The estimator.
#[derive(Clone, Debug)]
pub struct ExecutionModel {
    pub profile: SystemProfile,
}

impl ExecutionModel {
    pub fn new(profile: SystemProfile) -> Self {
        ExecutionModel { profile }
    }

    /// Estimate one training step of `model` under `par`.
    pub fn estimate(&self, model: &LlmModel, par: &Parallelism) -> TrainingEstimate {
        let p = &self.profile;
        let gpus = par.gpus() as f64;
        let m = par.microbatches(model.global_batch) as f64;

        // ---- compute: fwd + bwd + optimizer (3x fwd FLOPs + head), even
        // split over all GPUs at the achieved MFU
        let step_flops = 3.0
            * (model.fwd_flops_per_seq() + model.head_flops_per_seq())
            * model.global_batch as f64;
        let flops_per_gpu = step_flops / gpus;
        let flops_per_ns = p.gpu_tflops * 1e3 * p.mfu; // TFLOP/s -> flops/ns
        let compute_ns = flops_per_gpu / flops_per_ns;

        // ---- TP: 4 all-reduces per layer per microbatch over the TP
        // group, on intra-rack XLink
        let tp_comm_ns = if par.tp > 1 {
            let coll = CollectiveModel::flat(p.intra_rack);
            let per = coll.all_reduce(par.tp, model.tp_allreduce_bytes(par.microbatch), Algorithm::Ring);
            let layers_per_stage = (model.layers as f64 / par.pp as f64).ceil();
            4.0 * layers_per_stage * m * per
        } else {
            0.0
        };

        // ---- PP: 2 sends (fwd activation, bwd grad) per microbatch per
        // boundary; boundaries split into intra-rack and cross-rack
        let (pp_intra_ns, pp_inter_ns) = if par.pp > 1 {
            let bytes = model.boundary_bytes(par.microbatch);
            let cross = par.cross_rack_boundaries(p.rack_size) as f64;
            let intra = (par.pp - 1) as f64 - cross;
            let intra_coll = CollectiveModel::flat(p.intra_rack);
            let inter_coll = CollectiveModel::flat(p.inter_rack);
            // steady-state pipeline: each microbatch crosses every
            // boundary, transfers on different boundaries overlap; the
            // critical path is m transits of the slowest boundary plus one
            // fill traversal. We charge m x (per-boundary time) for the
            // cross-rack class and fill-only for the intra class when a
            // slower class exists (conservative middle ground).
            let intra_t = 2.0 * m * intra * intra_coll.p2p(bytes) / (par.pp as f64 - 1.0).max(1.0)
                + intra * intra_coll.p2p(bytes);
            let inter_t = if cross > 0.0 {
                2.0 * m * inter_coll.p2p(bytes) + cross * inter_coll.p2p(bytes)
            } else {
                0.0
            };
            (intra_t, inter_t)
        } else {
            (0.0, 0.0)
        };

        // ---- DP: gradient reduce-scatter + all-gather (ZeRO-style) over
        // the DP group; crosses racks whenever the job does
        let dp_comm_ns = if par.dp > 1 {
            let shard_bytes = model.grad_bytes() / (par.tp * par.pp) as f64;
            if par.dp_crosses_racks(p.rack_size) {
                let coll = CollectiveModel::flat(p.inter_rack);
                coll.all_reduce(par.dp, shard_bytes, Algorithm::Ring)
            } else {
                let coll = CollectiveModel::flat(p.intra_rack);
                coll.all_reduce(par.dp, shard_bytes, Algorithm::Ring)
            }
        } else {
            0.0
        };

        // ---- bubble: (pp-1)/m of the per-microbatch busy time; reduced
        // PP comm shrinks it ("reduced pipeline parallelism communication
        // time marginally decreases pipeline bubble durations")
        let busy = compute_ns + tp_comm_ns + pp_intra_ns + pp_inter_ns;
        let bubble_ns = if par.pp > 1 { (par.pp as f64 - 1.0) / m * (busy / par.pp as f64) } else { 0.0 };

        // ---- offload (weights + optimizer states, ZeRO-offload style):
        // traffic per GPU per step, overlapped with compute; only the
        // exposed part counts, plus the fixed software cost
        let state_per_gpu = model.state_bytes() / gpus;
        let offload_traffic_ns = 2.0 * state_per_gpu / p.offload_bw;
        let offload_ns = (offload_traffic_ns - 0.5 * compute_ns).max(0.0) + p.offload_sw_ns;

        TrainingEstimate {
            compute_ns,
            tp_comm_ns,
            pp_intra_ns,
            pp_inter_ns,
            dp_comm_ns,
            bubble_ns,
            offload_ns,
        }
    }
}

// ---------------------------------------------------------------------------
// canonical system profiles (Figure 6's two configurations)
// ---------------------------------------------------------------------------

/// NVLink5 intra-rack transport shared by both configurations.
fn nvlink_transport() -> Transport {
    Transport {
        base_latency_ns: 450.0,
        sw_overhead_ns: 350.0, // NCCL kernel launch amortized per step
        bw: 900.0,
        bw_efficiency: 0.85,
    }
}

impl SystemProfile {
    /// The paper's baseline: NVL72 racks + InfiniBand NDR with RDMA.
    /// Inter-rack effective bandwidth reflects the scale-out software
    /// path: staging copies across computing domains, (de)serialization,
    /// and communicator synchronization (§1, §6).
    pub fn baseline_rdma() -> SystemProfile {
        SystemProfile {
            name: "baseline-rdma".into(),
            rack_size: 72,
            gpu_tflops: 2_250.0,
            mfu: 0.55,
            intra_rack: nvlink_transport(),
            inter_rack: Transport {
                base_latency_ns: 2_000.0,
                sw_overhead_ns: 5_000.0,
                bw: 50.0,          // one NDR 400 NIC per GPU
                bw_efficiency: 0.30, // bounce copies across domains + serde
            },
            offload_bw: 450.0, // Grace C2C per GPU
            offload_sw_ns: 200_000.0,
        }
    }

    /// ScalePool: same racks, inter-rack over the hierarchical CXL fabric
    /// (hardware coherent, no software on the data path).
    pub fn scalepool_cxl() -> SystemProfile {
        SystemProfile {
            name: "scalepool-cxl".into(),
            rack_size: 72,
            gpu_tflops: 2_250.0,
            mfu: 0.55,
            intra_rack: nvlink_transport(),
            inter_rack: Transport {
                base_latency_ns: 900.0, // 3 CXL switch hops
                sw_overhead_ns: 300.0,
                bw: 64.0,           // one CXL x16 port per GPU
                bw_efficiency: 0.92, // direct device-to-device
            },
            offload_bw: 380.0, // 3 dedicated CXL ports to the tier-2 pool
            offload_sw_ns: 150_000.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt3() -> (LlmModel, Parallelism) {
        (
            LlmModel {
                name: "gpt3".into(),
                layers: 96,
                hidden: 12288,
                heads: 96,
                seq: 2048,
                vocab: 50257,
                global_batch: 1536,
                mlp_mult: 4,
            },
            Parallelism { tp: 8, pp: 8, dp: 16, microbatch: 1 },
        )
    }

    #[test]
    fn compute_identical_across_configs() {
        let (m, p) = gpt3();
        let b = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p);
        let s = ExecutionModel::new(SystemProfile::scalepool_cxl()).estimate(&m, &p);
        assert!((b.compute_ns - s.compute_ns).abs() < 1e-6);
        assert!((b.tp_comm_ns - s.tp_comm_ns).abs() < 1e-6, "TP comm is NVLink in both");
    }

    #[test]
    fn compute_time_plausible_for_gpt3() {
        let (m, p) = gpt3();
        let e = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p);
        let s = e.compute_ns / 1e9;
        // GPT-3 @1536 batch on 1024 B200s at 50% MFU: O(seconds) per step
        assert!(s > 0.5 && s < 20.0, "compute {s} s");
    }

    #[test]
    fn scalepool_reduces_inter_cluster_comm() {
        let (m, p) = gpt3();
        let b = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p);
        let s = ExecutionModel::new(SystemProfile::scalepool_cxl()).estimate(&m, &p);
        assert!(b.inter_cluster_comm_ns() > 2.0 * s.inter_cluster_comm_ns());
        assert!(b.total_ns() > s.total_ns());
    }

    #[test]
    fn no_pipeline_no_bubble() {
        let (m, mut p) = gpt3();
        p.pp = 1;
        p.dp = 128;
        let e = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p);
        assert_eq!(e.bubble_ns, 0.0);
        assert_eq!(e.pp_intra_ns + e.pp_inter_ns, 0.0);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let (m, p) = gpt3();
        let e = ExecutionModel::new(SystemProfile::scalepool_cxl()).estimate(&m, &p);
        let bd = e.breakdown();
        assert!((bd.total_ns() - e.total_ns()).abs() < 1e-6);
    }

    #[test]
    fn more_dp_more_inter_comm_latency_share() {
        let (m, p) = gpt3();
        let mut p2 = p;
        p2.dp = 64;
        let e1 = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p);
        let e2 = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&m, &p2);
        // ring steps grow with dp: more per-message overhead exposure
        assert!(e2.dp_comm_ns > e1.dp_comm_ns * 0.5);
    }
}
