//! Parallelism mapping: TP x PP x DP onto racks of accelerators, with the
//! rack-boundary analysis that decides which traffic stays on XLink and
//! which crosses the inter-cluster network (IB in the baseline, CXL in
//! ScalePool).

/// A 3-way parallelism configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Tensor-parallel degree (always mapped inside a rack).
    pub tp: usize,
    /// Pipeline-parallel degree.
    pub pp: usize,
    /// Data-parallel degree.
    pub dp: usize,
    /// Microbatch size (sequences).
    pub microbatch: usize,
}

impl Parallelism {
    pub fn gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }

    /// Microbatches per step per pipeline given the global batch.
    pub fn microbatches(&self, global_batch: usize) -> usize {
        (global_batch / (self.dp * self.microbatch)).max(1)
    }

    /// Pipeline stages resident per rack of `rack_size` accelerators
    /// (TP groups are never split across racks).
    pub fn stages_per_rack(&self, rack_size: usize) -> usize {
        (rack_size / self.tp).max(1).min(self.pp)
    }

    /// Number of pipeline-stage boundaries that cross a rack boundary.
    pub fn cross_rack_boundaries(&self, rack_size: usize) -> usize {
        let spr = self.stages_per_rack(rack_size);
        if self.pp <= spr {
            0
        } else {
            self.pp.div_ceil(spr) - 1
        }
    }

    /// Does the data-parallel all-reduce cross racks? It does whenever the
    /// job spans more than one rack: replica packing is not rack-aligned,
    /// so DP ring neighbors land in different racks.
    pub fn dp_crosses_racks(&self, rack_size: usize) -> bool {
        self.dp > 1 && self.gpus() > rack_size
    }

    /// Racks needed for the whole job.
    pub fn racks_needed(&self, rack_size: usize) -> usize {
        (self.gpus() as f64 / rack_size as f64).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NVL72: usize = 72;

    #[test]
    fn gpu_accounting() {
        let p = Parallelism { tp: 8, pp: 8, dp: 16, microbatch: 1 };
        assert_eq!(p.gpus(), 1024);
        assert_eq!(p.racks_needed(NVL72), 15);
    }

    #[test]
    fn microbatch_count() {
        let p = Parallelism { tp: 8, pp: 8, dp: 16, microbatch: 2 };
        assert_eq!(p.microbatches(1536), 48);
    }

    #[test]
    fn stage_rack_mapping() {
        // tp=8 -> 9 stages fit per 72-GPU rack
        let p = Parallelism { tp: 8, pp: 16, dp: 1, microbatch: 1 };
        assert_eq!(p.stages_per_rack(NVL72), 9);
        assert_eq!(p.cross_rack_boundaries(NVL72), 1);
    }

    #[test]
    fn small_pipeline_stays_in_rack() {
        let p = Parallelism { tp: 8, pp: 8, dp: 4, microbatch: 1 };
        assert_eq!(p.cross_rack_boundaries(NVL72), 0, "8 stages x tp8 = 64 GPUs fit one rack");
        // 4 replicas x 64 GPUs = 256 GPUs > one rack: DP crosses racks
        assert!(p.dp_crosses_racks(NVL72));
        let single = Parallelism { tp: 8, pp: 8, dp: 1, microbatch: 1 };
        assert!(!single.dp_crosses_racks(NVL72));
    }

    #[test]
    fn big_replica_forces_cross_rack_dp() {
        let p = Parallelism { tp: 8, pp: 12, dp: 8, microbatch: 1 };
        assert!(p.dp_crosses_racks(NVL72));
        assert!(p.cross_rack_boundaries(NVL72) >= 1);
    }

    #[test]
    fn degenerate_no_pipeline() {
        let p = Parallelism { tp: 8, pp: 1, dp: 2, microbatch: 1 };
        assert_eq!(p.cross_rack_boundaries(NVL72), 0);
        assert_eq!(p.stages_per_rack(NVL72), 1);
    }
}
