//! High-level LLM training co-design model — our reimplementation of the
//! methodology the paper borrows from Calculon [41]: an analytic model of
//! one training step under tensor/pipeline/data parallelism, producing the
//! {communication, computation, other} breakdown Figure 6 reports.

pub mod llm;
pub mod parallelism;
pub mod execution;
pub mod presets;

pub use execution::{Breakdown, ExecutionModel, TrainingEstimate};
pub use llm::LlmModel;
pub use parallelism::Parallelism;
pub use presets::paper_workloads;
