//! The five LLM training workloads of Figure 6, with model hyperparameters
//! and parallelism taken from each model's original paper (§6: "Simulation
//! parameters, including GPU count, parallelism degree, batch size, and
//! applied optimizations, adhere to the configurations originally
//! presented in each model's initial research").

use super::llm::LlmModel;
use super::parallelism::Parallelism;

/// One Figure 6 workload.
#[derive(Clone, Debug)]
pub struct Workload {
    pub model: LlmModel,
    pub par: Parallelism,
}

/// GPT-3 175B (Brown et al. 2020; parallelism per Megatron-LM practice).
pub fn gpt3_175b() -> Workload {
    Workload {
        model: LlmModel {
            name: "GPT-3 175B".into(),
            layers: 96,
            hidden: 12288,
            heads: 96,
            seq: 2048,
            vocab: 50257,
            global_batch: 1536,
            mlp_mult: 4,
        },
        par: Parallelism { tp: 8, pp: 8, dp: 16, microbatch: 1 },
    }
}

/// Gopher 280B (Rae et al. 2021).
pub fn gopher_280b() -> Workload {
    Workload {
        model: LlmModel {
            name: "Gopher 280B".into(),
            layers: 80,
            hidden: 16384,
            heads: 128,
            seq: 2048,
            vocab: 32000,
            global_batch: 1536,
            mlp_mult: 4,
        },
        par: Parallelism { tp: 8, pp: 10, dp: 24, microbatch: 1 },
    }
}

/// Llama 3 405B (Grattafiori et al. 2024): 16k GPUs, seq 8192.
pub fn llama3_405b() -> Workload {
    Workload {
        model: LlmModel {
            name: "Llama-3 405B".into(),
            layers: 126,
            hidden: 16384,
            heads: 128,
            seq: 8192,
            vocab: 128256,
            global_batch: 2048,
            mlp_mult: 4,
        },
        par: Parallelism { tp: 8, pp: 16, dp: 128, microbatch: 1 },
    }
}

/// PaLM 540B (Chowdhery et al. 2023).
pub fn palm_540b() -> Workload {
    Workload {
        model: LlmModel {
            name: "PaLM 540B".into(),
            layers: 118,
            hidden: 18432,
            heads: 48,
            seq: 2048,
            vocab: 256000,
            global_batch: 2048,
            mlp_mult: 4,
        },
        par: Parallelism { tp: 12, pp: 8, dp: 64, microbatch: 1 },
    }
}

/// Megatron-Turing NLG 530B (Shoeybi et al. lineage; Smith et al. 2022
/// deployment: tp=8, pp=35, batch 1920).
pub fn megatron_530b() -> Workload {
    Workload {
        model: LlmModel {
            name: "Megatron 530B".into(),
            layers: 105,
            hidden: 20480,
            heads: 128,
            seq: 2048,
            vocab: 51200,
            global_batch: 1920,
            mlp_mult: 4,
        },
        par: Parallelism { tp: 8, pp: 35, dp: 12, microbatch: 1 },
    }
}

/// All five Figure 6 workloads, in the paper's order.
pub fn paper_workloads() -> Vec<Workload> {
    vec![gpt3_175b(), gopher_280b(), llama3_405b(), palm_540b(), megatron_530b()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_workloads() {
        assert_eq!(paper_workloads().len(), 5);
    }

    #[test]
    fn parameter_counts_match_names() {
        for (w, lo, hi) in [
            (gpt3_175b(), 170e9, 180e9),
            (gopher_280b(), 250e9, 295e9),
            (llama3_405b(), 395e9, 430e9),
            (palm_540b(), 480e9, 575e9),
            (megatron_530b(), 520e9, 545e9),
        ] {
            let p = w.model.param_count();
            assert!(p >= lo && p <= hi, "{}: {p:.3e} outside [{lo:.1e}, {hi:.1e}]", w.model.name);
        }
    }

    #[test]
    fn gpu_counts_plausible() {
        for w in paper_workloads() {
            let g = w.par.gpus();
            assert!(g >= 1024 && g <= 16384, "{}: {g} GPUs", w.model.name);
        }
    }

    #[test]
    fn microbatches_positive() {
        for w in paper_workloads() {
            assert!(w.par.microbatches(w.model.global_batch) >= 1, "{}", w.model.name);
        }
    }
}
