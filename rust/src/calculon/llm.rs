//! Transformer model description: parameter counts, FLOPs and
//! activation/communication volumes per layer — the inputs the execution
//! model consumes.

/// A decoder-only transformer workload.
#[derive(Clone, Debug, PartialEq)]
pub struct LlmModel {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub seq: usize,
    pub vocab: usize,
    /// Global batch size in sequences.
    pub global_batch: usize,
    /// MLP expansion factor (4 for GPT-family).
    pub mlp_mult: usize,
}

impl LlmModel {
    /// Total parameter count (weights only, untied embedding + head).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.layers as f64;
        let v = self.vocab as f64;
        let m = self.mlp_mult as f64;
        // per layer: qkv 3h^2 + proj h^2 + mlp 2*m*h^2 + ln ~ 4h
        let per_layer = (4.0 + 2.0 * m) * h * h + 8.0 * h;
        l * per_layer + 2.0 * v * h + self.seq as f64 * h
    }

    /// Forward FLOPs for one token through one layer (2 FLOPs per MAC).
    pub fn fwd_flops_per_token_layer(&self) -> f64 {
        let h = self.hidden as f64;
        let s = self.seq as f64;
        let m = self.mlp_mult as f64;
        // matmuls: qkv 3h^2, attn-out h^2, mlp 2*m*h^2  -> 2*(4+2m)h^2
        // attention scores+values: 2 * 2 * s * h
        2.0 * (4.0 + 2.0 * m) * h * h + 4.0 * s * h
    }

    /// Forward FLOPs for one full sequence through the whole model
    /// (excluding the LM head).
    pub fn fwd_flops_per_seq(&self) -> f64 {
        self.fwd_flops_per_token_layer() * self.seq as f64 * self.layers as f64
    }

    /// LM-head FLOPs per sequence.
    pub fn head_flops_per_seq(&self) -> f64 {
        2.0 * self.seq as f64 * self.hidden as f64 * self.vocab as f64
    }

    /// Activation bytes crossing a pipeline-stage boundary per microbatch
    /// of `mb` sequences (fp16/bf16 activations).
    pub fn boundary_bytes(&self, mb: usize) -> f64 {
        2.0 * mb as f64 * self.seq as f64 * self.hidden as f64
    }

    /// Bytes all-reduced per layer by tensor parallelism, per microbatch
    /// (two all-reduces in fwd, two in bwd — Megatron-style; this is the
    /// per-all-reduce buffer size).
    pub fn tp_allreduce_bytes(&self, mb: usize) -> f64 {
        2.0 * mb as f64 * self.seq as f64 * self.hidden as f64
    }

    /// Gradient bytes per data-parallel replica (fp16 grads).
    pub fn grad_bytes(&self) -> f64 {
        2.0 * self.param_count()
    }

    /// Memory footprint of weights + optimizer state per replica, bytes
    /// (fp16 weights + fp32 master + two fp32 Adam moments = 18 B/param).
    pub fn state_bytes(&self) -> f64 {
        18.0 * self.param_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt3ish() -> LlmModel {
        LlmModel {
            name: "gpt3".into(),
            layers: 96,
            hidden: 12288,
            heads: 96,
            seq: 2048,
            vocab: 50257,
            global_batch: 1536,
            mlp_mult: 4,
        }
    }

    #[test]
    fn gpt3_parameter_count_near_175b() {
        let p = gpt3ish().param_count();
        assert!(p > 170e9 && p < 180e9, "gpt-3 params {p:.3e}");
    }

    #[test]
    fn fwd_flops_consistent_with_6nd_rule() {
        // fwd+bwd ~ 6 * params * tokens; fwd alone ~ 2 * params * tokens
        let m = gpt3ish();
        let per_token = m.fwd_flops_per_seq() / m.seq as f64 + m.head_flops_per_seq() / m.seq as f64;
        let rule = 2.0 * m.param_count();
        let ratio = per_token / rule;
        assert!(ratio > 0.9 && ratio < 1.25, "flops/token vs 2N: {ratio}");
    }

    #[test]
    fn boundary_bytes_scale_with_microbatch() {
        let m = gpt3ish();
        assert_eq!(m.boundary_bytes(4), 4.0 * m.boundary_bytes(1));
    }

    #[test]
    fn state_dominates_grads() {
        let m = gpt3ish();
        assert!(m.state_bytes() == 9.0 * m.grad_bytes());
    }
}
