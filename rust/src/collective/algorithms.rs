//! Collective algorithm cost models (ring, tree, hierarchical two-level),
//! parameterized by a [`Transport`] per level.
//!
//! Conventions: `n` ranks, message `bytes` is the *full* buffer size per
//! rank (all-reduce semantics: every rank ends with the reduced buffer).
//! Chunked rings pay per-step latency+software once per step; bandwidth
//! terms use the standard algorithm volume factors.

use super::transport::Transport;

/// Which algorithm a collective uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Ring,
    Tree,
    /// Two-level: intra-group (fast transport) then inter-group (slow),
    /// the standard hierarchical schedule of rack-scale systems.
    Hierarchical,
}

/// Cost model for a set of ranks joined by a transport (and optionally a
/// second-level transport for hierarchical schedules).
#[derive(Clone, Copy, Debug)]
pub struct CollectiveModel {
    /// Transport between peer ranks at the (single or outer) level.
    pub transport: Transport,
    /// Inner (intra-group) transport for hierarchical schedules.
    pub inner: Option<Transport>,
    /// Ranks per inner group (hierarchical only).
    pub group: usize,
}

impl CollectiveModel {
    pub fn flat(transport: Transport) -> CollectiveModel {
        CollectiveModel { transport, inner: None, group: 1 }
    }

    pub fn hierarchical(outer: Transport, inner: Transport, group: usize) -> CollectiveModel {
        assert!(group >= 1);
        CollectiveModel { transport: outer, inner: Some(inner), group }
    }

    /// All-reduce of `bytes` per rank across `n` ranks, ns.
    pub fn all_reduce(&self, n: usize, bytes: f64, algo: Algorithm) -> f64 {
        if n <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        match algo {
            Algorithm::Ring => ring_all_reduce(&self.transport, n, bytes),
            Algorithm::Tree => tree_all_reduce(&self.transport, n, bytes),
            Algorithm::Hierarchical => {
                let inner = self.inner.unwrap_or(self.transport);
                let g = self.group.min(n).max(1);
                let outer_n = n.div_ceil(g);
                // reduce-scatter inside groups, all-reduce across group
                // leaders on the shard, all-gather inside groups
                let rs = ring_reduce_scatter(&inner, g, bytes);
                let shard = bytes / g as f64;
                let ar = ring_all_reduce(&self.transport, outer_n, shard);
                let ag = ring_all_gather(&inner, g, bytes);
                rs + ar + ag
            }
        }
    }

    /// Reduce-scatter: each rank ends with bytes/n reduced shard.
    pub fn reduce_scatter(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        ring_reduce_scatter(&self.transport, n, bytes)
    }

    /// All-gather of bytes/n shards into the full buffer.
    pub fn all_gather(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        ring_all_gather(&self.transport, n, bytes)
    }

    /// Broadcast root -> n-1 peers (binomial tree).
    pub fn broadcast(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let rounds = (n as f64).log2().ceil();
        rounds * self.transport.message_ns(bytes)
    }

    /// Point-to-point send of `bytes`.
    pub fn p2p(&self, bytes: f64) -> f64 {
        self.transport.message_ns(bytes)
    }
}

/// Steps of a full ring all-reduce over `n` ranks (reduce-scatter +
/// all-gather). Shared with the event-driven
/// [`EventDrivenCollective`](super::EventDrivenCollective) so the
/// analytic and simulated schedules stay structurally identical.
pub fn ring_all_reduce_steps(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        2 * (n - 1)
    }
}

/// Steps of one ring pass (reduce-scatter or all-gather) over `n` ranks.
pub fn ring_phase_steps(n: usize) -> usize {
    n.saturating_sub(1)
}

fn ring_all_reduce(t: &Transport, n: usize, bytes: f64) -> f64 {
    // 2(n-1) steps, each moving bytes/n
    ring_all_reduce_steps(n) as f64 * t.message_ns(bytes / n as f64)
}

fn ring_reduce_scatter(t: &Transport, n: usize, bytes: f64) -> f64 {
    ring_phase_steps(n) as f64 * t.message_ns(bytes / n as f64)
}

fn ring_all_gather(t: &Transport, n: usize, bytes: f64) -> f64 {
    ring_phase_steps(n) as f64 * t.message_ns(bytes / n as f64)
}

fn tree_all_reduce(t: &Transport, n: usize, bytes: f64) -> f64 {
    // reduce up + broadcast down a binomial tree
    let rounds = (n as f64).log2().ceil();
    2.0 * rounds * t.message_ns(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Transport {
        // NVLink-class
        Transport { base_latency_ns: 400.0, sw_overhead_ns: 300.0, bw: 900.0, bw_efficiency: 0.9 }
    }
    fn slow_rdma() -> Transport {
        Transport { base_latency_ns: 2_000.0, sw_overhead_ns: 5_000.0, bw: 50.0, bw_efficiency: 0.8 }
    }
    fn cxl() -> Transport {
        Transport { base_latency_ns: 900.0, sw_overhead_ns: 300.0, bw: 64.0, bw_efficiency: 0.92 }
    }

    #[test]
    fn trivial_cases_zero() {
        let m = CollectiveModel::flat(fast());
        assert_eq!(m.all_reduce(1, 1e6, Algorithm::Ring), 0.0);
        assert_eq!(m.all_reduce(8, 0.0, Algorithm::Ring), 0.0);
    }

    #[test]
    fn ring_bandwidth_term_scales_correctly() {
        // for large buffers, ring all-reduce -> 2 * bytes / bw (n-indep)
        let m = CollectiveModel::flat(Transport { base_latency_ns: 0.0, sw_overhead_ns: 0.0, bw: 100.0, bw_efficiency: 1.0 });
        let t8 = m.all_reduce(8, 1e9, Algorithm::Ring);
        let t64 = m.all_reduce(64, 1e9, Algorithm::Ring);
        let ideal = 2.0 * 1e9 / 100.0;
        // ratio to ideal is (n-1)/n
        assert!((t8 / ideal - 7.0 / 8.0).abs() < 0.01);
        assert!((t64 / ideal - 63.0 / 64.0).abs() < 0.01);
    }

    #[test]
    fn latency_term_hurts_small_messages_on_rdma() {
        let rdma = CollectiveModel::flat(slow_rdma());
        let cxl = CollectiveModel::flat(cxl());
        // 1 MB over 64 ranks: 16 KB chunks -> overhead-dominated
        let r = rdma.all_reduce(64, 1e6, Algorithm::Ring);
        let c = cxl.all_reduce(64, 1e6, Algorithm::Ring);
        assert!(r / c > 3.0, "rdma {r} vs cxl {c}");
    }

    #[test]
    fn tree_beats_ring_for_tiny_buffers_large_n() {
        let m = CollectiveModel::flat(slow_rdma());
        let ring = m.all_reduce(256, 4096.0, Algorithm::Ring);
        let tree = m.all_reduce(256, 4096.0, Algorithm::Tree);
        assert!(tree < ring);
    }

    #[test]
    fn hierarchical_beats_flat_over_slow_outer() {
        let m = CollectiveModel::hierarchical(slow_rdma(), fast(), 72);
        let flat = CollectiveModel::flat(slow_rdma());
        let n = 288; // 4 racks of 72
        let h = m.all_reduce(n, 1e8, Algorithm::Hierarchical);
        let f = flat.all_reduce(n, 1e8, Algorithm::Ring);
        assert!(h < f, "hierarchical {h} !< flat {f}");
    }

    #[test]
    fn reduce_scatter_plus_all_gather_equals_ring_all_reduce() {
        let m = CollectiveModel::flat(fast());
        let n = 16;
        let b = 1e7;
        let sum = m.reduce_scatter(n, b) + m.all_gather(n, b);
        let ar = m.all_reduce(n, b, Algorithm::Ring);
        assert!((sum - ar).abs() / ar < 1e-9);
    }

    #[test]
    fn broadcast_log_rounds() {
        let m = CollectiveModel::flat(fast());
        let t8 = m.broadcast(8, 1e6);
        let t64 = m.broadcast(64, 1e6);
        assert!((t64 / t8 - 2.0).abs() < 1e-9); // log2 64 / log2 8 = 2
    }
}
