//! Collective-communication cost models over the fabric: ring/tree/
//! hierarchical algorithms, the RDMA software stack of the scale-out
//! baseline, and the CXL hardware-coherent path that replaces it
//! (§4: "protocol-level coherence ... enables efficient collective
//! communication by eliminating explicit synchronization and redundant
//! data copying overhead").
//!
//! Two complementary forms of the same algorithms:
//! * [`algorithms`] — closed-form alpha-beta costs on an idle fabric;
//! * [`schedule`] — the [`EventDrivenCollective`] traffic source that
//!   issues every per-step chunk transfer through the shared event
//!   backend, validated against the closed form when uncontended and
//!   exposing contention when not (the `mixed` experiment).

pub mod transport;
pub mod rdma;
pub mod algorithms;
pub mod schedule;

pub use algorithms::{ring_all_reduce_steps, ring_phase_steps, Algorithm, CollectiveModel};
pub use rdma::RdmaStack;
pub use schedule::{EventDrivenCollective, RingPhase};
pub use transport::Transport;
