//! Collective-communication cost models over the fabric: ring/tree/
//! hierarchical algorithms, the RDMA software stack of the scale-out
//! baseline, and the CXL hardware-coherent path that replaces it
//! (§4: "protocol-level coherence ... enables efficient collective
//! communication by eliminating explicit synchronization and redundant
//! data copying overhead").

pub mod transport;
pub mod rdma;
pub mod algorithms;

pub use algorithms::{Algorithm, CollectiveModel};
pub use rdma::RdmaStack;
pub use transport::Transport;
