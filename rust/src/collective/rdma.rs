//! The RDMA/InfiniBand software stack of the scale-out baseline.
//!
//! §1/§6: "Even performance-optimized frameworks such as RDMA cannot
//! completely eliminate performance degradation due to unnecessary data
//! copying across different computing domains, serialization /
//! deserialization, and computational overhead" ... "including
//! synchronization across communicators".
//!
//! Each term is modeled separately so ablations can switch them off.

/// Software cost components of one RDMA message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RdmaStack {
    /// Communicator synchronization per collective step, ns.
    pub sync_ns: f64,
    /// Serialization/deserialization per message, ns per byte (captures
    /// staging copies between device and pinned buffers).
    pub serde_ns_per_byte: f64,
    /// Fixed per-message launch (verbs post, completion poll), ns.
    pub launch_ns: f64,
    /// Bandwidth efficiency of the stack (copies, pipelining gaps).
    pub bw_efficiency: f64,
}

impl RdmaStack {
    /// A well-tuned NCCL-over-IB-style stack.
    pub fn tuned() -> RdmaStack {
        RdmaStack {
            sync_ns: 3_000.0,
            serde_ns_per_byte: 0.004, // staging copy at ~250 GB/s
            launch_ns: 2_000.0,
            bw_efficiency: 0.80,
        }
    }

    /// CXL hardware-coherent path: no software on the data path
    /// ("hardware implicitly manages data movements"). A residual launch
    /// cost remains for initiating the collective kernel.
    pub fn cxl_hardware() -> RdmaStack {
        RdmaStack { sync_ns: 0.0, serde_ns_per_byte: 0.0, launch_ns: 300.0, bw_efficiency: 0.92 }
    }

    /// Per-message software overhead, ns.
    pub fn overhead_ns(&self, bytes: f64) -> f64 {
        self.sync_ns + self.launch_ns + self.serde_ns_per_byte * bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdma_small_message_overhead_is_microseconds() {
        let o = RdmaStack::tuned().overhead_ns(1024.0);
        assert!(o > 4_000.0 && o < 10_000.0, "{o}");
    }

    #[test]
    fn cxl_overhead_is_sub_microsecond() {
        let o = RdmaStack::cxl_hardware().overhead_ns(1024.0);
        assert!(o < 1_000.0, "{o}");
    }

    #[test]
    fn serde_grows_with_size() {
        let s = RdmaStack::tuned();
        assert!(s.overhead_ns(1e6) > s.overhead_ns(1e3) + 3_000.0);
    }

    #[test]
    fn overhead_gap_is_order_of_magnitude() {
        // the structural claim behind Fig 6's 3.79x comm speedup
        let r = RdmaStack::tuned().overhead_ns(65_536.0);
        let c = RdmaStack::cxl_hardware().overhead_ns(65_536.0);
        assert!(r / c > 10.0, "rdma {r} vs cxl {c}");
    }
}
