//! A transport is "what one rank pays to move one message to a peer":
//! base hardware latency, per-message software overhead, and bandwidth.
//! Collectives compose transports; transports are derived from the fabric
//! (hardware terms) and the software stack model (software terms).

use crate::fabric::{Fabric, NodeId};

/// Point-to-point transport characteristics between two ranks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Transport {
    /// Hardware one-way latency for a small (cache-line .. KB) message, ns.
    pub base_latency_ns: f64,
    /// Software overhead per message (launch, synchronization,
    /// (de)serialization, registration) paid by the sender+receiver, ns.
    pub sw_overhead_ns: f64,
    /// Sustained per-rank bandwidth, bytes/ns.
    pub bw: f64,
    /// Fraction of `bw` achievable by the protocol stack (copies,
    /// pipelining gaps).
    pub bw_efficiency: f64,
}

impl Transport {
    /// Time to move one `bytes` message to a peer, ns.
    pub fn message_ns(&self, bytes: f64) -> f64 {
        self.base_latency_ns + self.sw_overhead_ns + bytes / self.effective_bw()
    }

    pub fn effective_bw(&self) -> f64 {
        self.bw * self.bw_efficiency
    }

    /// Derive the hardware part from a routed fabric path (software terms
    /// zero — add them via `with_software`).
    pub fn from_fabric(fabric: &Fabric, src: NodeId, dst: NodeId) -> Option<Transport> {
        let path = fabric.path(src, dst)?;
        let small = fabric.message_latency(&path, 512.0).total_ns();
        let bw = fabric.path_bandwidth(&path, 1024.0 * 1024.0);
        Some(Transport { base_latency_ns: small, sw_overhead_ns: 0.0, bw, bw_efficiency: 1.0 })
    }

    /// Derive the transport that reproduces the *event simulator's*
    /// store-and-forward walk of the routed `src -> dst` path for
    /// messages of about `calib_bytes`: base latency is the per-hop
    /// fixed cost (prop + PHY + framing + receiving-switch traversal)
    /// summed over the path, and bandwidth is calibrated so
    /// `message_ns(calib_bytes)` equals the sum of per-hop wire
    /// serializations. This is the analytic counterpart the event-driven
    /// collective is validated against on an uncontended fabric.
    pub fn from_sim_path(fabric: &Fabric, src: NodeId, dst: NodeId, calib_bytes: f64) -> Option<Transport> {
        let p = fabric.path(src, dst)?;
        if p.links.is_empty() {
            return Some(Transport { base_latency_ns: 0.0, sw_overhead_ns: 0.0, bw: 1e18, bw_efficiency: 1.0 });
        }
        let mut fixed = 0.0;
        let mut ser = 0.0;
        for (i, &l) in p.links.iter().enumerate() {
            let lp = &fabric.topo.link(l).params;
            fixed += lp.prop_ns + lp.phy.latency_ns() + lp.flit_overhead_ns;
            // switch traversal is paid at the receiving node of each hop
            let recv = p.nodes[i + 1];
            if let Some(sw) = &fabric.topo.node(recv).switch {
                fixed += sw.traversal_ns();
            }
            ser += lp.flit.wire_bytes(calib_bytes) / (lp.raw_bw * lp.phy.efficiency());
        }
        Some(Transport {
            base_latency_ns: fixed,
            sw_overhead_ns: 0.0,
            bw: calib_bytes / ser,
            bw_efficiency: 1.0,
        })
    }

    pub fn with_software(mut self, sw_overhead_ns: f64, bw_efficiency: f64) -> Transport {
        self.sw_overhead_ns = sw_overhead_ns;
        self.bw_efficiency = bw_efficiency;
        self
    }

    pub fn with_bandwidth(mut self, bw: f64) -> Transport {
        self.bw = bw;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, NodeKind, Topology};

    #[test]
    fn message_time_decomposes() {
        let t = Transport { base_latency_ns: 100.0, sw_overhead_ns: 50.0, bw: 10.0, bw_efficiency: 0.5 };
        assert_eq!(t.message_ns(0.0), 150.0);
        assert_eq!(t.message_ns(500.0), 150.0 + 100.0);
    }

    #[test]
    fn from_fabric_matches_facade() {
        let topo = Topology::single_hop(4, LinkKind::NvLink5, "r");
        let accs = topo.nodes_of(NodeKind::Accelerator);
        let f = Fabric::new(topo);
        let t = Transport::from_fabric(&f, accs[0], accs[1]).unwrap();
        assert!(t.base_latency_ns > 0.0);
        assert!(t.bw > 50.0 && t.bw <= 100.0);
        assert_eq!(t.sw_overhead_ns, 0.0);
    }

    #[test]
    fn software_overhead_composes() {
        let topo = Topology::single_hop(4, LinkKind::NvLink5, "r");
        let accs = topo.nodes_of(NodeKind::Accelerator);
        let f = Fabric::new(topo);
        let hw = Transport::from_fabric(&f, accs[0], accs[1]).unwrap();
        let sw = hw.with_software(5_000.0, 0.8);
        assert!(sw.message_ns(1024.0) > hw.message_ns(1024.0) + 4_000.0);
    }
}
