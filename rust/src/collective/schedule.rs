//! Event-driven collective schedules: the per-step chunk transfers of
//! ring and hierarchical all-reduce issued as fabric transactions through
//! the shared [`MemSim`](crate::sim::MemSim) backend.
//!
//! The analytic [`CollectiveModel`](super::CollectiveModel) answers "what
//! does this collective cost on an idle fabric"; this schedule runs the
//! *same* algorithm step structure event-by-event, so on an uncontended
//! fabric the two agree (regression-tested within 5% against a
//! [`Transport::from_sim_path`](super::Transport::from_sim_path)
//! calibration), while under cross-traffic the event-driven path shows
//! the contention the closed form cannot.
//!
//! # Step dependencies
//!
//! In a ring of n members, member m's step-k send may fly once (a) its
//! own step-(k-1) send completed (single injection port) and (b) it
//! received the step-(k-1) chunk from its predecessor (reduction data
//! dependency). Phases (reduce-scatter / leader all-reduce / all-gather
//! in the hierarchical schedule) are separated by a full barrier.

use crate::fabric::NodeId;
use crate::sim::{Pull, SourcedTx, TrafficClass, TrafficSource, Transaction};
use crate::util::stats::Welford;
use std::collections::VecDeque;

/// One phase: a set of disjoint rings each running `steps` uniform
/// chunk-steps (rings with fewer than two members are skipped).
#[derive(Clone, Debug)]
pub struct RingPhase {
    pub rings: Vec<Vec<NodeId>>,
    pub steps: usize,
    pub chunk_bytes: f64,
}

/// Per-member state inside the active phase.
#[derive(Clone)]
struct Member {
    src: NodeId,
    /// Ring successor (receives this member's sends).
    dst: NodeId,
    /// Global index of the successor member.
    succ: u32,
    /// Sends issued so far (the next send's step index).
    emitted: u32,
    /// Chunks received from the predecessor.
    recvd: u32,
    outstanding: bool,
    queued: bool,
}

/// Event-driven collective over a list of [`RingPhase`]s, optionally
/// repeated (`repeats` back-to-back collectives, e.g. one per training
/// step).
///
/// `Clone` snapshots the complete schedule cursor (phase, per-member
/// step state, ready queue, accumulators) — the basis of the
/// [`TrafficSource::checkpoint`] support that lets the optimistic
/// sharded backend roll a fabric-spanning ring back to an epoch barrier.
#[derive(Clone)]
pub struct EventDrivenCollective {
    phases: Vec<RingPhase>,
    repeats: usize,
    device_ns: f64,
    // runtime
    rep: usize,
    phase_idx: usize,
    members: Vec<Member>,
    ready: VecDeque<u32>,
    /// Transfers still to complete in the active phase.
    phase_remaining: u64,
    inflight: usize,
    rep_started_at: f64,
    rep_latency: Welford,
    transfers: u64,
    done: bool,
}

impl EventDrivenCollective {
    /// Flat ring all-reduce over `ranks` of a `bytes` buffer per rank.
    pub fn ring(ranks: Vec<NodeId>, bytes: f64, repeats: usize) -> EventDrivenCollective {
        let n = ranks.len();
        let phases = vec![RingPhase {
            rings: vec![ranks],
            steps: super::algorithms::ring_all_reduce_steps(n),
            chunk_bytes: if n > 0 { bytes / n as f64 } else { 0.0 },
        }];
        EventDrivenCollective::from_phases(phases, repeats)
    }

    /// Hierarchical all-reduce: reduce-scatter inside each (equal-sized)
    /// group, ring all-reduce across group leaders on the shard,
    /// all-gather inside each group — the same three-phase structure as
    /// the analytic `Algorithm::Hierarchical`.
    pub fn hierarchical(groups: Vec<Vec<NodeId>>, bytes: f64, repeats: usize) -> EventDrivenCollective {
        assert!(!groups.is_empty());
        let g = groups[0].len();
        assert!(groups.iter().all(|gr| gr.len() == g), "groups must be equal-sized");
        let leaders: Vec<NodeId> = groups.iter().map(|gr| gr[0]).collect();
        let l = leaders.len();
        let g_f = g.max(1) as f64;
        let phases = vec![
            RingPhase {
                rings: groups.clone(),
                steps: super::algorithms::ring_phase_steps(g),
                chunk_bytes: bytes / g_f,
            },
            RingPhase {
                rings: vec![leaders],
                steps: super::algorithms::ring_all_reduce_steps(l),
                chunk_bytes: bytes / (g_f * l.max(1) as f64),
            },
            RingPhase {
                rings: groups,
                steps: super::algorithms::ring_phase_steps(g),
                chunk_bytes: bytes / g_f,
            },
        ];
        EventDrivenCollective::from_phases(phases, repeats)
    }

    /// Custom phase list.
    pub fn from_phases(phases: Vec<RingPhase>, repeats: usize) -> EventDrivenCollective {
        assert!(repeats >= 1);
        let mut c = EventDrivenCollective {
            phases,
            repeats,
            device_ns: 0.0,
            rep: 0,
            phase_idx: 0,
            members: Vec::new(),
            ready: VecDeque::new(),
            phase_remaining: 0,
            inflight: 0,
            rep_started_at: 0.0,
            rep_latency: Welford::new(),
            transfers: 0,
            done: false,
        };
        c.enter_phase(0.0);
        c
    }

    /// Destination-side service per chunk (reduction/copy cost), ns.
    pub fn with_device_ns(mut self, device_ns: f64) -> EventDrivenCollective {
        self.device_ns = device_ns;
        self
    }

    /// Wall time of each completed all-reduce repeat, ns.
    pub fn repeat_latency(&self) -> &Welford {
        &self.rep_latency
    }

    /// Chunk transfers completed.
    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Set up the next non-empty phase starting at `now` (or finish the
    /// repeat / the whole schedule).
    fn enter_phase(&mut self, now: f64) {
        loop {
            if self.done {
                return;
            }
            if self.phase_idx >= self.phases.len() {
                // repeat complete
                self.rep_latency.push(now - self.rep_started_at);
                self.rep += 1;
                if self.rep >= self.repeats {
                    self.done = true;
                    return;
                }
                self.phase_idx = 0;
                self.rep_started_at = now;
                continue;
            }
            let phase = &self.phases[self.phase_idx];
            let steps = phase.steps;
            if steps == 0 {
                self.phase_idx += 1;
                continue;
            }
            self.members.clear();
            self.ready.clear();
            let mut base = 0u32;
            for ring in &phase.rings {
                let len = ring.len();
                if len < 2 {
                    continue;
                }
                for (i, &node) in ring.iter().enumerate() {
                    let succ_pos = (i + 1) % len;
                    self.members.push(Member {
                        src: node,
                        dst: ring[succ_pos],
                        succ: base + succ_pos as u32,
                        emitted: 0,
                        recvd: 0,
                        outstanding: false,
                        queued: false,
                    });
                }
                base += len as u32;
            }
            if self.members.is_empty() {
                self.phase_idx += 1;
                continue;
            }
            self.phase_remaining = self.members.len() as u64 * steps as u64;
            // step 0 has no dependencies: every member starts
            for m in 0..self.members.len() as u32 {
                self.members[m as usize].queued = true;
                self.ready.push_back(m);
            }
            return;
        }
    }

    /// Queue member `m` if its next step's dependencies are met.
    fn check_ready(&mut self, m: u32) {
        let steps = self.phases[self.phase_idx].steps as u32;
        let mem = &mut self.members[m as usize];
        if !mem.queued && !mem.outstanding && mem.emitted < steps && mem.recvd >= mem.emitted {
            mem.queued = true;
            self.ready.push_back(m);
        }
    }
}

impl TrafficSource for EventDrivenCollective {
    fn class(&self) -> TrafficClass {
        TrafficClass::Collective
    }

    fn pull(&mut self, now: f64) -> Pull {
        if self.done {
            return Pull::Done;
        }
        if let Some(m) = self.ready.pop_front() {
            let chunk = self.phases[self.phase_idx].chunk_bytes;
            let mem = &mut self.members[m as usize];
            mem.queued = false;
            mem.outstanding = true;
            mem.emitted += 1;
            self.inflight += 1;
            // one flow per (pair, ring direction): a member only ever
            // sends to its ring successor, so the ordered (src, dst)
            // pair identifies the directed chunk stream. Stamping it
            // keeps every step of the stream on one HashSpray rail —
            // ordered collective steps never reorder across rails
            // (ROADMAP item 4)
            let flow = ((mem.src as u64) << 32) | mem.dst as u64;
            return Pull::Tx(
                SourcedTx::new(
                    Transaction {
                        src: mem.src,
                        dst: mem.dst,
                        at: now,
                        bytes: chunk,
                        device_ns: self.device_ns,
                    },
                    m as u64,
                )
                .with_flow(flow),
            );
        }
        debug_assert!(self.inflight > 0, "collective stalled with no ready member");
        Pull::Blocked
    }

    fn on_complete(&mut self, token: u64, now: f64) {
        let m = token as u32;
        self.inflight -= 1;
        self.transfers += 1;
        self.phase_remaining -= 1;
        let succ = self.members[m as usize].succ;
        self.members[m as usize].outstanding = false;
        self.members[succ as usize].recvd += 1;
        if self.phase_remaining == 0 {
            debug_assert_eq!(self.inflight, 0);
            self.phase_idx += 1;
            self.enter_phase(now);
            return;
        }
        self.check_ready(m);
        self.check_ready(succ);
    }

    /// Every chunk flies between ring neighbors, and every ring of every
    /// phase is fixed at construction — the footprint is the union of
    /// all phase rings, making the schedule eligible for coupled-domain
    /// shard pinning (a rack-local ring pins to its rack's shard; a
    /// fabric-wide ring spans the partition and runs on the coordinator
    /// under the optimistic checkpoint/rollback protocol).
    fn footprint(&self) -> Option<Vec<NodeId>> {
        let mut nodes: Vec<NodeId> = Vec::new();
        for phase in &self.phases {
            for ring in &phase.rings {
                for &n in ring {
                    if !nodes.contains(&n) {
                        nodes.push(n);
                    }
                }
            }
        }
        Some(nodes)
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn checkpoint(&self) -> Option<Box<dyn std::any::Any + Send>> {
        Some(Box::new(self.clone()))
    }

    fn restore(&mut self, snap: &(dyn std::any::Any + Send)) {
        let snap = snap.downcast_ref::<EventDrivenCollective>().expect("snapshot type mismatch");
        self.clone_from(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
    use crate::sim::MemSim;

    fn rack(n: usize) -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    fn run(mut c: EventDrivenCollective, f: &Fabric) -> (EventDrivenCollective, crate::sim::StreamReport) {
        let rep = {
            let mut sim = MemSim::new(f);
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut c];
            sim.run_streamed(&mut sources)
        };
        (c, rep)
    }

    #[test]
    fn ring_transfer_count_is_steps_times_ranks() {
        let (f, accs) = rack(8);
        let c = EventDrivenCollective::ring(accs, 8.0 * 1024.0 * 1024.0, 1);
        let (c, rep) = run(c, &f);
        // 2(n-1) steps x n ranks
        assert_eq!(c.transfers(), 14 * 8);
        assert_eq!(rep.total.completed, 14 * 8);
        assert_eq!(rep.class(TrafficClass::Collective).completed, 14 * 8);
        assert_eq!(c.repeat_latency().count(), 1);
    }

    #[test]
    fn steps_serialize_through_dependencies() {
        // n ranks, uncontended: total time ~= steps x per-step time, so
        // doubling rank count (same chunk) roughly doubles makespan
        let (f8, accs8) = rack(8);
        let bytes8 = 8.0 * 8192.0; // chunk 8 KiB
        let (_, rep8) = run(EventDrivenCollective::ring(accs8, bytes8, 1), &f8);
        let (f16, accs16) = rack(16);
        let bytes16 = 16.0 * 8192.0; // same 8 KiB chunk
        let (_, rep16) = run(EventDrivenCollective::ring(accs16, bytes16, 1), &f16);
        let ratio = rep16.total.makespan_ns / rep8.total.makespan_ns;
        // steps: 30 vs 14 => 2.14x
        assert!((ratio - 30.0 / 14.0).abs() < 0.2, "step scaling off: {ratio}");
    }

    #[test]
    fn repeats_run_back_to_back() {
        let (f, accs) = rack(4);
        let (c, rep) = run(EventDrivenCollective::ring(accs, 4.0 * 65536.0, 3), &f);
        assert_eq!(c.repeat_latency().count(), 3);
        assert_eq!(rep.total.completed, 3 * 6 * 4);
        // identical repeats on an idle fabric take identical time
        let w = c.repeat_latency();
        assert!((w.max() - w.min()) / w.max() < 1e-6, "repeat jitter");
    }

    #[test]
    fn hierarchical_structure_counts() {
        let (f, accs) = rack(12);
        let groups: Vec<Vec<NodeId>> = accs.chunks(4).map(|c| c.to_vec()).collect();
        let (c, rep) = run(EventDrivenCollective::hierarchical(groups, 12.0 * 1024.0 * 1024.0, 1), &f);
        // phase1: 3 rings x 4 members x 3 steps = 36
        // phase2: 1 ring x 3 leaders x 4 steps = 12
        // phase3: = phase1 = 36
        assert_eq!(c.transfers(), 36 + 12 + 36);
        assert_eq!(rep.total.completed, 84);
    }

    #[test]
    fn degenerate_sizes_complete() {
        let (f, accs) = rack(2);
        let (c, _) = run(EventDrivenCollective::ring(accs[..2].to_vec(), 1024.0, 1), &f);
        assert_eq!(c.transfers(), 2 * 2); // 2 steps x 2 ranks
        // single rank: nothing to do, schedule is immediately done
        let mut solo = EventDrivenCollective::ring(vec![accs[0]], 1024.0, 1);
        assert!(matches!(solo.pull(0.0), Pull::Done));
    }
}
