//! Hand-rolled micro-benchmark runner (criterion is not in the offline
//! vendor set): warmup, timed iterations, and a mean/σ/p50/p99 report.
//! Used by the `rust/benches/*` binaries (`cargo bench` with
//! `harness = false`).

use crate::util::stats::Summary;
use std::time::Instant;

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 20 }
    }
}

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>12}/iter  (σ {:>10}, p99 {:>12}, n={})",
            self.name,
            crate::util::units::fmt_ns(s.mean),
            crate::util::units::fmt_ns(s.std),
            crate::util::units::fmt_ns(s.p99),
            s.n
        )
    }
}

/// Time `f` under `cfg`; the closure's return value is black-boxed.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult { name: name.to_string(), summary: Summary::from(samples) }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run a group of benches and print a header + rows (the bench binaries'
/// common skeleton).
pub struct BenchGroup {
    title: String,
    results: Vec<BenchResult>,
    cfg: BenchConfig,
}

impl BenchGroup {
    pub fn new(title: &str) -> BenchGroup {
        println!("\n=== {title} ===");
        BenchGroup { title: title.to_string(), results: Vec::new(), cfg: BenchConfig::default() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) -> &mut Self {
        let r = bench(name, self.cfg, f);
        println!("{}", r.report());
        self.results.push(r);
        self
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn title(&self) -> &str {
        &self.title
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", BenchConfig { warmup_iters: 1, iters: 5 }, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.report().contains("spin"));
    }
}
