//! Discrete-event simulation engine (transaction-level): event heap,
//! links/switch ports as class-aware servers with real queuing and
//! pluggable QoS arbitration (module [`qos`]), and a memory-transaction
//! simulator used by Figure 7's detailed mode, the `scalepool simulate`
//! subcommand, and the unified traffic layer behind the `mixed` and
//! `qos` experiments.
//!
//! The analytic model in [`crate::fabric`] answers "what is the latency of
//! one message on an idle/uniformly-loaded path"; this engine answers the
//! same question under *actual* contention from a concrete transaction
//! stream (the paper's "queuing behaviors at both link and transaction
//! layers").
//!
//! # The traffic layer
//!
//! [`traffic::TrafficSource`] is the single abstraction every workload
//! class plugs into: coherence protocol flows
//! ([`crate::coherence::CoherenceTraffic`]), tier-2 migrations
//! ([`crate::coordinator::TieringTraffic`]), collective schedules
//! ([`crate::collective::EventDrivenCollective`]) and synthetic load
//! ([`crate::workloads::SyntheticTraffic`]) all emit transactions into
//! the same slab-engine backend via [`MemSim::run_streamed`], so
//! cross-class interference on shared links emerges instead of each
//! class being modeled in a closed-form silo.
//!
//! Hot-path design (§Perf, see `benches/simscale.rs` for the numbers):
//! the [`Engine`] is a calendar queue (timing wheel) carrying lean
//! `(time, seq, handle)` keys with payloads in a recycled slab (the
//! pre-calendar binary heap survives as `engine::reference::HeapEngine`,
//! the dispatch-order oracle), and [`MemSim`] interns routed paths per
//! `(src, dst)` pair (packed into one `u64` key) with precomputed per-hop
//! direction bits — sized for millions of transactions over
//! multi-thousand-node fabrics. Streamed injection pulls sources one
//! transaction ahead and recycles in-flight slots, so memory scales with
//! peak concurrency, not workload length. For pod-scale open-loop runs,
//! [`MemSim::run_streamed_sharded`] partitions the fabric into
//! topology-derived domains and streams one engine per shard under
//! conservative lookahead (module `shard`), matching the serial backend's
//! per-class counts, byte totals and latency multiset exactly. On a
//! multipath-enabled fabric the per-tier [`rails::RoutingPolicy`] decides
//! how transactions spread over equal-cost rails (deterministic rail 0 /
//! ECMP hash-spray / congestion-adaptive steering on the live QoS
//! telemetry — module [`rails`]).

pub mod engine;
pub mod server;
pub mod memsim;
pub mod qos;
pub mod rails;
mod shard;
pub mod trace;
pub mod traffic;

pub use engine::{Engine, EngineSnapshot, EventKind};
pub use memsim::{MemSim, MemSimReport, Transaction};
pub use qos::{ArbPolicy, ClassedServer, LinkClassStats, LinkTier, QosPolicy};
pub use rails::{RailSelector, RoutingPolicy};
pub use server::Server;
pub use trace::{
    chrome_trace, time_series, GaugeSample, InstantEvent, InstantKind, SpanRecord, TraceConfig,
    TraceData,
};
pub use traffic::{
    BatchSource, ClassReport, Pull, ShardMode, ShardStats, SourcedTx, StreamReport, TrafficClass,
    TrafficSource,
};
