//! Discrete-event simulation engine (transaction-level): event heap,
//! links/switch ports as FCFS servers with real queuing, and a
//! memory-transaction simulator used by Figure 7's detailed mode and the
//! `scalepool simulate` subcommand.
//!
//! The analytic model in [`crate::fabric`] answers "what is the latency of
//! one message on an idle/uniformly-loaded path"; this engine answers the
//! same question under *actual* contention from a concrete transaction
//! stream (the paper's "queuing behaviors at both link and transaction
//! layers").

pub mod engine;
pub mod server;
pub mod memsim;

pub use engine::{Engine, EventKind};
pub use memsim::{MemSim, MemSimReport, Transaction};
pub use server::Server;
