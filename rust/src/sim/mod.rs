//! Discrete-event simulation engine (transaction-level): event heap,
//! links/switch ports as FCFS servers with real queuing, and a
//! memory-transaction simulator used by Figure 7's detailed mode and the
//! `scalepool simulate` subcommand.
//!
//! The analytic model in [`crate::fabric`] answers "what is the latency of
//! one message on an idle/uniformly-loaded path"; this engine answers the
//! same question under *actual* contention from a concrete transaction
//! stream (the paper's "queuing behaviors at both link and transaction
//! layers").
//!
//! Hot-path design (§Perf, see `benches/simscale.rs` for the numbers):
//! the [`Engine`] heap carries lean `(time, seq, handle)` keys with
//! payloads in a recycled slab, and [`MemSim`] interns routed paths per
//! `(src, dst)` pair with precomputed per-hop direction bits — sized for
//! millions of transactions over multi-thousand-node fabrics.

pub mod engine;
pub mod server;
pub mod memsim;

pub use engine::{Engine, EventKind};
pub use memsim::{MemSim, MemSimReport, Transaction};
pub use server::Server;
