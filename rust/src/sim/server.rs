//! FCFS server: models one link direction (or switch port) as a resource
//! with a service rate; transactions queue when busy. This is the "real"
//! queuing counterpart of the analytic M/D/1 adder in `fabric::switch`.

use super::engine::SimTime;

/// A first-come-first-served serial resource.
#[derive(Clone, Debug, Default)]
pub struct Server {
    /// Time at which the server frees up.
    free_at: SimTime,
    /// Cumulative busy time (for utilization reporting).
    busy: f64,
    /// Number of serviced jobs.
    served: u64,
    /// Cumulative queueing delay experienced by jobs.
    queued: f64,
}

impl Server {
    pub fn new() -> Server {
        Server::default()
    }

    /// Admit a job arriving at `now` needing `service` time units.
    /// Returns the completion time; updates occupancy accounting.
    #[inline]
    pub fn admit(&mut self, now: SimTime, service: f64) -> SimTime {
        let start = now.max(self.free_at);
        self.queued += start - now;
        self.free_at = start + service;
        self.busy += service;
        self.served += 1;
        self.free_at
    }

    /// Earliest start time for a job arriving at `now` (without admitting).
    pub fn earliest_start(&self, now: SimTime) -> SimTime {
        now.max(self.free_at)
    }

    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy / horizon).min(1.0)
        }
    }

    pub fn served(&self) -> u64 {
        self.served
    }

    pub fn mean_queue_delay(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.queued / self.served as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_server_serves_immediately() {
        let mut s = Server::new();
        assert_eq!(s.admit(10.0, 5.0), 15.0);
        assert_eq!(s.mean_queue_delay(), 0.0);
    }

    #[test]
    fn busy_server_queues() {
        let mut s = Server::new();
        s.admit(0.0, 10.0); // busy until 10
        let done = s.admit(2.0, 5.0); // waits 8
        assert_eq!(done, 15.0);
        assert_eq!(s.mean_queue_delay(), 4.0); // (0 + 8) / 2
    }

    #[test]
    fn utilization_accounting() {
        let mut s = Server::new();
        s.admit(0.0, 30.0);
        s.admit(50.0, 20.0);
        assert!((s.utilization(100.0) - 0.5).abs() < 1e-12);
        assert_eq!(s.served(), 2);
    }

    #[test]
    fn back_to_back_jobs_serialize() {
        let mut s = Server::new();
        let mut done = 0.0;
        for _ in 0..10 {
            done = s.admit(0.0, 7.0);
        }
        assert_eq!(done, 70.0);
    }
}
