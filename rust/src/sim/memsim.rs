//! Transaction-level memory-access simulation over a [`Fabric`]: each
//! transaction walks its routed path hop by hop; every link direction is an
//! FCFS [`Server`] sized by that link's serialization time, so contention
//! and head-of-line blocking emerge rather than being assumed.

use super::engine::{Engine, EventKind};
use super::server::Server;
use crate::fabric::{Fabric, NodeId};
use crate::util::stats::Welford;

/// One memory transaction (request; the response is modeled by doubling
/// the one-way latency contribution of symmetric protocol phases).
#[derive(Clone, Debug)]
pub struct Transaction {
    pub src: NodeId,
    pub dst: NodeId,
    /// Request issue time, ns.
    pub at: f64,
    /// Payload bytes moved.
    pub bytes: f64,
    /// Fixed service time at the destination device (e.g. DRAM access), ns.
    pub device_ns: f64,
}

/// Aggregate results of a simulation run.
#[derive(Clone, Debug)]
pub struct MemSimReport {
    pub completed: u64,
    pub latency: Welford,
    /// Simulated makespan, ns.
    pub makespan_ns: f64,
    /// Events dispatched (engine throughput metric).
    pub events: u64,
}

struct InFlight {
    tx: Transaction,
    path_links: Vec<usize>,
    issued: f64,
}

/// Precomputed per-link hot-path constants (§Perf: avoids re-deriving
/// PHY/flit math on every arrival event).
#[derive(Clone, Copy)]
struct LinkConsts {
    /// 1 / (raw_bw * phy_efficiency), ns per wire byte.
    inv_rate: f64,
    /// prop + phy + framing, ns.
    fixed_ns: f64,
    /// switch traversal at node a / node b (0 if not a switch).
    switch_ns: [f64; 2],
}

/// The simulator.
pub struct MemSim<'f> {
    fabric: &'f Fabric,
    /// one server per (link, direction)
    servers: Vec<[Server; 2]>,
    consts: Vec<LinkConsts>,
}

impl<'f> MemSim<'f> {
    pub fn new(fabric: &'f Fabric) -> Self {
        let servers = (0..fabric.topo.links.len()).map(|_| [Server::new(), Server::new()]).collect();
        let consts = fabric
            .topo
            .links
            .iter()
            .map(|l| {
                let p = &l.params;
                let sw = |n: crate::fabric::NodeId| {
                    fabric.topo.node(n).switch.as_ref().map(|s| s.traversal_ns()).unwrap_or(0.0)
                };
                LinkConsts {
                    inv_rate: 1.0 / (p.raw_bw * p.phy.efficiency()),
                    fixed_ns: p.prop_ns + p.phy.latency_ns() + p.flit_overhead_ns,
                    switch_ns: [sw(l.a), sw(l.b)],
                }
            })
            .collect();
        MemSim { fabric, servers, consts }
    }

    /// Run all transactions to completion; returns latency statistics.
    /// Transactions must be pre-sorted by issue time (asserted).
    pub fn run(&mut self, txs: Vec<Transaction>) -> MemSimReport {
        let mut engine = Engine::new();
        let mut inflight: Vec<Option<InFlight>> = Vec::with_capacity(txs.len());
        let mut last = f64::NEG_INFINITY;
        let router = self.fabric.router();
        let mut links = Vec::new();
        for tx in txs {
            assert!(tx.at >= last, "transactions must be sorted by issue time");
            last = tx.at;
            if !router.links_into(tx.src, tx.dst, &mut links) && tx.src != tx.dst {
                panic!("no path {} -> {}", tx.src, tx.dst);
            }
            let id = inflight.len();
            engine.schedule(tx.at, EventKind::Arrive { id, hop: 0 });
            inflight.push(Some(InFlight { issued: tx.at, path_links: links.clone(), tx }));
        }

        let mut latency = Welford::new();
        let mut completed = 0u64;
        while let Some((now, ev)) = engine.next() {
            match ev {
                EventKind::Arrive { id, hop } => {
                    let fl = inflight[id].as_ref().unwrap();
                    if hop >= fl.path_links.len() {
                        // reached destination: pay device service then complete
                        let dev = fl.tx.device_ns;
                        engine.after(dev, EventKind::Complete { id });
                        continue;
                    }
                    let link_idx = fl.path_links[hop];
                    let link = self.fabric.topo.link(link_idx);
                    let c = &self.consts[link_idx];
                    // direction: 0 = a->b
                    let from = if hop == 0 {
                        fl.tx.src
                    } else {
                        let prev = self.fabric.topo.link(fl.path_links[hop - 1]);
                        // the node shared between prev and this link
                        if prev.a == link.a || prev.b == link.a { link.a } else { link.b }
                    };
                    let dir = if from == link.a { 0 } else { 1 };
                    let service = link.params.flit.wire_bytes(fl.tx.bytes) * c.inv_rate;
                    let done = self.servers[link_idx][dir].admit(now, service);
                    // fixed per-hop latency + switch traversal at the
                    // receiving node (precomputed — §Perf)
                    let sw = c.switch_ns[1 - dir];
                    engine.schedule(done + c.fixed_ns + sw, EventKind::Arrive { id, hop: hop + 1 });
                }
                EventKind::Complete { id } => {
                    let fl = inflight[id].take().unwrap();
                    latency.push(now - fl.issued);
                    completed += 1;
                }
                _ => {}
            }
        }
        MemSimReport { completed, latency, makespan_ns: engine.now(), events: engine.dispatched() }
    }

    /// Utilization of the busiest link direction over the makespan.
    pub fn peak_utilization(&self, makespan_ns: f64) -> f64 {
        self.servers
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|s| s.utilization(makespan_ns))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, NodeKind, Topology};

    fn rack(n: usize) -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    #[test]
    fn single_transaction_matches_analytic_roughly() {
        let (f, accs) = rack(4);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 }]);
        assert_eq!(rep.completed, 1);
        let analytic = f.latency_ns(accs[0], accs[1], 4096.0).unwrap();
        let simulated = rep.latency.mean();
        let ratio = simulated / analytic;
        // same factors modeled; the event path serializes per hop rather
        // than cut-through, so allow a 2.5x band
        assert!(ratio > 0.8 && ratio < 2.5, "sim {simulated} vs analytic {analytic}");
    }

    #[test]
    fn contention_increases_latency() {
        let (f, accs) = rack(8);
        // all 7 sources hammer acc0 simultaneously -> fan-in on its link
        let mk = |i: usize| Transaction { src: accs[i], dst: accs[0], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run((1..8).map(mk).collect());
        assert_eq!(rep.completed, 7);
        assert!(rep.latency.max() > 3.0 * solo, "fan-in must queue: max {} vs solo {solo}", rep.latency.max());
    }

    #[test]
    fn disjoint_pairs_do_not_interfere() {
        let (f, accs) = rack(8);
        let mk = |s: usize, d: usize| Transaction { src: accs[s], dst: accs[d], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(0, 1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run(vec![mk(0, 1), mk(2, 3), mk(4, 5), mk(6, 7)]);
        // disjoint src links, disjoint dst links: only switch shared (not a server here)
        assert!((rep.latency.max() - solo) / solo < 0.05, "disjoint pairs interfered");
    }

    #[test]
    fn device_time_adds() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let base = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 }]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let with_dev = sim2.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 500.0 }]).latency.mean();
        assert!((with_dev - base - 500.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_transactions_rejected() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        sim.run(vec![
            Transaction { src: accs[0], dst: accs[1], at: 10.0, bytes: 64.0, device_ns: 0.0 },
            Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 },
        ]);
    }

    #[test]
    fn throughput_bounded_by_link_bandwidth() {
        let (f, accs) = rack(2);
        // 100 back-to-back 1 MB transfers over a 100 GB/s link: >= 1 ms total
        let txs: Vec<_> = (0..100)
            .map(|i| Transaction { src: accs[0], dst: accs[1], at: i as f64, bytes: 1e6, device_ns: 0.0 })
            .collect();
        let mut sim = MemSim::new(&f);
        let rep = sim.run(txs);
        let min_makespan = 100.0 * 1e6 / 100.0; // bytes / (bytes/ns)
        assert!(rep.makespan_ns > min_makespan, "makespan {} below wire limit {min_makespan}", rep.makespan_ns);
        assert!(sim.peak_utilization(rep.makespan_ns) > 0.9);
    }
}
