//! Transaction-level memory-access simulation over a [`Fabric`]: each
//! transaction walks its routed path hop by hop; every link direction is a
//! class-aware [`ClassedServer`] sized by that link's serialization time,
//! so contention and head-of-line blocking emerge rather than being
//! assumed. The default policy is class-blind FCFS — byte-identical to
//! the pre-QoS plain `Server` — and [`MemSim::set_qos`] swaps in
//! strict-priority or weighted-fair arbitration per link tier (module
//! [`qos`](super::qos)).
//!
//! # Performance architecture (§Perf)
//!
//! Routed paths are *interned* per `(src, dst, rail)` triple: the
//! N-transactions-per-pair case (every workload sweep) shares one
//! contiguous hop slice in a common arena instead of cloning a
//! `Vec<usize>` per transaction. The cache key packs
//! `(src << 34) | (dst << 4) | rail` into one `u64`, so the hot-path
//! probe hashes a single word instead of a tuple. Each arena entry packs
//! `(link << 1) | direction` — the hop's direction bit is computed once at
//! path-build time, so the per-event handler never re-derives it by
//! comparing link endpoints. Combined with the slab [`Engine`] this keeps
//! the Arrive hot path to: one inflight load, one arena load, one
//! `LinkConsts` load, one server admit, one schedule.
//!
//! # Copy-on-write sweep forking (§Perf)
//!
//! Sweeps (fig7 working-set points, the `qos`/`rails` policy grids) run
//! many points over one immutable system. [`MemSim::fork`] produces a
//! cheap per-point clone: the link constants, structural tiers and the
//! interned path arena are shared behind `Arc`s, while the mutable state
//! — link servers, realized-diversity telemetry, and any paths interned
//! after the fork (a private *overlay*) — is fresh per point. The
//! canonical sweep shape is build once, run the first point on the
//! master (lazily interning every path the workload rides), then
//! [`MemSim::freeze_paths`] and fork each remaining point: forks replay
//! the warmed arena without a single route walk or hash insert, and
//! never rebuild the O(links) constant tables. A fork is observably
//! identical to a freshly built simulator with the same configuration
//! (pinned by `prop_forked_sim_matches_fresh_build`).
//!
//! # Multi-rail routing
//!
//! On a multipath-enabled fabric ([`Fabric::enable_multipath`]) the
//! active [`RoutingPolicy`] decides, **once per transaction at injection
//! time**, which equal-cost rail it rides: rail 0 (deterministic — the
//! parity baseline), an ECMP hash over `(src, dst, tx_seq)`
//! ([`RailSelector::HashSpray`]), or the least-backlogged candidate path
//! by live [`ClassedServer`] state ([`RailSelector::Adaptive`]). The
//! resolved rail index is applied per hop only at cells whose
//! [`LinkTier`] has a spreading selector; deterministic tiers stay on
//! rail 0. Under the all-deterministic default (or a single-path
//! fabric), every path, latency and makespan is byte-identical to the
//! pre-multipath simulator.
//!
//! # Streamed injection
//!
//! The core loop is [`MemSim::run_streamed`]: [`TrafficSource`]s are
//! pulled one transaction ahead as the clock advances, and in-flight slots
//! are recycled through a free list — a million-transaction run holds the
//! peak *concurrent* transaction count in memory, never the whole
//! workload. [`MemSim::run`] is the batch adapter over the same loop
//! (a [`BatchSource`] wrapping the pre-sorted `Vec<Transaction>`).

use super::engine::{Engine, EventKind};
use super::qos::{self, Admission, BatchAdmit, ClassedServer, LinkClassStats, LinkTier, QosPolicy};
use super::rails::{spray_rail, RailSelector, RoutingPolicy};
use super::trace::{GaugeSample, TraceConfig, TraceData, TraceSink};
use super::traffic::{BatchSource, Pull, SourcedTx, StreamReport, TrafficClass, TrafficSource};
use crate::fabric::flit::FlitFormat;
use crate::fabric::{Fabric, NodeId};
use crate::util::stats::Welford;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// One memory transaction (request; the response is modeled by doubling
/// the one-way latency contribution of symmetric protocol phases).
#[derive(Clone, Debug)]
pub struct Transaction {
    pub src: NodeId,
    pub dst: NodeId,
    /// Request issue time, ns.
    pub at: f64,
    /// Payload bytes moved.
    pub bytes: f64,
    /// Fixed service time at the destination device (e.g. DRAM access), ns.
    pub device_ns: f64,
}

/// Aggregate results of a simulation run.
#[derive(Clone, Debug)]
pub struct MemSimReport {
    pub completed: u64,
    pub latency: Welford,
    /// Simulated makespan, ns.
    pub makespan_ns: f64,
    /// Events dispatched (engine throughput metric; streamed runs count
    /// one injection event per transaction on top of the hop events).
    pub events: u64,
}

/// Per-transaction state: issue time plus a borrowed slice of the shared
/// hop arena (start/len), not an owned path. Slots are recycled through a
/// free list, so the table size equals peak concurrency.
struct InFlight {
    issued: f64,
    bytes: f64,
    device_ns: f64,
    path_start: u32,
    path_len: u32,
    /// Index of the emitting source.
    source: u32,
    /// Traffic class (the VC every hop's server files this under).
    class: TrafficClass,
    /// Source-defined token echoed back on completion.
    token: u64,
}

/// Precomputed per-link hot-path constants (§Perf: avoids re-deriving
/// PHY/flit math and link-struct lookups on every arrival event).
/// `pub(crate)` so the sharded workers in [`super::shard`] share them.
#[derive(Clone, Copy)]
pub(crate) struct LinkConsts {
    /// 1 / (raw_bw * phy_efficiency), ns per wire byte.
    pub(crate) inv_rate: f64,
    /// prop + phy + framing, ns.
    pub(crate) fixed_ns: f64,
    /// switch traversal at node a / node b (0 if not a switch).
    pub(crate) switch_ns: [f64; 2],
    /// Flit format, copied out of the link so the handler touches no
    /// topology memory.
    pub(crate) flit: FlitFormat,
}

/// Lifecycle of a source inside the streamed loop.
#[derive(Clone, Copy, PartialEq)]
enum SrcState {
    Active,
    /// Waiting on one of its own completions (`Pull::Blocked`).
    Blocked,
    Done,
}

/// The frozen, fork-shared half of the path-interning state: interned
/// hop slices plus the `(src, dst, rail)` -> slice index. Forks hold it
/// behind an `Arc` and intern any path *not* already frozen into a
/// private overlay, so sweep points share one warmed arena without
/// copying it and without synchronization on the hot path.
#[derive(Debug, Default)]
struct PathArena {
    /// interned hops, `(link << 1) | dir`, contiguous per path
    hops: Vec<u32>,
    /// [`path_key`]`(src, dst, rail)` -> (start, len) into `hops`.
    /// Rails that walk to an identical hop sequence alias one slice.
    cache: HashMap<u64, (u32, u32)>,
}

/// The simulator.
pub struct MemSim<'f> {
    pub(crate) fabric: &'f Fabric,
    /// one class-aware server per (link, direction)
    pub(crate) servers: Vec<[ClassedServer; 2]>,
    pub(crate) consts: Arc<Vec<LinkConsts>>,
    /// Structural tier of each link (QoS policy granularity).
    pub(crate) tiers: Arc<Vec<LinkTier>>,
    /// The active per-tier arbitration configuration.
    qos: QosPolicy,
    /// The active per-tier rail-selection configuration.
    routing: RoutingPolicy,
    /// Which tiers spread beyond rail 0 (derived from `routing`; shared
    /// with the sharded workers).
    pub(crate) spread: [bool; LinkTier::COUNT],
    /// Serialization-time quantum of the fastest link: the calendar
    /// engine's bucket-width floor (§Perf).
    pub(crate) granularity: f64,
    /// The frozen fork-shared arena ([`MemSim::freeze_paths`]). Slice
    /// starts below `paths.hops.len()` index into it; starts at or above
    /// index into this instance's overlay. A path never spans both.
    paths: Arc<PathArena>,
    /// Hops interned after the last freeze, private to this instance.
    overlay_hops: Vec<u32>,
    /// Cache entries interned after the last freeze. Keys are disjoint
    /// from the frozen cache (the frozen cache is probed first).
    overlay_cache: HashMap<u64, (u32, u32)>,
    /// Distinct arena slices transactions actually rode (serial streamed
    /// backend) — the realized-diversity numerator, as opposed to the
    /// cache keys, which also count adaptive *probes* and aliased rails.
    used_paths: HashSet<(u32, u32)>,
    /// Distinct `(src, dst)` pairs that carried traffic.
    used_pairs: HashSet<u64>,
    /// Express dispatch (peek-gated hop fusion) on the streamed
    /// backends. On by default — provably byte-inert, pinned by
    /// `prop_fused_matches_unfused` — the switch exists for A/B
    /// benchmarking (`SCALEPOOL_BENCH_FUSION=off`) and bisection.
    pub(crate) fuse: bool,
    /// Flight-recorder configuration ([`MemSim::set_trace`]); `None`
    /// (the default) keeps every event arm on the record-nothing path.
    pub(crate) trace_cfg: Option<TraceConfig>,
    /// Records of the last traced run ([`MemSim::take_trace`]).
    pub(crate) trace_out: Option<TraceData>,
}

/// Path-cache key: `(src << 34) | (dst << 4) | rail`. Node ids stay far
/// below 2^30 (the n x n table's memory gives out long before the pack
/// becomes ambiguous — asserted in [`MemSim::new`]) and rails are capped
/// at [`crate::fabric::routing::MAX_RAILS`] = 16 by the router build.
#[inline]
pub(crate) fn path_key(src: NodeId, dst: NodeId, rail: u16) -> u64 {
    debug_assert!(src < (1 << 30) && dst < (1 << 30) && rail < 16);
    ((src as u64) << 34) | ((dst as u64) << 4) | rail as u64
}

/// One rail-aware PBR step: the equal-cost candidate taken at `at`
/// toward `dst` under the `spread` tier mask — candidate
/// `rail % rails(cell)` where the cell's tier spreads, rail 0 otherwise.
/// `None` when unreachable. Shared by the serial interner, the sharded
/// workers' interner and the sharded coordinator's first-hop targeting.
#[inline]
pub(crate) fn rail_step(
    fabric: &Fabric,
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    at: NodeId,
    dst: NodeId,
    rail: u16,
) -> Option<(NodeId, usize)> {
    let router = fabric.router();
    let rails = router.rails(at, dst);
    if rails == 0 {
        return None;
    }
    let idx = if rails > 1 {
        // the cell's tier comes from its rail-0 link (equal-cost
        // candidates at one node share a structural tier in every
        // Figure-4a shape; rail 0 is the deterministic anchor)
        let (_, l0) = router.rail_entry(at, dst, 0).expect("rails > 0");
        if spread[tiers[l0].index()] {
            rail as usize % rails
        } else {
            0
        }
    } else {
        0
    };
    router.rail_entry(at, dst, idx)
}

/// Walk the rail-aware path src -> dst, appending packed
/// `(link << 1) | direction` hops to `out`. Returns false (leaving `out`
/// partially extended — callers truncate) when unreachable. The twin of
/// the pre-multipath `next_hop` walk, shared by [`MemSim::intern_path`]
/// and the sharded workers' local interner.
pub(crate) fn rail_hops(
    fabric: &Fabric,
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    src: NodeId,
    dst: NodeId,
    rail: u16,
    out: &mut Vec<u32>,
) -> bool {
    let n = fabric.router().node_count();
    let mut cur = src;
    let mut hops = 0usize;
    while cur != dst {
        let Some((nxt, link)) = rail_step(fabric, tiers, spread, cur, dst, rail) else {
            return false;
        };
        // direction bit decided once here, not per event: 0 = a -> b
        let dir = if fabric.topo.link(link).a == cur { 0u32 } else { 1u32 };
        out.push(((link as u32) << 1) | dir);
        cur = nxt;
        hops += 1;
        if hops > n {
            panic!("routing loop walking rail {rail} of {src} -> {dst}: cycled at node {cur}");
        }
    }
    true
}

impl<'f> MemSim<'f> {
    pub fn new(fabric: &'f Fabric) -> Self {
        // the path-cache key packs node ids into 30 bits (see `path_key`);
        // the n*n routing table exhausts memory long before this triggers
        assert!(
            fabric.topo.nodes.len() < (1 << 30),
            "fabric too large for the packed path-cache key"
        );
        let servers =
            (0..fabric.topo.links.len()).map(|_| [ClassedServer::fcfs(), ClassedServer::fcfs()]).collect();
        let tiers = qos::classify_links(&fabric.topo);
        let consts: Vec<LinkConsts> = fabric
            .topo
            .links
            .iter()
            .map(|l| {
                let p = &l.params;
                let sw = |n: NodeId| {
                    fabric.topo.node(n).switch.as_ref().map(|s| s.traversal_ns()).unwrap_or(0.0)
                };
                LinkConsts {
                    inv_rate: 1.0 / (p.raw_bw * p.phy.efficiency()),
                    fixed_ns: p.prop_ns + p.phy.latency_ns() + p.flit_overhead_ns,
                    switch_ns: [sw(l.a), sw(l.b)],
                    flit: p.flit,
                }
            })
            .collect();
        // calendar bucket-width floor: the wire time of one cache line on
        // the fastest link — no two hop events of one flow land closer
        let granularity = consts
            .iter()
            .map(|c| c.flit.wire_bytes(64.0) * c.inv_rate)
            .fold(f64::INFINITY, f64::min)
            .clamp(1e-3, 1e3);
        MemSim {
            fabric,
            servers,
            consts: Arc::new(consts),
            tiers: Arc::new(tiers),
            qos: QosPolicy::fcfs(),
            routing: RoutingPolicy::deterministic(),
            spread: [false; LinkTier::COUNT],
            granularity,
            paths: Arc::new(PathArena::default()),
            overlay_hops: Vec::new(),
            overlay_cache: HashMap::new(),
            used_paths: HashSet::new(),
            used_pairs: HashSet::new(),
            fuse: true,
            trace_cfg: None,
            trace_out: None,
        }
    }

    /// Enable/disable express dispatch (peek-gated hop fusion) for the
    /// streamed backends, serial and sharded. On by default; fusion is
    /// byte-inert (`prop_fused_matches_unfused`), so the only observable
    /// difference is wall-clock time and the [`StreamReport::fused_hops`]
    /// telemetry.
    pub fn set_fusion(&mut self, on: bool) {
        self.fuse = on;
    }

    /// Whether express dispatch is enabled.
    pub fn fusion(&self) -> bool {
        self.fuse
    }

    /// Fork a cheap per-sweep-point clone: the link constants, tiers and
    /// the frozen path arena are shared behind `Arc`s; the servers (built
    /// fresh under the active QoS policy), telemetry, and path overlay
    /// start empty. The fork is observably identical to
    /// `MemSim::new(fabric)` followed by the same `set_qos`/`set_routing`
    /// calls — pinned by `prop_forked_sim_matches_fresh_build` — but
    /// skips the O(links) constant-table rebuild and (after
    /// [`MemSim::freeze_paths`]) every route walk the master already paid.
    ///
    /// The parent's *unfrozen* overlay is not carried over (forks re-walk
    /// those paths lazily); call [`MemSim::freeze_paths`] on the master
    /// first to share a warmed arena.
    pub fn fork(&self) -> MemSim<'f> {
        let servers = self
            .tiers
            .iter()
            .map(|t| {
                let p = self.qos.tier(*t);
                [ClassedServer::new(p), ClassedServer::new(p)]
            })
            .collect();
        MemSim {
            fabric: self.fabric,
            servers,
            consts: Arc::clone(&self.consts),
            tiers: Arc::clone(&self.tiers),
            qos: self.qos,
            routing: self.routing,
            spread: self.spread,
            granularity: self.granularity,
            paths: Arc::clone(&self.paths),
            overlay_hops: Vec::new(),
            overlay_cache: HashMap::new(),
            used_paths: HashSet::new(),
            used_pairs: HashSet::new(),
            fuse: self.fuse,
            // the recorder configuration forks with the point; recorded
            // data does not (each fork records its own run)
            trace_cfg: self.trace_cfg,
            trace_out: None,
        }
    }

    /// Arm the flight recorder: the next streamed run (serial or sharded)
    /// records hop-level spans, gauges, and backend instants into a
    /// bounded ring, retrievable via [`MemSim::take_trace`]. Forks
    /// inherit the configuration. Recording never changes simulation
    /// output (pinned by `prop_tracing_is_inert`).
    pub fn set_trace(&mut self, cfg: TraceConfig) {
        self.trace_cfg = Some(cfg);
    }

    /// Disarm the flight recorder (subsequent runs record nothing).
    pub fn clear_trace(&mut self) {
        self.trace_cfg = None;
    }

    /// The active flight-recorder configuration, if armed.
    pub fn trace_config(&self) -> Option<TraceConfig> {
        self.trace_cfg
    }

    /// Take the records of the last traced run (`None` when the recorder
    /// was not armed or no run has finished since).
    pub fn take_trace(&mut self) -> Option<TraceData> {
        self.trace_out.take()
    }

    /// Merge this instance's path overlay into the fork-shared arena, so
    /// subsequent [`MemSim::fork`]s replay every path interned so far
    /// without re-walking the router. Global slice indices are unchanged
    /// (overlay entries were already numbered past the frozen base), so
    /// freezing mid-run is safe. A no-op when nothing new was interned.
    pub fn freeze_paths(&mut self) {
        if self.overlay_cache.is_empty() && self.overlay_hops.is_empty() {
            return;
        }
        let mut merged = PathArena {
            hops: Vec::with_capacity(self.paths.hops.len() + self.overlay_hops.len()),
            cache: HashMap::with_capacity(self.paths.cache.len() + self.overlay_cache.len()),
        };
        merged.hops.extend_from_slice(&self.paths.hops);
        merged.hops.append(&mut self.overlay_hops);
        merged.cache.extend(self.paths.cache.iter().map(|(&k, &v)| (k, v)));
        merged.cache.extend(self.overlay_cache.drain());
        self.paths = Arc::new(merged);
    }

    /// Build a simulator with a QoS configuration already applied.
    pub fn with_qos(fabric: &'f Fabric, policy: QosPolicy) -> Self {
        let mut sim = MemSim::new(fabric);
        sim.set_qos(policy);
        sim
    }

    /// Build a simulator with a rail-selection configuration already
    /// applied (meaningful on a multipath-enabled fabric —
    /// [`Fabric::enable_multipath`]).
    pub fn with_routing(fabric: &'f Fabric, policy: RoutingPolicy) -> Self {
        let mut sim = MemSim::new(fabric);
        sim.set_routing(policy);
        sim
    }

    /// Apply a per-tier rail-selection configuration. Interned paths
    /// depend only on the *spread mask*, not the selector (a rail-aware
    /// walk consults which tiers spread, never how the rail index was
    /// chosen), so the path cache survives a policy change with an equal
    /// mask (e.g. HashSpray -> Adaptive everywhere) and is discarded
    /// otherwise. Call before running traffic; the coordinator's
    /// [`RoutingManager`](crate::coordinator::RoutingManager) is the
    /// usual owner. A no-op in effect on a single-path fabric
    /// (`max_rails() == 1`), where every cell holds one candidate.
    pub fn set_routing(&mut self, policy: RoutingPolicy) {
        let keep_paths = policy.spread_mask() == self.spread;
        self.routing = policy;
        self.spread = policy.spread_mask();
        if !keep_paths {
            self.paths = Arc::new(PathArena::default());
            self.overlay_hops.clear();
            self.overlay_cache.clear();
        }
        self.used_paths.clear();
        self.used_pairs.clear();
    }

    /// The active rail-selection configuration.
    pub fn routing_policy(&self) -> RoutingPolicy {
        self.routing
    }

    /// Apply a per-tier arbitration configuration: every link direction
    /// gets a fresh [`ClassedServer`] running its tier's policy (so any
    /// telemetry accumulated before the call is discarded). Call before
    /// running traffic; the coordinator's
    /// [`QosManager`](crate::coordinator::QosManager) is the usual owner.
    pub fn set_qos(&mut self, policy: QosPolicy) {
        self.qos = policy;
        for (li, tier) in self.tiers.iter().enumerate() {
            let p = policy.tier(*tier);
            self.servers[li] = [ClassedServer::new(p), ClassedServer::new(p)];
        }
    }

    /// The active QoS configuration.
    pub fn qos_policy(&self) -> QosPolicy {
        self.qos
    }

    /// Structural tier of link `link` (QoS policy granularity).
    pub fn link_tier(&self, link: usize) -> LinkTier {
        self.tiers[link]
    }

    /// Snapshot the per-link per-class service telemetry (only link
    /// directions that served traffic are listed). Also exported into
    /// [`StreamReport::qos`] at the end of every streamed run.
    pub fn collect_qos_stats(&self) -> Vec<LinkClassStats> {
        let mut out = Vec::new();
        for (li, pair) in self.servers.iter().enumerate() {
            for (dir, srv) in pair.iter().enumerate() {
                for class in TrafficClass::ALL {
                    let st = srv.class_stats(class);
                    if st.served > 0 {
                        out.push(LinkClassStats {
                            link: li as u32,
                            dir: dir as u8,
                            tier: self.tiers[li],
                            class,
                            served: st.served,
                            bytes: st.bytes,
                            busy_ns: st.busy_ns,
                            queue_delay_ns: st.queued_ns,
                        });
                    }
                }
            }
        }
        out
    }

    /// The hop slice behind a `(start, len)` cache entry: starts below
    /// the frozen base index into the shared arena, the rest into this
    /// instance's private overlay (a path never spans both).
    #[inline]
    fn path_hops(&self, start: u32, len: u32) -> &[u32] {
        let base = self.paths.hops.len() as u32;
        if start >= base {
            let s = (start - base) as usize;
            &self.overlay_hops[s..s + len as usize]
        } else {
            &self.paths.hops[start as usize..(start + len) as usize]
        }
    }

    /// Hop `i` of the path starting at global index `start` (§Perf: the
    /// per-event load — one branch, one indexed read).
    #[inline]
    fn hop_at(&self, start: u32, i: usize) -> u32 {
        let base = self.paths.hops.len() as u32;
        if start >= base {
            self.overlay_hops[(start - base) as usize + i]
        } else {
            self.paths.hops[start as usize + i]
        }
    }

    /// Intern the routed path src -> dst along `rail`: returns
    /// (start, len) into the hop arena, building (with per-hop direction
    /// bits) on first use. None when unreachable. Frozen (fork-shared)
    /// entries are probed first; misses build into the private overlay,
    /// numbered past the frozen base so [`MemSim::freeze_paths`] can
    /// merge without renumbering.
    ///
    /// Distinct rail indices frequently collapse onto the same hop
    /// sequence (a cell with fewer than `rail + 1` candidates wraps, and
    /// deterministic tiers ignore the index entirely); those are aliased
    /// to one arena slice, so duplicate probes cost no arena memory and
    /// the slice identity `(start, len)` means "same physical path".
    fn intern_path(&mut self, src: NodeId, dst: NodeId, rail: u16) -> Option<(u32, u32)> {
        let key = path_key(src, dst, rail);
        if let Some(&r) = self.paths.cache.get(&key) {
            return Some(r);
        }
        if let Some(&r) = self.overlay_cache.get(&key) {
            return Some(r);
        }
        let base = self.paths.hops.len() as u32;
        let local_start = self.overlay_hops.len();
        if !rail_hops(self.fabric, &self.tiers, self.spread, src, dst, rail, &mut self.overlay_hops)
        {
            self.overlay_hops.truncate(local_start);
            return None;
        }
        let mut entry =
            (base + local_start as u32, (self.overlay_hops.len() - local_start) as u32);
        // scan EVERY cached rail of the pair (rails intern in hash order,
        // not ascending, so an alias may sit at a higher index): identical
        // content can therefore never be stored twice
        let k = self.fabric.router().max_rails() as u16;
        for r in 0..k {
            if r == rail {
                continue;
            }
            let alias_key = path_key(src, dst, r);
            let alias = self
                .paths
                .cache
                .get(&alias_key)
                .or_else(|| self.overlay_cache.get(&alias_key))
                .copied();
            if let Some((s0, l0)) = alias {
                if l0 == entry.1 && *self.path_hops(s0, l0) == self.overlay_hops[local_start..] {
                    self.overlay_hops.truncate(local_start);
                    entry = (s0, l0);
                    break;
                }
            }
        }
        self.overlay_cache.insert(key, entry);
        Some(entry)
    }

    /// Resolve which rail a transaction rides, per the active
    /// [`RoutingPolicy`] — called once per transaction at injection time.
    /// `seq` is the spray hash input: the per-source emission index, or
    /// the source-supplied flow id when one was attached
    /// ([`SourcedTx::flow`] — per-flow rail affinity).
    fn resolve_rail(&mut self, src: NodeId, dst: NodeId, seq: u64, now: f64) -> u16 {
        let k = self.fabric.router().max_rails();
        if k <= 1 || self.spread == [false; LinkTier::COUNT] {
            return 0;
        }
        match self.routing.resolution() {
            RailSelector::Deterministic => 0,
            RailSelector::HashSpray => spray_rail(src, dst, seq, k),
            RailSelector::Adaptive => {
                // score every candidate rail path by the live service
                // backlog on its links; least-loaded wins, ties to the
                // lowest rail (so an idle fabric is exactly rail 0)
                let mut best = 0u16;
                let mut best_score = f64::INFINITY;
                for r in 0..k as u16 {
                    let Some((start, len)) = self.intern_path(src, dst, r) else {
                        break;
                    };
                    let mut score = 0.0;
                    for h in self.path_hops(start, len) {
                        let link = (h >> 1) as usize;
                        let dir = (h & 1) as usize;
                        score += self.servers[link][dir].pending_ns(now);
                    }
                    if score < best_score {
                        best_score = score;
                        best = r;
                    }
                }
                best
            }
        }
    }

    /// Number of distinct (src, dst, rail) cache entries interned so far
    /// — frozen arena plus this instance's overlay (cache telemetry:
    /// includes adaptive probes and aliased rails). The two key sets are
    /// disjoint (the frozen cache is probed first), so the sum counts
    /// each triple once.
    pub fn interned_paths(&self) -> usize {
        self.paths.cache.len() + self.overlay_cache.len()
    }

    /// Number of distinct (src, dst) pairs among the interned entries.
    pub fn interned_pairs(&self) -> usize {
        let pairs: HashSet<u64> =
            self.paths.cache.keys().chain(self.overlay_cache.keys()).map(|&k| k >> 4).collect();
        pairs.len()
    }

    /// Distinct physical paths transactions actually rode — adaptive
    /// probes and rail indices that alias the same hop sequence do not
    /// count. `used_path_count() / used_pair_count()` is the realized
    /// path diversity the `rails` experiment reports. Populated by the
    /// serial streamed backend on multipath-enabled fabrics only
    /// (single-path runs skip the accounting; their diversity is 1 by
    /// construction).
    pub fn used_path_count(&self) -> usize {
        self.used_paths.len()
    }

    /// Distinct (src, dst) pairs that actually carried traffic (same
    /// population rules as [`MemSim::used_path_count`]).
    pub fn used_pair_count(&self) -> usize {
        self.used_pairs.len()
    }

    /// Advance transaction `id` (state `fl`) arriving at hop `hop` at
    /// time `at`: admit it to the link-direction server, or pay device
    /// time and complete. The single shared hop-advance of the serial
    /// backend — injection (hop 0, inline), the Arrive handler's batch
    /// members and the Depart chain all funnel into it (directly or via
    /// [`MemSim::commit_admission`]), so express dispatch has exactly
    /// one call site.
    ///
    /// FCFS servers time-release (the completion time is known at
    /// admission, no extra events); queued-mode policies defer backlogged
    /// transactions to the link's `Depart` chain, which re-schedules the
    /// next-hop Arrive when the arbiter starts them.
    ///
    /// `bound` is the express-dispatch ceiling (see
    /// [`MemSim::forward_local`]); `at` may sit ahead of the engine
    /// clock when reached by a fused chain. Returns the number of hops
    /// fused inline downstream of this admission.
    #[inline]
    fn step(
        &mut self,
        engine: &mut Engine,
        fl: &InFlight,
        at: f64,
        id: usize,
        hop: usize,
        bound: f64,
        trace: &mut Option<Box<TraceSink>>,
    ) -> u64 {
        if hop >= fl.path_len as usize {
            // reached destination: pay device service then complete
            engine.schedule(at + fl.device_ns, EventKind::Complete { id });
            return 0;
        }
        let h = self.hop_at(fl.path_start, hop);
        let link_idx = (h >> 1) as usize;
        let dir = (h & 1) as usize;
        let c = self.consts[link_idx];
        let service = c.flit.wire_bytes(fl.bytes) * c.inv_rate;
        let adm =
            self.servers[link_idx][dir].admit(at, service, fl.bytes, fl.class, id as u32, hop as u32);
        self.commit_admission(engine, fl, id, hop, link_idx, dir, service, adm, at, bound, trace)
    }

    /// Commit one admission outcome at time `at`: the hop span record,
    /// the queued-mode Depart chain, and the forward to the next hop.
    /// Shared by [`MemSim::step`]'s single admissions and the Arrive
    /// handler's batch admissions — the one place admission outcomes
    /// turn into scheduled (or fused) events in the serial backend.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn commit_admission(
        &mut self,
        engine: &mut Engine,
        fl: &InFlight,
        id: usize,
        hop: usize,
        link_idx: usize,
        dir: usize,
        service: f64,
        adm: Admission,
        at: f64,
        bound: f64,
        trace: &mut Option<Box<TraceSink>>,
    ) -> u64 {
        match adm {
            Admission::Release { done } => {
                if let Some(tr) = trace.as_deref_mut() {
                    // both admission flavors serve over [done-service, done]
                    tr.hop(id, at, done - service, done, link_idx, dir);
                }
                self.forward_local(engine, fl, id, hop, link_idx, dir, done, bound, trace)
            }
            Admission::Start { done } => {
                if let Some(tr) = trace.as_deref_mut() {
                    tr.hop(id, at, done - service, done, link_idx, dir);
                }
                engine
                    .schedule(done, EventKind::Depart { link: link_idx as u32, dir: dir as u8 });
                self.forward_local(engine, fl, id, hop, link_idx, dir, done, bound, trace)
            }
            Admission::Queued => {
                if let Some(tr) = trace.as_deref_mut() {
                    tr.queued(id, at);
                }
                0
            }
        }
    }

    /// Put transaction `id` onto the hop after `hop`, whose service on
    /// `(li, di)` finished at `done`: schedule the next-hop Arrive — or,
    /// under the express-dispatch gate, admit the next hop *inline* at
    /// its true arrival time and keep chaining (ISSUE 10's peek-gated
    /// hop fusion). Returns the number of hops fused.
    ///
    /// The gate: the next-hop arrival `t_next = done + fixed + switch`
    /// must be strictly earlier than both `bound` (events the caller
    /// knows are coming but has not filed yet — `-inf` disables fusion,
    /// the sharded workers pass their epoch horizon) and every pending
    /// event ([`Engine::would_dispatch_next`]). Strict `<` because an
    /// event scheduled at exactly `peek_time` dispatches *after* the
    /// already-pending same-time events (FIFO `seq` tie-break): only a
    /// strictly earlier arrival is guaranteed to be the very next
    /// dispatch, making the inline admission exactly the event the
    /// engine would have dispatched — byte-identical results, span
    /// chain included. A backlogged downstream server
    /// ([`ClassedServer::fuse_ready`]) or a failed gate ends the chain
    /// through the unchanged per-hop schedule path.
    #[allow(clippy::too_many_arguments)]
    fn forward_local(
        &mut self,
        engine: &mut Engine,
        fl: &InFlight,
        id: usize,
        hop: usize,
        li: usize,
        di: usize,
        done: f64,
        bound: f64,
        trace: &mut Option<Box<TraceSink>>,
    ) -> u64 {
        let (mut hop, mut li, mut di, mut done) = (hop, li, di, done);
        let mut fused = 0u64;
        loop {
            let c = self.consts[li];
            // fixed per-hop latency + switch traversal at the receiving
            // node (precomputed — §Perf). NOTE: the sum is associated
            // exactly as the pre-QoS hot path (`done + fixed + sw`) so
            // FCFS results stay byte-identical to the plain-Server oracle.
            let sw = c.switch_ns[1 - di];
            let t_next = done + c.fixed_ns + sw;
            let nh = hop + 1;
            if !(self.fuse && t_next < bound && engine.would_dispatch_next(t_next)) {
                engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
                return fused;
            }
            if nh >= fl.path_len as usize {
                // fused destination arrival: device service, then complete
                engine.schedule(t_next + fl.device_ns, EventKind::Complete { id });
                return fused + 1;
            }
            let h = self.hop_at(fl.path_start, nh);
            let nl = (h >> 1) as usize;
            let nd = (h & 1) as usize;
            if !self.servers[nl][nd].fuse_ready(t_next) {
                // backlogged downstream server: degrade to per-hop dispatch
                engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
                return fused;
            }
            let c2 = self.consts[nl];
            let service = c2.flit.wire_bytes(fl.bytes) * c2.inv_rate;
            match self.servers[nl][nd].admit(t_next, service, fl.bytes, fl.class, id as u32, nh as u32)
            {
                Admission::Release { done: d } => {
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.hop(id, t_next, d - service, d, nl, nd);
                    }
                    fused += 1;
                    hop = nh;
                    li = nl;
                    di = nd;
                    done = d;
                }
                Admission::Start { done: d } => {
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.hop(id, t_next, d - service, d, nl, nd);
                    }
                    // the Depart at `d` lands before the following arrival,
                    // so the next gate check fails and the chain exits
                    // through the schedule path above
                    engine.schedule(d, EventKind::Depart { link: nl as u32, dir: nd as u8 });
                    fused += 1;
                    hop = nh;
                    li = nl;
                    di = nd;
                    done = d;
                }
                Admission::Queued => {
                    // unreachable under fuse_ready; kept as the safe
                    // degradation (identical to a dispatched arrival that
                    // parked in a VC)
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.queued(id, t_next);
                    }
                    return fused + 1;
                }
            }
        }
    }

    /// Run all transactions to completion; returns latency statistics.
    /// Transactions must be pre-sorted by issue time (asserted). This is
    /// the batch adapter over [`MemSim::run_streamed`].
    pub fn run(&mut self, txs: Vec<Transaction>) -> MemSimReport {
        let mut last = f64::NEG_INFINITY;
        for tx in &txs {
            assert!(tx.at >= last, "transactions must be sorted by issue time");
            last = tx.at;
        }
        let mut batch = BatchSource::new(txs, TrafficClass::Generic);
        let mut sources: [&mut dyn TrafficSource; 1] = [&mut batch];
        self.run_streamed(&mut sources).total
    }

    /// Build the serial run's recorder sink when the recorder is armed —
    /// the single `Option` check the off path pays per event arm.
    fn make_sink(&self) -> Option<Box<TraceSink>> {
        self.trace_cfg.map(|cfg| Box::new(TraceSink::new(&cfg, 0, cfg.capacity, &self.tiers)))
    }

    /// The streamed core: pull each source one transaction ahead, inject
    /// at issue time, dispatch hop/completion events, and route
    /// completions back to their source (which may unblock reactive
    /// emissions). Panics if a source goes `Blocked` with nothing in
    /// flight (a deadlock by the streaming contract) or a transaction's
    /// endpoints are unreachable.
    pub fn run_streamed(&mut self, sources: &mut [&mut dyn TrafficSource]) -> StreamReport {
        let n = sources.len();
        let mut engine = Engine::with_granularity(self.granularity);
        let classes: Vec<TrafficClass> = sources.iter().map(|s| s.class()).collect();
        let mut staged: Vec<Option<SourcedTx>> = (0..n).map(|_| None).collect();
        let mut state = vec![SrcState::Active; n];
        let mut inflight_count = vec![0usize; n];
        // per-source emission index: the rail selectors' tx_seq (identical
        // to the sharded coordinator's staging order, so HashSpray picks
        // the same rails on both backends)
        let mut emitted = vec![0u64; n];
        // realized-diversity telemetry is only meaningful (and only paid
        // for) on a multipath-enabled fabric — single-path runs keep the
        // injection path free of the two hash-set inserts
        let track_rails = self.fabric.router().max_rails() > 1;
        let mut slots: Vec<InFlight> = Vec::new();
        let mut free_slots: Vec<u32> = Vec::new();
        let mut report = StreamReport::new();
        // flight recorder: a local sink so the hot loop borrows it
        // independently of `self`; None (the default) records nothing
        let mut trace = self.make_sink();

        // Pull source `i` once (if active and unstaged) and schedule its
        // injection event.
        fn pump(
            i: usize,
            now: f64,
            sources: &mut [&mut dyn TrafficSource],
            staged: &mut [Option<SourcedTx>],
            state: &mut [SrcState],
            inflight_count: &[usize],
            engine: &mut Engine,
        ) {
            if state[i] != SrcState::Active || staged[i].is_some() {
                return;
            }
            match sources[i].pull(now) {
                Pull::Tx(stx) => {
                    let at = stx.tx.at.max(now);
                    engine.schedule(at, EventKind::Custom { tag: i as u64 });
                    staged[i] = Some(stx);
                }
                Pull::Blocked => {
                    assert!(
                        inflight_count[i] > 0,
                        "traffic source {i} blocked with nothing in flight (deadlock)"
                    );
                    state[i] = SrcState::Blocked;
                }
                Pull::Done => state[i] = SrcState::Done,
            }
        }

        for i in 0..n {
            pump(i, 0.0, sources, &mut staged, &mut state, &inflight_count, &mut engine);
        }

        // epoch-batching scratch (§Perf): consecutive same-timestamp
        // arrivals on one link direction admit as one batch, amortizing
        // the per-admission ClassedServer bookkeeping. An event popped
        // while probing for batch members that does not extend the batch
        // is carried into the next loop iteration unprocessed, so the
        // dispatch order (and therefore every result) is unchanged.
        let mut carried: Option<(f64, EventKind)> = None;
        let mut batch_ids: Vec<(usize, usize)> = Vec::new();
        let mut batch_items: Vec<BatchAdmit> = Vec::new();
        let mut admissions: Vec<Admission> = Vec::new();
        // hops admitted inline by express dispatch — each one is exactly
        // one calendar event the engine never had to file and pop
        let mut fused_hops = 0u64;

        loop {
            let Some((now, ev)) = carried.take().or_else(|| engine.next()) else {
                break;
            };
            if let Some(tr) = trace.as_deref_mut() {
                if tr.gauge_due(now) {
                    let t0 = std::time::Instant::now();
                    let mut busy = [0.0; LinkTier::COUNT];
                    let mut queued = [0u32; LinkTier::COUNT];
                    for (li, pair) in self.servers.iter().enumerate() {
                        let t = self.tiers[li].index();
                        for srv in pair {
                            busy[t] += srv.busy_ns();
                            queued[t] += srv.backlog() as u32;
                        }
                    }
                    tr.gauge(GaugeSample {
                        at: now,
                        shard: 0,
                        tier_busy_ns: busy,
                        tier_queued: queued,
                        inflight: (slots.len() - free_slots.len()) as u32,
                    });
                    tr.add_overhead(t0.elapsed().as_nanos() as f64);
                }
            }
            match ev {
                // injection: the staged transaction of source `tag`
                // reaches its issue time
                EventKind::Custom { tag } => {
                    let i = tag as usize;
                    let stx = staged[i].take().expect("staged transaction for injection event");
                    let tx = stx.tx;
                    let seq = emitted[i];
                    emitted[i] += 1;
                    // per-flow rail affinity: a source-supplied flow id
                    // replaces the emission index as the spray key, so an
                    // ordered stream rides one rail (ROADMAP item 4)
                    let rail = self.resolve_rail(tx.src, tx.dst, stx.flow.unwrap_or(seq), now);
                    let (path_start, path_len) = match self.intern_path(tx.src, tx.dst, rail) {
                        Some(r) => r,
                        None => panic!(
                            "no path {} ({}) -> {} ({}) for traffic source {} (class {})",
                            tx.src,
                            self.fabric.topo.node(tx.src).label,
                            tx.dst,
                            self.fabric.topo.node(tx.dst).label,
                            i,
                            classes[i].name()
                        ),
                    };
                    if track_rails {
                        // slice identity == physical path identity (aliased
                        // in intern_path): realized-diversity telemetry
                        self.used_paths.insert((path_start, path_len));
                        self.used_pairs.insert(((tx.src as u64) << 32) | tx.dst as u64);
                    }
                    let entry = InFlight {
                        issued: now,
                        bytes: tx.bytes,
                        device_ns: tx.device_ns,
                        path_start,
                        path_len,
                        source: i as u32,
                        class: classes[i],
                        token: stx.token,
                    };
                    let id = match free_slots.pop() {
                        Some(s) => {
                            slots[s as usize] = entry;
                            s as usize
                        }
                        None => {
                            slots.push(entry);
                            slots.len() - 1
                        }
                    };
                    inflight_count[i] += 1;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.inject(
                            id,
                            now,
                            tx.src,
                            tx.dst,
                            tx.bytes,
                            rail,
                            classes[i],
                            i,
                            slots[id].token,
                        );
                    }
                    // no fusion off the injection: the source is re-pumped
                    // only after this admission, so its next staged event is
                    // not yet in the engine and the peek gate would be blind
                    // to it
                    fused_hops +=
                        self.step(&mut engine, &slots[id], now, id, 0, f64::NEG_INFINITY, &mut trace);
                    pump(i, now, sources, &mut staged, &mut state, &inflight_count, &mut engine);
                }
                EventKind::Arrive { id, hop } => {
                    let fl = &slots[id];
                    if hop >= fl.path_len as usize {
                        // destination arrival: no link admission to batch
                        fused_hops +=
                            self.step(&mut engine, fl, now, id, hop, f64::NEG_INFINITY, &mut trace);
                        continue;
                    }
                    // epoch batching: coalesce the consecutive arrivals at
                    // exactly `now` that land on the same link direction
                    let h = self.hop_at(fl.path_start, hop);
                    batch_ids.clear();
                    batch_ids.push((id, hop));
                    while engine.peek_time() == Some(now) {
                        let (t2, ev2) = engine.next().expect("peeked event");
                        if let EventKind::Arrive { id: id2, hop: hop2 } = ev2 {
                            let fl2 = &slots[id2];
                            if hop2 < fl2.path_len as usize
                                && self.hop_at(fl2.path_start, hop2) == h
                            {
                                batch_ids.push((id2, hop2));
                                continue;
                            }
                        }
                        // not a batch member: defer to the next iteration
                        // (it was popped after the batch, so flushing the
                        // batch first preserves the serial handler order)
                        carried = Some((t2, ev2));
                        break;
                    }
                    let link_idx = (h >> 1) as usize;
                    let dir = (h & 1) as usize;
                    let c = self.consts[link_idx];
                    batch_items.clear();
                    for &(bid, bhop) in &batch_ids {
                        let fl = &slots[bid];
                        batch_items.push(BatchAdmit {
                            service: c.flit.wire_bytes(fl.bytes) * c.inv_rate,
                            bytes: fl.bytes,
                            class: fl.class,
                            id: bid as u32,
                            hop: bhop as u32,
                        });
                    }
                    admissions.clear();
                    self.servers[link_idx][dir].admit_batch(now, &batch_items, &mut admissions);
                    let last = admissions.len() - 1;
                    for (k, (adm, &(bid, bhop))) in admissions.iter().zip(&batch_ids).enumerate() {
                        // only the batch's last member may open an express
                        // chain: earlier members' next-hop arrivals are
                        // already filed by the time it forwards, but a later
                        // member's are not (the gate would be blind to
                        // them). A carried event at `now` disables fusion
                        // the same way — it is pending work the engine does
                        // not know about, and it must be handled before any
                        // admission at a later timestamp.
                        let bound = if k == last && carried.is_none() {
                            f64::INFINITY
                        } else {
                            f64::NEG_INFINITY
                        };
                        fused_hops += self.commit_admission(
                            &mut engine,
                            &slots[bid],
                            bid,
                            bhop,
                            link_idx,
                            dir,
                            batch_items[k].service,
                            *adm,
                            now,
                            bound,
                            &mut trace,
                        );
                    }
                }
                // a queued-mode link freed: arbitrate the next VC and put
                // the started transaction back on its path
                EventKind::Depart { link, dir } => {
                    let (li, di) = (link as usize, dir as usize);
                    if let Some((id, hop, done)) = self.servers[li][di].depart(now) {
                        if let Some(tr) = trace.as_deref_mut() {
                            // the arbiter starts the queued hop now; its
                            // arrival time was parked at admission
                            tr.departed(id as usize, now, done, li, di);
                        }
                        // next Depart first (the vanilla order), so it
                        // participates in the express gate below
                        engine.schedule(done, EventKind::Depart { link, dir });
                        fused_hops += self.forward_local(
                            &mut engine,
                            &slots[id as usize],
                            id as usize,
                            hop as usize,
                            li,
                            di,
                            done,
                            f64::INFINITY,
                            &mut trace,
                        );
                    }
                }
                EventKind::Complete { id } => {
                    let fl = &slots[id];
                    let i = fl.source as usize;
                    let token = fl.token;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.complete(id, now, now - fl.issued);
                    }
                    report.record(classes[i], now - fl.issued, fl.bytes);
                    free_slots.push(id as u32);
                    inflight_count[i] -= 1;
                    sources[i].on_complete(token, now);
                    if state[i] == SrcState::Blocked {
                        state[i] = SrcState::Active;
                    }
                    pump(i, now, sources, &mut staged, &mut state, &inflight_count, &mut engine);
                }
            }
        }
        report.total.makespan_ns = engine.now();
        // a fused hop is exactly the event the engine would have
        // dispatched next, so the logical event count — and therefore
        // every events-based parity assertion — is identical fusion on
        // or off
        report.total.events = engine.dispatched() + fused_hops;
        report.fused_hops = fused_hops;
        // the slot table's high-water mark IS the peak concurrency (slots
        // recycle through the free list) — the streaming memory contract
        report.peak_inflight = slots.len();
        report.qos = self.collect_qos_stats();
        if let Some(tr) = trace {
            let data = tr.into_data();
            report.dropped_spans = data.dropped_spans;
            report.trace_overhead_ns = data.overhead_ns;
            self.trace_out = Some(data);
        }
        report
    }

    /// Multi-core sibling of [`MemSim::run_streamed`]: partition the
    /// fabric into topology-derived domains (rack/leaf subtrees), run one
    /// calendar engine per shard on scoped worker threads, and hand
    /// cross-shard transactions off through per-shard mailboxes under
    /// conservative lookahead (bounded below by the minimum
    /// cross-partition hop latency). A reactive source that declares a
    /// static [`TrafficSource::footprint`] is co-located inside one shard
    /// by coupled-domain partitioning and runs *on* that shard's worker;
    /// open-loop sources are staged by the coordinator as before.
    /// Per-class completed counts, byte totals and the per-transaction
    /// latency multiset match the serial backend exactly (pinned by
    /// `prop_sharded_matches_serial`).
    ///
    /// A declared footprint that would collapse the partition (e.g. a
    /// fabric-wide ring) no longer forces a serial run: the source stays
    /// on the coordinator and executes optimistically — per-shard
    /// checkpoint at the epoch barrier, rollback + replay when a
    /// cross-shard completion invalidates the window's speculated
    /// injections (see [`super::shard`]'s module docs) — provided every
    /// reactive source supports [`TrafficSource::checkpoint`].
    ///
    /// Falls back to the serial loop when sharding cannot help or cannot
    /// be correct — a single shard, non-positive lookahead, a reactive
    /// source without a footprint, or a spanning footprint alongside a
    /// reactive source that cannot checkpoint — and says why in the
    /// report's [`ShardMode::SerialFallback`](super::traffic::ShardMode).
    pub fn run_streamed_sharded(&mut self, sources: &mut [&mut dyn TrafficSource]) -> StreamReport {
        let shards = crate::util::par::shards_for(usize::MAX);
        self.run_streamed_sharded_with(sources, shards)
    }

    /// As [`MemSim::run_streamed_sharded`] with an explicit shard-count
    /// cap (the actual count is `min(max_shards, topology domains)`).
    pub fn run_streamed_sharded_with(
        &mut self,
        sources: &mut [&mut dyn TrafficSource],
        max_shards: usize,
    ) -> StreamReport {
        use super::shard::{PlanOutcome, SourceMeta};
        let meta: Vec<SourceMeta> = sources
            .iter()
            .map(|s| {
                let open = s.open_loop();
                SourceMeta {
                    open,
                    footprint: if open { None } else { s.footprint() },
                    class: s.class(),
                    checkpointable: s.checkpointable(),
                }
            })
            .collect();
        // the effective rail fan at injection: footprint closures must
        // cover every rail a pinned source's traffic can spray over
        let rail_fan = self.fabric.router().max_rails();
        let spraying = rail_fan > 1
            && self.spread != [false; LinkTier::COUNT]
            && self.routing.resolution().spreads();
        let rails = if spraying { rail_fan as u16 } else { 1 };
        match super::shard::plan(
            self.fabric,
            &self.consts,
            &self.tiers,
            self.spread,
            rails,
            &meta,
            max_shards,
        ) {
            PlanOutcome::Sharded(plan) => super::shard::run(self, sources, &plan),
            PlanOutcome::Fallback(reason) => {
                let mut rep = self.run_streamed(sources);
                rep.mode = super::traffic::ShardMode::SerialFallback { reason };
                rep
            }
        }
    }

    /// Utilization of the busiest link direction over the makespan.
    pub fn peak_utilization(&self, makespan_ns: f64) -> f64 {
        self.servers
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|s| s.utilization(makespan_ns))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, NodeKind, Topology};

    fn rack(n: usize) -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    #[test]
    fn single_transaction_matches_analytic_roughly() {
        let (f, accs) = rack(4);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 }]);
        assert_eq!(rep.completed, 1);
        let analytic = f.latency_ns(accs[0], accs[1], 4096.0).unwrap();
        let simulated = rep.latency.mean();
        let ratio = simulated / analytic;
        // same factors modeled; the event path serializes per hop rather
        // than cut-through, so allow a 2.5x band
        assert!(ratio > 0.8 && ratio < 2.5, "sim {simulated} vs analytic {analytic}");
    }

    #[test]
    fn contention_increases_latency() {
        let (f, accs) = rack(8);
        // all 7 sources hammer acc0 simultaneously -> fan-in on its link
        let mk = |i: usize| Transaction { src: accs[i], dst: accs[0], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run((1..8).map(mk).collect());
        assert_eq!(rep.completed, 7);
        assert!(rep.latency.max() > 3.0 * solo, "fan-in must queue: max {} vs solo {solo}", rep.latency.max());
    }

    #[test]
    fn disjoint_pairs_do_not_interfere() {
        let (f, accs) = rack(8);
        let mk = |s: usize, d: usize| Transaction { src: accs[s], dst: accs[d], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(0, 1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run(vec![mk(0, 1), mk(2, 3), mk(4, 5), mk(6, 7)]);
        // disjoint src links, disjoint dst links: only switch shared (not a server here)
        assert!((rep.latency.max() - solo) / solo < 0.05, "disjoint pairs interfered");
    }

    #[test]
    fn device_time_adds() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let base = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 }]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let with_dev = sim2.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 500.0 }]).latency.mean();
        assert!((with_dev - base - 500.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_transactions_rejected() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        sim.run(vec![
            Transaction { src: accs[0], dst: accs[1], at: 10.0, bytes: 64.0, device_ns: 0.0 },
            Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 },
        ]);
    }

    #[test]
    fn throughput_bounded_by_link_bandwidth() {
        let (f, accs) = rack(2);
        // 100 back-to-back 1 MB transfers over a 100 GB/s link: >= 1 ms total
        let txs: Vec<_> = (0..100)
            .map(|i| Transaction { src: accs[0], dst: accs[1], at: i as f64, bytes: 1e6, device_ns: 0.0 })
            .collect();
        let mut sim = MemSim::new(&f);
        let rep = sim.run(txs);
        let min_makespan = 100.0 * 1e6 / 100.0; // bytes / (bytes/ns)
        assert!(rep.makespan_ns > min_makespan, "makespan {} below wire limit {min_makespan}", rep.makespan_ns);
        assert!(sim.peak_utilization(rep.makespan_ns) > 0.9);
    }

    #[test]
    fn paths_are_interned_per_pair() {
        let (f, accs) = rack(8);
        // 1000 transactions over only 3 distinct (src, dst) pairs
        let pairs = [(0usize, 1usize), (2, 3), (4, 5)];
        let txs: Vec<_> = (0..1000)
            .map(|i| {
                let (s, d) = pairs[i % 3];
                Transaction { src: accs[s], dst: accs[d], at: i as f64, bytes: 256.0, device_ns: 0.0 }
            })
            .collect();
        let mut sim = MemSim::new(&f);
        let rep = sim.run(txs);
        assert_eq!(rep.completed, 1000);
        assert_eq!(sim.interned_paths(), 3, "one arena path per distinct pair");
    }

    #[test]
    fn self_transaction_pays_only_device_time() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![Transaction { src: accs[0], dst: accs[0], at: 5.0, bytes: 64.0, device_ns: 300.0 }]);
        assert_eq!(rep.completed, 1);
        assert!((rep.latency.mean() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn interned_directions_match_link_endpoints() {
        // a -> sw -> b: first hop leaves from the endpoint side recorded
        // on the link, second hop leaves from the switch side; the
        // direction bits must route each hop onto its own server
        let (f, accs) = rack(4);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![
            Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 },
            Transaction { src: accs[1], dst: accs[0], at: 0.0, bytes: 4096.0, device_ns: 0.0 },
        ]);
        // opposite directions of the same two links: full-duplex, so no
        // queuing — both finish with identical latency
        assert_eq!(rep.completed, 2);
        assert!((rep.latency.max() - rep.latency.min()).abs() < 1e-9, "duplex paths interfered");
    }

    // ------------------------------------------------------------------
    // multi-rail routing
    // ------------------------------------------------------------------

    /// 2 spines, one endpoint per leaf: the smallest fabric with real
    /// equal-cost diversity (the leaf picks its spine plane).
    fn spined(leaves: usize, spines: usize) -> (Fabric, Vec<NodeId>) {
        let (mut t, leaf_ids) = Topology::clos(leaves, spines, LinkKind::CxlCoherent, "f");
        let mut eps = Vec::new();
        for (i, &l) in leaf_ids.iter().enumerate() {
            let e = t.add_node(NodeKind::Accelerator, format!("ep{i}"));
            t.connect(e, l, LinkKind::CxlCoherent);
            eps.push(e);
        }
        (Fabric::new(t), eps)
    }

    fn pair_load(eps: &[NodeId], n: usize) -> Vec<Transaction> {
        (0..n)
            .map(|i| Transaction {
                src: eps[0],
                dst: eps[1],
                at: i as f64 * 5.0,
                bytes: 4096.0,
                device_ns: 0.0,
            })
            .collect()
    }

    #[test]
    fn deterministic_rails_match_single_path_exactly() {
        // multipath fabric + all-deterministic policy is byte-identical
        // to the single-path simulator (the parity acceptance bar)
        let (f1, eps1) = spined(2, 2);
        let mut single = MemSim::new(&f1);
        let a = single.run(pair_load(&eps1, 50));
        let (mut f2, eps2) = spined(2, 2);
        f2.enable_multipath(4);
        let mut multi = MemSim::new(&f2); // default: deterministic routing
        let b = multi.run(pair_load(&eps2, 50));
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.latency.mean(), b.latency.mean());
        assert_eq!(a.latency.max(), b.latency.max());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn hash_spray_spreads_one_pair_over_both_spines() {
        let (mut f, eps) = spined(2, 2);
        f.enable_multipath(4);
        let run = |policy: RoutingPolicy| {
            let mut sim = MemSim::with_routing(&f, policy);
            let rep = sim.run(pair_load(&eps, 64));
            assert_eq!(rep.completed, 64);
            let links: std::collections::HashSet<u32> =
                sim.collect_qos_stats().iter().map(|s| s.link).collect();
            (links.len(), sim.used_path_count(), sim.used_pair_count())
        };
        let (det_links, det_paths, det_pairs) = run(RoutingPolicy::deterministic());
        assert_eq!((det_paths, det_pairs), (1, 1));
        assert_eq!(det_links, 4, "single path: ep-leaf, leaf-spine, spine-leaf, leaf-ep");
        let (spray_links, spray_paths, spray_pairs) =
            run(RoutingPolicy::uniform(RailSelector::HashSpray));
        assert_eq!(spray_pairs, 1);
        // 2 spines: rails 2/3 wrap onto (and alias) rails 0/1, so the
        // pair rides exactly 2 distinct physical paths
        assert_eq!(spray_paths, 2, "spray must ride both spine planes");
        assert_eq!(spray_links, 6, "both spine planes must serve traffic");
    }

    #[test]
    fn flow_keyed_spray_pins_a_flow_to_one_rail() {
        // HashSpray hashes the flow id when the source stamps one
        // (SourcedTx::with_flow): every transaction of that flow rides
        // the same rail. The identical stream without a flow id sprays
        // per transaction and must ride both spine planes.
        struct FlowSource {
            src: NodeId,
            dst: NodeId,
            emitted: u64,
            total: u64,
            flow: Option<u64>,
        }
        impl TrafficSource for FlowSource {
            fn class(&self) -> TrafficClass {
                TrafficClass::Generic
            }
            fn pull(&mut self, _now: f64) -> Pull {
                if self.emitted == self.total {
                    return Pull::Done;
                }
                let i = self.emitted;
                self.emitted += 1;
                let tx = Transaction {
                    src: self.src,
                    dst: self.dst,
                    at: i as f64 * 5.0,
                    bytes: 4096.0,
                    device_ns: 0.0,
                };
                let stx = SourcedTx::new(tx, i);
                Pull::Tx(match self.flow {
                    Some(fl) => stx.with_flow(fl),
                    None => stx,
                })
            }
            fn on_complete(&mut self, _token: u64, _now: f64) {}
            fn open_loop(&self) -> bool {
                true
            }
        }
        let (mut f, eps) = spined(2, 2);
        f.enable_multipath(4);
        let run = |flow: Option<u64>| {
            let mut sim =
                MemSim::with_routing(&f, RoutingPolicy::uniform(RailSelector::HashSpray));
            let mut s = FlowSource { src: eps[0], dst: eps[1], emitted: 0, total: 64, flow };
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut s];
            let rep = sim.run_streamed(&mut sources);
            assert_eq!(rep.total.completed, 64);
            sim.used_path_count()
        };
        assert_eq!(run(None), 2, "per-transaction spray rides both spine planes");
        // a flow id pins the whole stream to whichever rail it hashes to
        for fl in [0u64, 1, 7, 1234] {
            assert_eq!(run(Some(fl)), 1, "flow {fl} must ride exactly one rail");
        }
    }

    #[test]
    fn adaptive_probes_do_not_inflate_realized_diversity() {
        // adaptive interns every candidate to score it, but an idle
        // fabric always rides rail 0 — realized diversity must be 1.0
        let (mut f, eps) = spined(2, 2);
        f.enable_multipath(4);
        let mut sim = MemSim::with_routing(&f, RoutingPolicy::uniform(RailSelector::Adaptive));
        // serialize the pair so no queue ever builds (ties -> rail 0)
        let txs: Vec<Transaction> = (0..8)
            .map(|i| Transaction {
                src: eps[0],
                dst: eps[1],
                at: i as f64 * 1e6,
                bytes: 64.0,
                device_ns: 0.0,
            })
            .collect();
        let rep = sim.run(txs);
        assert_eq!(rep.completed, 8);
        assert!(sim.interned_paths() >= 2, "adaptive probed the candidate rails");
        assert_eq!(
            (sim.used_path_count(), sim.used_pair_count()),
            (1, 1),
            "probes must not count as ridden paths"
        );
    }

    #[test]
    fn adaptive_steers_around_a_loaded_spine() {
        let (mut f, eps) = spined(2, 2);
        f.enable_multipath(2);
        let leaf0 = f.topo.neighbors(eps[0])[0].0;
        let (_, busy_link) = f.router().rail_entry(leaf0, eps[1], 0).unwrap();
        let dir = if f.topo.link(busy_link).a == leaf0 { 0 } else { 1 };
        let tx = vec![Transaction { src: eps[0], dst: eps[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 }];
        let run = |policy: RoutingPolicy| {
            let mut sim = MemSim::with_routing(&f, policy);
            // park 1 ms of pre-existing service on the deterministic spine
            sim.servers[busy_link][dir].admit(0.0, 1e6, 64.0, TrafficClass::Generic, 0, 0);
            sim.run(tx.clone()).latency.mean()
        };
        let det = run(RoutingPolicy::deterministic());
        let adaptive = run(RoutingPolicy::uniform(RailSelector::Adaptive));
        assert!(det > 1e6, "deterministic must queue behind the busy spine: {det}");
        assert!(adaptive < det / 10.0, "adaptive failed to steer around: {adaptive} vs det {det}");
    }

    #[test]
    fn adaptive_on_idle_fabric_is_rail_zero() {
        // score ties resolve to the lowest rail, so an uncontended
        // adaptive run reproduces the deterministic path exactly
        let (mut f, eps) = spined(2, 2);
        f.enable_multipath(4);
        let one = vec![Transaction { src: eps[0], dst: eps[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 }];
        let mut det = MemSim::new(&f);
        let a = det.run(one.clone());
        let mut ad = MemSim::with_routing(&f, RoutingPolicy::uniform(RailSelector::Adaptive));
        let b = ad.run(one);
        assert_eq!(a.latency.mean(), b.latency.mean());
        assert_eq!(a.makespan_ns, b.makespan_ns);
    }

    // ------------------------------------------------------------------
    // streamed-injection behavior
    // ------------------------------------------------------------------

    /// A reactive source: emits a chain of K transactions, each issued
    /// only after the previous one completes (serial dependency).
    struct ChainSource {
        src: NodeId,
        dst: NodeId,
        remaining: usize,
        waiting: bool,
        completions: Vec<f64>,
    }

    impl TrafficSource for ChainSource {
        fn class(&self) -> TrafficClass {
            TrafficClass::Generic
        }
        fn pull(&mut self, now: f64) -> Pull {
            if self.remaining == 0 {
                return Pull::Done;
            }
            if self.waiting {
                return Pull::Blocked;
            }
            self.remaining -= 1;
            self.waiting = true;
            Pull::Tx(SourcedTx::new(
                Transaction { src: self.src, dst: self.dst, at: now, bytes: 4096.0, device_ns: 0.0 },
                self.remaining as u64,
            ))
        }
        fn on_complete(&mut self, _token: u64, now: f64) {
            self.waiting = false;
            self.completions.push(now);
        }
    }

    #[test]
    fn reactive_chain_serializes_on_completions() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let mut chain = ChainSource { src: accs[0], dst: accs[1], remaining: 5, waiting: false, completions: Vec::new() };
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut chain];
            sim.run_streamed(&mut sources)
        };
        assert_eq!(rep.total.completed, 5);
        assert_eq!(chain.completions.len(), 5);
        // strictly increasing completion times: each tx waited for its
        // predecessor, so the makespan is ~5x the single-tx latency
        for w in chain.completions.windows(2) {
            assert!(w[1] > w[0]);
        }
        let single = chain.completions[0];
        assert!((rep.total.makespan_ns - 5.0 * single).abs() / rep.total.makespan_ns < 0.01);
    }

    #[test]
    fn per_class_stats_are_partitioned() {
        let (f, accs) = rack(4);
        let mk = |at: f64, s: usize, d: usize| Transaction { src: accs[s], dst: accs[d], at, bytes: 1024.0, device_ns: 0.0 };
        let mut a = BatchSource::new(vec![mk(0.0, 0, 1), mk(10.0, 0, 1)], TrafficClass::Coherence);
        let mut b = BatchSource::new(vec![mk(5.0, 2, 3)], TrafficClass::Tiering);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 2] = [&mut a, &mut b];
            sim.run_streamed(&mut sources)
        };
        assert_eq!(rep.total.completed, 3);
        assert_eq!(rep.class(TrafficClass::Coherence).completed, 2);
        assert_eq!(rep.class(TrafficClass::Tiering).completed, 1);
        assert_eq!(rep.class(TrafficClass::Collective).completed, 0);
        assert!((rep.class(TrafficClass::Coherence).bytes - 2048.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn blocked_source_with_nothing_in_flight_panics() {
        struct Stuck;
        impl TrafficSource for Stuck {
            fn class(&self) -> TrafficClass {
                TrafficClass::Generic
            }
            fn pull(&mut self, _now: f64) -> Pull {
                Pull::Blocked
            }
        }
        let (f, _) = rack(2);
        let mut sim = MemSim::new(&f);
        let mut s = Stuck;
        let mut sources: [&mut dyn TrafficSource; 1] = [&mut s];
        sim.run_streamed(&mut sources);
    }

    #[test]
    fn streamed_equals_batch_on_identical_transactions() {
        let (f, accs) = rack(8);
        let mut rng = crate::util::Rng::new(99);
        let mut at = 0.0;
        let txs: Vec<Transaction> = (0..500)
            .map(|_| {
                at += rng.exp(1.0 / 40.0);
                let s = rng.below(8) as usize;
                let mut d = rng.below(8) as usize;
                if d == s {
                    d = (d + 1) % 8;
                }
                Transaction { src: accs[s], dst: accs[d], at, bytes: 2048.0, device_ns: 50.0 }
            })
            .collect();
        let mut sim_a = MemSim::new(&f);
        let batch = sim_a.run(txs.clone());
        let mut sim_b = MemSim::new(&f);
        let mut src = BatchSource::new(txs, TrafficClass::Generic);
        let streamed = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim_b.run_streamed(&mut sources)
        };
        assert_eq!(batch.completed, streamed.total.completed);
        assert!((batch.makespan_ns - streamed.total.makespan_ns).abs() < 1e-9);
        assert!((batch.latency.mean() - streamed.total.latency.mean()).abs() < 1e-9);
        assert!((batch.latency.max() - streamed.total.latency.max()).abs() < 1e-9);
    }
}
