//! Transaction-level memory-access simulation over a [`Fabric`]: each
//! transaction walks its routed path hop by hop; every link direction is an
//! FCFS [`Server`] sized by that link's serialization time, so contention
//! and head-of-line blocking emerge rather than being assumed.
//!
//! # Performance architecture (§Perf)
//!
//! Routed paths are *interned* per `(src, dst)` pair: the N-transactions-
//! per-pair case (every workload sweep) shares one contiguous hop slice in
//! a common arena instead of cloning a `Vec<usize>` per transaction. Each
//! arena entry packs `(link << 1) | direction` — the hop's direction bit
//! is computed once at path-build time, so the per-event handler never
//! re-derives it by comparing link endpoints. Combined with the slab
//! [`Engine`] this keeps the Arrive hot path to: one inflight load, one
//! arena load, one `LinkConsts` load, one server admit, one schedule.

use super::engine::{Engine, EventKind};
use super::server::Server;
use crate::fabric::flit::FlitFormat;
use crate::fabric::{Fabric, NodeId};
use crate::util::stats::Welford;
use std::collections::HashMap;

/// One memory transaction (request; the response is modeled by doubling
/// the one-way latency contribution of symmetric protocol phases).
#[derive(Clone, Debug)]
pub struct Transaction {
    pub src: NodeId,
    pub dst: NodeId,
    /// Request issue time, ns.
    pub at: f64,
    /// Payload bytes moved.
    pub bytes: f64,
    /// Fixed service time at the destination device (e.g. DRAM access), ns.
    pub device_ns: f64,
}

/// Aggregate results of a simulation run.
#[derive(Clone, Debug)]
pub struct MemSimReport {
    pub completed: u64,
    pub latency: Welford,
    /// Simulated makespan, ns.
    pub makespan_ns: f64,
    /// Events dispatched (engine throughput metric).
    pub events: u64,
}

/// Per-transaction state: issue time plus a borrowed slice of the shared
/// hop arena (start/len), not an owned path.
struct InFlight {
    issued: f64,
    bytes: f64,
    device_ns: f64,
    path_start: u32,
    path_len: u32,
}

/// Precomputed per-link hot-path constants (§Perf: avoids re-deriving
/// PHY/flit math and link-struct lookups on every arrival event).
#[derive(Clone, Copy)]
struct LinkConsts {
    /// 1 / (raw_bw * phy_efficiency), ns per wire byte.
    inv_rate: f64,
    /// prop + phy + framing, ns.
    fixed_ns: f64,
    /// switch traversal at node a / node b (0 if not a switch).
    switch_ns: [f64; 2],
    /// Flit format, copied out of the link so the handler touches no
    /// topology memory.
    flit: FlitFormat,
}

/// The simulator.
pub struct MemSim<'f> {
    fabric: &'f Fabric,
    /// one server per (link, direction)
    servers: Vec<[Server; 2]>,
    consts: Vec<LinkConsts>,
    /// interned hops, `(link << 1) | dir`, contiguous per path
    hop_arena: Vec<u32>,
    /// (src, dst) -> (start, len) into `hop_arena`
    path_cache: HashMap<(u32, u32), (u32, u32)>,
}

impl<'f> MemSim<'f> {
    pub fn new(fabric: &'f Fabric) -> Self {
        let servers = (0..fabric.topo.links.len()).map(|_| [Server::new(), Server::new()]).collect();
        let consts = fabric
            .topo
            .links
            .iter()
            .map(|l| {
                let p = &l.params;
                let sw = |n: NodeId| {
                    fabric.topo.node(n).switch.as_ref().map(|s| s.traversal_ns()).unwrap_or(0.0)
                };
                LinkConsts {
                    inv_rate: 1.0 / (p.raw_bw * p.phy.efficiency()),
                    fixed_ns: p.prop_ns + p.phy.latency_ns() + p.flit_overhead_ns,
                    switch_ns: [sw(l.a), sw(l.b)],
                    flit: p.flit,
                }
            })
            .collect();
        MemSim {
            fabric,
            servers,
            consts,
            hop_arena: Vec::new(),
            path_cache: HashMap::new(),
        }
    }

    /// Intern the routed path src -> dst: returns (start, len) into the
    /// hop arena, building (with per-hop direction bits) on first use.
    /// None when unreachable.
    fn intern_path(&mut self, src: NodeId, dst: NodeId) -> Option<(u32, u32)> {
        let key = (src as u32, dst as u32);
        if let Some(&r) = self.path_cache.get(&key) {
            return Some(r);
        }
        let fabric = self.fabric;
        let router = fabric.router();
        let start = self.hop_arena.len() as u32;
        let mut cur = src;
        while cur != dst {
            let Some((nxt, link)) = router.next_hop(cur, dst) else {
                self.hop_arena.truncate(start as usize);
                return None;
            };
            // direction bit decided once here, not per event: 0 = a -> b
            let dir = if fabric.topo.link(link).a == cur { 0u32 } else { 1u32 };
            self.hop_arena.push(((link as u32) << 1) | dir);
            cur = nxt;
        }
        let entry = (start, self.hop_arena.len() as u32 - start);
        self.path_cache.insert(key, entry);
        Some(entry)
    }

    /// Number of distinct (src, dst) paths interned so far.
    pub fn interned_paths(&self) -> usize {
        self.path_cache.len()
    }

    /// Run all transactions to completion; returns latency statistics.
    /// Transactions must be pre-sorted by issue time (asserted).
    pub fn run(&mut self, txs: Vec<Transaction>) -> MemSimReport {
        let mut engine = Engine::new();
        let mut inflight: Vec<InFlight> = Vec::with_capacity(txs.len());
        let mut last = f64::NEG_INFINITY;
        for tx in txs {
            assert!(tx.at >= last, "transactions must be sorted by issue time");
            last = tx.at;
            let (path_start, path_len) = match self.intern_path(tx.src, tx.dst) {
                Some(r) => r,
                None => panic!("no path {} -> {}", tx.src, tx.dst),
            };
            let id = inflight.len();
            engine.schedule(tx.at, EventKind::Arrive { id, hop: 0 });
            inflight.push(InFlight {
                issued: tx.at,
                bytes: tx.bytes,
                device_ns: tx.device_ns,
                path_start,
                path_len,
            });
        }

        let mut latency = Welford::new();
        let mut completed = 0u64;
        while let Some((now, ev)) = engine.next() {
            match ev {
                EventKind::Arrive { id, hop } => {
                    let fl = &inflight[id];
                    if hop >= fl.path_len as usize {
                        // reached destination: pay device service then complete
                        engine.after(fl.device_ns, EventKind::Complete { id });
                        continue;
                    }
                    let h = self.hop_arena[fl.path_start as usize + hop];
                    let link_idx = (h >> 1) as usize;
                    let dir = (h & 1) as usize;
                    let c = &self.consts[link_idx];
                    let service = c.flit.wire_bytes(fl.bytes) * c.inv_rate;
                    let done = self.servers[link_idx][dir].admit(now, service);
                    // fixed per-hop latency + switch traversal at the
                    // receiving node (precomputed — §Perf)
                    let sw = c.switch_ns[1 - dir];
                    engine.schedule(done + c.fixed_ns + sw, EventKind::Arrive { id, hop: hop + 1 });
                }
                EventKind::Complete { id } => {
                    latency.push(now - inflight[id].issued);
                    completed += 1;
                }
                // exhaustive on purpose: a new EventKind must be handled
                // here explicitly, not dropped by a catch-all arm
                EventKind::Custom { tag } => {
                    unreachable!("MemSim schedules no Custom events (tag {tag})")
                }
            }
        }
        MemSimReport { completed, latency, makespan_ns: engine.now(), events: engine.dispatched() }
    }

    /// Utilization of the busiest link direction over the makespan.
    pub fn peak_utilization(&self, makespan_ns: f64) -> f64 {
        self.servers
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|s| s.utilization(makespan_ns))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, NodeKind, Topology};

    fn rack(n: usize) -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(n, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    #[test]
    fn single_transaction_matches_analytic_roughly() {
        let (f, accs) = rack(4);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 }]);
        assert_eq!(rep.completed, 1);
        let analytic = f.latency_ns(accs[0], accs[1], 4096.0).unwrap();
        let simulated = rep.latency.mean();
        let ratio = simulated / analytic;
        // same factors modeled; the event path serializes per hop rather
        // than cut-through, so allow a 2.5x band
        assert!(ratio > 0.8 && ratio < 2.5, "sim {simulated} vs analytic {analytic}");
    }

    #[test]
    fn contention_increases_latency() {
        let (f, accs) = rack(8);
        // all 7 sources hammer acc0 simultaneously -> fan-in on its link
        let mk = |i: usize| Transaction { src: accs[i], dst: accs[0], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run((1..8).map(mk).collect());
        assert_eq!(rep.completed, 7);
        assert!(rep.latency.max() > 3.0 * solo, "fan-in must queue: max {} vs solo {solo}", rep.latency.max());
    }

    #[test]
    fn disjoint_pairs_do_not_interfere() {
        let (f, accs) = rack(8);
        let mk = |s: usize, d: usize| Transaction { src: accs[s], dst: accs[d], at: 0.0, bytes: 65536.0, device_ns: 0.0 };
        let mut sim = MemSim::new(&f);
        let solo = sim.run(vec![mk(0, 1)]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let rep = sim2.run(vec![mk(0, 1), mk(2, 3), mk(4, 5), mk(6, 7)]);
        // disjoint src links, disjoint dst links: only switch shared (not a server here)
        assert!((rep.latency.max() - solo) / solo < 0.05, "disjoint pairs interfered");
    }

    #[test]
    fn device_time_adds() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let base = sim.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 }]).latency.mean();
        let mut sim2 = MemSim::new(&f);
        let with_dev = sim2.run(vec![Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 500.0 }]).latency.mean();
        assert!((with_dev - base - 500.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn unsorted_transactions_rejected() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        sim.run(vec![
            Transaction { src: accs[0], dst: accs[1], at: 10.0, bytes: 64.0, device_ns: 0.0 },
            Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 64.0, device_ns: 0.0 },
        ]);
    }

    #[test]
    fn throughput_bounded_by_link_bandwidth() {
        let (f, accs) = rack(2);
        // 100 back-to-back 1 MB transfers over a 100 GB/s link: >= 1 ms total
        let txs: Vec<_> = (0..100)
            .map(|i| Transaction { src: accs[0], dst: accs[1], at: i as f64, bytes: 1e6, device_ns: 0.0 })
            .collect();
        let mut sim = MemSim::new(&f);
        let rep = sim.run(txs);
        let min_makespan = 100.0 * 1e6 / 100.0; // bytes / (bytes/ns)
        assert!(rep.makespan_ns > min_makespan, "makespan {} below wire limit {min_makespan}", rep.makespan_ns);
        assert!(sim.peak_utilization(rep.makespan_ns) > 0.9);
    }

    #[test]
    fn paths_are_interned_per_pair() {
        let (f, accs) = rack(8);
        // 1000 transactions over only 3 distinct (src, dst) pairs
        let pairs = [(0usize, 1usize), (2, 3), (4, 5)];
        let txs: Vec<_> = (0..1000)
            .map(|i| {
                let (s, d) = pairs[i % 3];
                Transaction { src: accs[s], dst: accs[d], at: i as f64, bytes: 256.0, device_ns: 0.0 }
            })
            .collect();
        let mut sim = MemSim::new(&f);
        let rep = sim.run(txs);
        assert_eq!(rep.completed, 1000);
        assert_eq!(sim.interned_paths(), 3, "one arena path per distinct pair");
    }

    #[test]
    fn self_transaction_pays_only_device_time() {
        let (f, accs) = rack(2);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![Transaction { src: accs[0], dst: accs[0], at: 5.0, bytes: 64.0, device_ns: 300.0 }]);
        assert_eq!(rep.completed, 1);
        assert!((rep.latency.mean() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn interned_directions_match_link_endpoints() {
        // a -> sw -> b: first hop leaves from the endpoint side recorded
        // on the link, second hop leaves from the switch side; the
        // direction bits must route each hop onto its own server
        let (f, accs) = rack(4);
        let mut sim = MemSim::new(&f);
        let rep = sim.run(vec![
            Transaction { src: accs[0], dst: accs[1], at: 0.0, bytes: 4096.0, device_ns: 0.0 },
            Transaction { src: accs[1], dst: accs[0], at: 0.0, bytes: 4096.0, device_ns: 0.0 },
        ]);
        // opposite directions of the same two links: full-duplex, so no
        // queuing — both finish with identical latency
        assert_eq!(rep.completed, 2);
        assert!((rep.latency.max() - rep.latency.min()).abs() < 1e-9, "duplex paths interfered");
    }
}
