//! The unified traffic layer: every workload class — coherent CXL.cache
//! message flows, tier-2 migration streams, collective all-reduce
//! schedules, synthetic background load — is a [`TrafficSource`] that the
//! streamed [`MemSim`](super::MemSim) backend pulls as the clock advances.
//!
//! The paper's core claim is that *one* hybrid XLink-CXL fabric carries
//! all traffic classes; before this layer existed each class was modeled
//! in a closed-form silo and cross-class interference (DFabric's central
//! result for hybrid interconnects) was structurally invisible. A source
//! emits transactions into the shared slab engine, so per-class latency
//! emerges from contention on the same links.
//!
//! # Streamed injection contract
//!
//! * The driver pulls **one transaction ahead** per source: after a
//!   source's staged transaction is injected (at its issue time), the
//!   source is pulled again. A source therefore never holds more than its
//!   own bookkeeping in memory — million-transaction runs do not
//!   materialize a `Vec<Transaction>`.
//! * `pull(now)` must return transactions with nondecreasing issue times,
//!   each `>= now`. Cross-source ordering is handled by the event heap.
//! * A *reactive* source (one whose next emission depends on an earlier
//!   transaction finishing — e.g. a ring all-reduce step, or a MESI
//!   intervention that follows its dir-request) returns [`Pull::Blocked`];
//!   the driver re-pulls it after the next completion of one of its
//!   in-flight transactions (`on_complete` fires first, carrying the
//!   source's own token back). Returning `Blocked` with nothing in flight
//!   is a deadlock and panics.
//! * [`Pull::Done`] is terminal: the source is never pulled again.

use super::memsim::{MemSimReport, Transaction};
use super::qos::LinkClassStats;
use crate::fabric::NodeId;
use crate::util::stats::{LogHistogram, Welford};
use std::collections::VecDeque;

/// Which subsystem a source's transactions belong to (per-class
/// accounting under interference — the `mixed` experiment's axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficClass {
    /// MESI protocol messages (dir_req / intervention / data / ack).
    Coherence,
    /// Tier-1 <-> tier-2 migrations (spills, promotions, demotions).
    Tiering,
    /// Collective chunk transfers (ring / hierarchical steps).
    Collective,
    /// Anything else: batch workloads, synthetic background load.
    Generic,
}

impl TrafficClass {
    pub const ALL: [TrafficClass; 4] =
        [TrafficClass::Coherence, TrafficClass::Tiering, TrafficClass::Collective, TrafficClass::Generic];

    pub fn index(self) -> usize {
        match self {
            TrafficClass::Coherence => 0,
            TrafficClass::Tiering => 1,
            TrafficClass::Collective => 2,
            TrafficClass::Generic => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrafficClass::Coherence => "coherence",
            TrafficClass::Tiering => "tiering",
            TrafficClass::Collective => "collective",
            TrafficClass::Generic => "generic",
        }
    }
}

/// A transaction plus the source-defined token echoed back in
/// [`TrafficSource::on_complete`].
#[derive(Clone, Debug)]
pub struct SourcedTx {
    pub tx: Transaction,
    pub token: u64,
    /// Optional flow id for per-flow rail affinity: when set, HashSpray
    /// rail selection hashes this instead of the per-source emission
    /// index, so every transaction of one flow rides the same rail (an
    /// ordered stream spreads across rails per *flow*, never per
    /// transaction — no intra-flow reordering). `None` (the default)
    /// keeps per-transaction spray.
    pub flow: Option<u64>,
}

impl SourcedTx {
    /// A transaction with no flow affinity (per-transaction spray).
    pub fn new(tx: Transaction, token: u64) -> SourcedTx {
        SourcedTx { tx, token, flow: None }
    }

    /// Attach a flow id (see [`SourcedTx::flow`]).
    pub fn with_flow(mut self, flow: u64) -> SourcedTx {
        self.flow = Some(flow);
        self
    }
}

/// What a source hands back when pulled.
#[derive(Clone, Debug)]
pub enum Pull {
    /// Inject this transaction at `tx.at` (must be `>= now`).
    Tx(SourcedTx),
    /// Nothing until one of this source's in-flight transactions
    /// completes. Illegal with nothing in flight (deadlock; panics).
    Blocked,
    /// Exhausted; the source is never pulled again.
    Done,
}

/// A workload that emits fabric transactions as simulated time advances.
///
/// Sources are `Send` so a reactive source with a shard-local
/// [`footprint`](TrafficSource::footprint) can be moved onto its owning
/// shard's worker thread by the sharded backend.
pub trait TrafficSource: Send {
    /// Traffic class for per-class accounting.
    fn class(&self) -> TrafficClass;

    /// Pull the next transaction (see the module-level contract).
    fn pull(&mut self, now: f64) -> Pull;

    /// A transaction this source emitted (identified by its token)
    /// completed end-to-end at `now`.
    fn on_complete(&mut self, _token: u64, _now: f64) {}

    /// True when this source's emissions never depend on its completions:
    /// `pull` never returns [`Pull::Blocked`] and `on_complete` does not
    /// influence future emissions (telemetry only). Open-loop sources are
    /// eligible for the sharded conservative backend
    /// ([`MemSim::run_streamed_sharded`](super::MemSim::run_streamed_sharded)),
    /// where injections are staged ahead of the parallel event window; a
    /// reactive source (the default) forces the serial loop, because its
    /// zero-delay completion→emission chain can cross shard boundaries
    /// faster than any fabric lookahead — unless it declares a static
    /// [`footprint`](TrafficSource::footprint) the planner can co-locate
    /// inside one shard.
    fn open_loop(&self) -> bool {
        false
    }

    /// Static fabric footprint of a *reactive* source: every node this
    /// source will ever name as a transaction endpoint, over its whole
    /// lifetime (requester + home + sharers for a coherence engine, the
    /// union of ring members for a collective schedule).
    ///
    /// The sharded planner closes the footprint over the link owners of
    /// every endpoint-pair path and merges the touched topology domains
    /// into one shard (*coupled-domain scheduling*); the source is then
    /// pinned to that shard's worker, where its zero-delay
    /// completion-to-emission chain is shard-local and needs no lookahead.
    /// A footprint whose closure glues every domain together (e.g. a
    /// fabric-wide all-reduce ring) cannot be pinned — the planner runs
    /// such a *spanning* source on the coordinator under the optimistic
    /// checkpoint/rollback protocol instead, which requires
    /// [`checkpoint`](TrafficSource::checkpoint) support from every
    /// reactive source in the run. `None` (the default) means the
    /// footprint is unknown or unbounded, which forces the serial
    /// fallback for a reactive source. Ignored for open-loop sources
    /// (they are staged by the coordinator and may roam the whole
    /// fabric).
    fn footprint(&self) -> Option<Vec<NodeId>> {
        None
    }

    /// Capture this source's complete mutable state, to be applied back
    /// by [`restore`](TrafficSource::restore). The optimistic sharded
    /// backend checkpoints reactive sources at epoch barriers and rolls
    /// them back when a speculatively executed epoch is invalidated by a
    /// cross-shard reaction, so a restored source must replay the exact
    /// pull/on_complete sequence it produced the first time. The usual
    /// implementation is `Some(Box::new(self.clone()))`. The default
    /// `None` pairs with [`checkpointable`](TrafficSource::checkpointable)
    /// returning `false`.
    fn checkpoint(&self) -> Option<Box<dyn std::any::Any + Send>> {
        None
    }

    /// Whether [`checkpoint`](TrafficSource::checkpoint) returns a real
    /// snapshot. The planner probes this (cheaply, without materializing
    /// a snapshot) when a spanning footprint calls for optimistic
    /// execution; any reactive source answering `false` forces the
    /// serial fallback for the whole run.
    fn checkpointable(&self) -> bool {
        false
    }

    /// Apply a state snapshot taken by
    /// [`checkpoint`](TrafficSource::checkpoint) on this same source.
    /// Only ever called with a value this source's own `checkpoint`
    /// returned; the default (paired with the default `checkpoint`) is
    /// unreachable.
    fn restore(&mut self, _snap: &(dyn std::any::Any + Send)) {
        unreachable!("restore() called on a source without checkpoint support");
    }
}

/// Which backend a streamed run actually executed on — the sharded entry
/// points fall back to the serial loop when the plan is not profitable or
/// not provably safe, and callers need to see that (a bad footprint merge
/// silently serializing a run is otherwise invisible).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardMode {
    /// The serial streamed loop, as requested (no sharding attempted).
    Serial,
    /// The conservative parallel backend: `shards` workers, of which
    /// `pinned_sources` reactive sources ran pinned on their owning
    /// shard's worker.
    Sharded { shards: usize, pinned_sources: usize },
    /// A sharded entry point fell back to the serial loop; `reason` says
    /// why (single domain, unpartitionable footprint, zero lookahead...).
    SerialFallback { reason: String },
}

impl ShardMode {
    /// True when the run actually executed on the parallel backend.
    pub fn is_sharded(&self) -> bool {
        matches!(self, ShardMode::Sharded { .. })
    }
}

/// Per-shard balance telemetry from a sharded run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Shard index (dense, `0..shards`).
    pub shard: usize,
    /// Logical events this shard processed (engine dispatches plus hops
    /// its express chains admitted inline) — the load-balance axis.
    pub events: u64,
    /// Reactive sources pinned to this shard's worker.
    pub pinned_sources: usize,
    /// Wall-clock seconds this worker spent parked waiting for its next
    /// epoch command (coordinator turnaround + barrier skew). A shard
    /// idling far above its peers marks a footprint merge that starved it.
    pub idle_s: f64,
}

/// Per-class slice of a streamed run.
#[derive(Clone, Debug)]
pub struct ClassReport {
    pub class: TrafficClass,
    pub completed: u64,
    /// End-to-end transaction latency within the class, ns.
    pub latency: Welford,
    /// Log-binned latency distribution (~±4% bins) — streaming
    /// percentiles without storing per-transaction samples.
    pub hist: LogHistogram,
    /// Payload bytes moved by the class.
    pub bytes: f64,
}

impl ClassReport {
    fn new(class: TrafficClass) -> ClassReport {
        ClassReport {
            class,
            completed: 0,
            latency: Welford::new(),
            hist: LogHistogram::new(),
            bytes: 0.0,
        }
    }

    /// Median transaction latency, ns (0 when the class moved nothing).
    pub fn p50_ns(&self) -> f64 {
        self.hist.p50()
    }

    /// 99th-percentile transaction latency, ns — the tail the QoS
    /// policies trade against each other.
    pub fn p99_ns(&self) -> f64 {
        self.hist.p99()
    }

    /// Mean transaction latency, ns (0 when the class moved nothing).
    pub fn mean_ns(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.latency.mean()
        }
    }
}

/// Aggregate + per-class results of a streamed run.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub total: MemSimReport,
    /// Indexed by [`TrafficClass::index`]; classes a run never used have
    /// `completed == 0`.
    pub per_class: [ClassReport; 4],
    /// High-water mark of concurrently in-flight transactions — the
    /// memory footprint of the streamed run (slots recycle; the full
    /// workload is never materialized). Sharded runs report the sum of
    /// per-shard slot high-waters: the slot memory actually allocated,
    /// an upper bound on this serial definition.
    pub peak_inflight: usize,
    /// Per-link per-class QoS telemetry (served counts, bytes, busy time,
    /// cumulative queue delay), one entry per link direction × class that
    /// actually served traffic. Filled after the run from the link
    /// servers; identical between the serial and sharded backends.
    pub qos: Vec<LinkClassStats>,
    /// Which backend actually ran (serial / sharded / fallback + reason).
    pub mode: ShardMode,
    /// Conservative epochs executed by the sharded coordinator (0 on the
    /// serial loop). Few huge epochs = good lookahead; a fully-pinned run
    /// completes in a single unbounded epoch.
    pub epochs: u64,
    /// Epoch commands issued to workers (each is one barrier round-trip);
    /// 0 on the serial loop. `barriers / epochs` below the shard count
    /// means idle shards were skipped.
    pub barriers: u64,
    /// Per-shard balance telemetry (empty on the serial loop).
    pub shards: Vec<ShardStats>,
    /// Reactive sources the planner could not pin to one shard and ran
    /// on the coordinator under the optimistic checkpoint/rollback
    /// protocol (0 for conservative sharded runs and the serial loop).
    pub optimistic_sources: usize,
    /// Epochs whose per-shard state was checkpointed because a spanning
    /// source could react inside the window (optimistic mode only).
    pub checkpoints: u64,
    /// Speculative epoch executions invalidated by a spanning reaction
    /// landing inside the already-executed window and replayed from the
    /// checkpoint. Commits always outnumber rollbacks (the earliest
    /// divergence point advances every replay round).
    pub rollbacks: u64,
    /// Flight-recorder records lost to the trace ring capacity (0 when
    /// tracing is off or the ring never filled) — the honesty counter
    /// that makes a truncated trace visible.
    pub dropped_spans: u64,
    /// Self-measured wall-clock cost of recording (ns): what tracing
    /// added to this run. 0 when tracing is off.
    pub trace_overhead_ns: f64,
    /// Hops admitted inline by express dispatch (peek-gated hop fusion)
    /// instead of being filed and popped as calendar events. Each fused
    /// hop is exactly the event the engine would have dispatched next,
    /// so it is counted into [`MemSimReport::events`] and every
    /// events-based parity holds with fusion on or off, serial or
    /// sharded. 0 when fusion is disabled ([`MemSim::set_fusion`]).
    ///
    /// [`MemSim::set_fusion`]: super::MemSim::set_fusion
    pub fused_hops: u64,
}

impl StreamReport {
    pub(crate) fn new() -> StreamReport {
        let per_class = [
            ClassReport::new(TrafficClass::Coherence),
            ClassReport::new(TrafficClass::Tiering),
            ClassReport::new(TrafficClass::Collective),
            ClassReport::new(TrafficClass::Generic),
        ];
        StreamReport {
            total: MemSimReport { completed: 0, latency: Welford::new(), makespan_ns: 0.0, events: 0 },
            per_class,
            peak_inflight: 0,
            qos: Vec::new(),
            mode: ShardMode::Serial,
            epochs: 0,
            barriers: 0,
            shards: Vec::new(),
            optimistic_sources: 0,
            checkpoints: 0,
            rollbacks: 0,
            dropped_spans: 0,
            trace_overhead_ns: 0.0,
            fused_hops: 0,
        }
    }

    pub fn class(&self, class: TrafficClass) -> &ClassReport {
        &self.per_class[class.index()]
    }

    /// Fraction of hop-level events (link arrivals + queued-mode
    /// departs; the total minus one injection and one completion per
    /// transaction) that express dispatch admitted inline instead of
    /// dispatching through the calendar. 0.0 when fusion is off or the
    /// run had no hop events.
    pub fn fusion_rate(&self) -> f64 {
        let hop_events = self.total.events.saturating_sub(2 * self.total.completed);
        if hop_events == 0 {
            0.0
        } else {
            self.fused_hops as f64 / hop_events as f64
        }
    }

    pub(crate) fn record(&mut self, class: TrafficClass, latency: f64, bytes: f64) {
        self.total.completed += 1;
        self.total.latency.push(latency);
        let c = &mut self.per_class[class.index()];
        c.completed += 1;
        c.latency.push(latency);
        c.hist.push(latency);
        c.bytes += bytes;
    }
}

/// A pre-materialized transaction list as a source — the adapter that
/// lets `MemSim::run` ride the streamed path, and the building block of
/// the streamed-vs-batch equivalence property test.
pub struct BatchSource {
    txs: VecDeque<Transaction>,
    class: TrafficClass,
}

impl BatchSource {
    /// `txs` must be sorted by issue time (the per-source contract).
    pub fn new(txs: Vec<Transaction>, class: TrafficClass) -> BatchSource {
        BatchSource { txs: txs.into(), class }
    }
}

impl TrafficSource for BatchSource {
    fn class(&self) -> TrafficClass {
        self.class
    }

    fn pull(&mut self, _now: f64) -> Pull {
        match self.txs.pop_front() {
            Some(tx) => Pull::Tx(SourcedTx::new(tx, 0)),
            None => Pull::Done,
        }
    }

    fn open_loop(&self) -> bool {
        true // a pre-materialized list never waits on completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_indices_are_distinct_and_stable() {
        for (i, c) in TrafficClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
    }

    #[test]
    fn batch_source_drains_in_order() {
        let mk = |at: f64| Transaction { src: 0, dst: 1, at, bytes: 64.0, device_ns: 0.0 };
        let mut s = BatchSource::new(vec![mk(1.0), mk(2.0)], TrafficClass::Generic);
        match s.pull(0.0) {
            Pull::Tx(t) => assert_eq!(t.tx.at, 1.0),
            other => panic!("expected Tx, got {other:?}"),
        }
        match s.pull(1.0) {
            Pull::Tx(t) => assert_eq!(t.tx.at, 2.0),
            other => panic!("expected Tx, got {other:?}"),
        }
        assert!(matches!(s.pull(2.0), Pull::Done));
    }
}
