//! Rail selection: how a transaction picks among the equal-cost
//! multipath candidates the PBR table holds (see
//! [`crate::fabric::routing`] §Multipath). The fabric's Clos spine and
//! multi-planar XLink shapes are rich in path diversity, but a
//! single-path table makes every `(src, dst)` pair hammer one
//! deterministic route — the interference the `mixed`/`qos` experiments
//! measure is partly self-inflicted. This module is the policy layer
//! that spreads and steers that traffic (the DFabric/Octopus direction):
//!
//! * [`RailSelector::Deterministic`] — rail 0 everywhere: byte-identical
//!   to the pre-multipath router, and the parity baseline pinned by
//!   `tests/prop_invariants.rs::prop_deterministic_routing_parity`.
//! * [`RailSelector::HashSpray`] — ECMP-style: a deterministic
//!   [splitmix64 hash](spray_rail) over `(src, dst, key)` picks the
//!   rail at injection time, where `key` is the source's per-emission
//!   sequence number — or, when the source stamped a flow id on the
//!   transaction ([`SourcedTx::with_flow`](super::traffic::SourcedTx::with_flow)),
//!   that flow id, pinning every transaction of the flow to one rail
//!   (order-sensitive streams keep a single path; distinct flows still
//!   spread). Either way a pair's transactions spread across the
//!   equal-cost paths while any single run stays exactly reproducible
//!   (and identical between the serial and sharded backends).
//! * [`RailSelector::Adaptive`] — congestion-adaptive: at injection the
//!   candidate rail paths are scored by the service backlog
//!   ([`ClassedServer::pending_ns`](super::qos::ClassedServer::pending_ns))
//!   on their links — the same per-link state the QoS subsystem already
//!   maintains — and the least-loaded rail wins (ties to the lowest
//!   rail). The serial backend scores live state; the sharded backend
//!   scores per-link backlog *digests* each worker piggybacks on its
//!   epoch-barrier response (folded at commit, so the table is one
//!   barrier stale but identical across replay attempts — see
//!   [`super::shard`]'s module docs). Both backends are deterministic;
//!   their rail choices may differ, so cross-backend byte parity is
//!   pinned for Deterministic and HashSpray only.
//!
//! Policies are per [`LinkTier`] (mirroring
//! [`QosPolicy`](super::qos::QosPolicy)): a [`RoutingPolicy`] can spray
//! over the contended CXL spine while the XLink domain stays
//! deterministic. A transaction resolves *one* rail index; cells in
//! tiers whose selector is [`RailSelector::Deterministic`] ignore it and
//! stay on rail 0, cells in spreading tiers take candidate
//! `rail % rails(cell)`. Since every candidate is an equal-cost shortest
//! next hop, any mix of per-cell choices stays shortest and loop-free.

use super::qos::LinkTier;
use crate::fabric::NodeId;

/// How a transaction picks among equal-cost rails.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RailSelector {
    /// Rail 0 everywhere — byte-identical to the single-path router.
    Deterministic,
    /// ECMP: deterministic hash over `(src, dst, key)` where `key` is
    /// the transaction's flow id when the source stamped one
    /// ([`SourcedTx::with_flow`](super::traffic::SourcedTx::with_flow))
    /// and its per-source emission index otherwise.
    HashSpray,
    /// Least-loaded candidate by link-server backlog: the live
    /// [`pending_ns`](super::qos::ClassedServer::pending_ns) on the
    /// serial backend, barrier-piggybacked per-link digests on the
    /// sharded backend (one barrier stale, deterministic either way).
    Adaptive,
}

impl RailSelector {
    pub const ALL: [RailSelector; 3] =
        [RailSelector::Deterministic, RailSelector::HashSpray, RailSelector::Adaptive];

    pub fn name(self) -> &'static str {
        match self {
            RailSelector::Deterministic => "det",
            RailSelector::HashSpray => "spray",
            RailSelector::Adaptive => "adaptive",
        }
    }

    /// True when this selector uses rails beyond rail 0.
    pub fn spreads(self) -> bool {
        !matches!(self, RailSelector::Deterministic)
    }
}

/// Per-link-tier rail-selection configuration, owned by the coordinator
/// ([`RoutingManager`](crate::coordinator::RoutingManager)) and applied
/// to a simulator with [`MemSim::set_routing`](super::MemSim::set_routing)
/// — the routing twin of [`QosPolicy`](super::qos::QosPolicy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoutingPolicy {
    per_tier: [RailSelector; LinkTier::COUNT],
}

impl RoutingPolicy {
    /// The same selector on every tier.
    pub fn uniform(s: RailSelector) -> RoutingPolicy {
        RoutingPolicy { per_tier: [s; LinkTier::COUNT] }
    }

    /// The parity baseline: rail 0 on every tier (exactly the
    /// pre-multipath fabric).
    pub fn deterministic() -> RoutingPolicy {
        RoutingPolicy::uniform(RailSelector::Deterministic)
    }

    pub fn tier(&self, t: LinkTier) -> RailSelector {
        self.per_tier[t.index()]
    }

    pub fn set(&mut self, t: LinkTier, s: RailSelector) {
        self.per_tier[t.index()] = s;
    }

    /// Which tiers spread beyond rail 0, indexed by [`LinkTier::index`].
    pub fn spread_mask(&self) -> [bool; LinkTier::COUNT] {
        let mut m = [false; LinkTier::COUNT];
        for (i, s) in self.per_tier.iter().enumerate() {
            m[i] = s.spreads();
        }
        m
    }

    /// How the per-transaction rail index is resolved: the strongest
    /// selector across tiers (Adaptive > HashSpray > Deterministic). The
    /// resolved index is then applied only at cells in spreading tiers.
    pub fn resolution(&self) -> RailSelector {
        if self.per_tier.contains(&RailSelector::Adaptive) {
            RailSelector::Adaptive
        } else if self.per_tier.contains(&RailSelector::HashSpray) {
            RailSelector::HashSpray
        } else {
            RailSelector::Deterministic
        }
    }
}

impl Default for RoutingPolicy {
    fn default() -> RoutingPolicy {
        RoutingPolicy::deterministic()
    }
}

/// ECMP rail hash: splitmix64 finalizer over the packed flow key.
/// Deterministic across platforms and identical between the serial and
/// sharded backends (both feed the per-source emission index as `seq`).
#[inline]
pub fn spray_rail(src: NodeId, dst: NodeId, seq: u64, k: usize) -> u16 {
    debug_assert!(k >= 1);
    let mut z = (((src as u64) << 32) | dst as u64) ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % k as u64) as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selector_names_and_spread() {
        assert_eq!(RailSelector::Deterministic.name(), "det");
        assert_eq!(RailSelector::HashSpray.name(), "spray");
        assert_eq!(RailSelector::Adaptive.name(), "adaptive");
        assert!(!RailSelector::Deterministic.spreads());
        assert!(RailSelector::HashSpray.spreads());
        assert!(RailSelector::Adaptive.spreads());
    }

    #[test]
    fn policy_per_tier_and_resolution() {
        let mut p = RoutingPolicy::deterministic();
        assert_eq!(p.resolution(), RailSelector::Deterministic);
        assert_eq!(p.spread_mask(), [false; 4]);
        p.set(LinkTier::CxlSpine, RailSelector::HashSpray);
        assert_eq!(p.tier(LinkTier::CxlSpine), RailSelector::HashSpray);
        assert_eq!(p.tier(LinkTier::Xlink), RailSelector::Deterministic);
        assert_eq!(p.resolution(), RailSelector::HashSpray);
        assert!(p.spread_mask()[LinkTier::CxlSpine.index()]);
        p.set(LinkTier::CxlLeaf, RailSelector::Adaptive);
        assert_eq!(p.resolution(), RailSelector::Adaptive);
        let u = RoutingPolicy::uniform(RailSelector::Adaptive);
        assert_eq!(u.spread_mask(), [true; 4]);
    }

    #[test]
    fn spray_is_deterministic_and_in_range() {
        for k in 1..=8usize {
            for seq in 0..200u64 {
                let a = spray_rail(5, 9, seq, k);
                let b = spray_rail(5, 9, seq, k);
                assert_eq!(a, b);
                assert!((a as usize) < k);
            }
        }
    }

    #[test]
    fn spray_spreads_over_rails() {
        // over a few hundred sequence numbers every rail of a k=4 fan
        // must be picked — the ECMP property the steering relies on
        let mut hit = [false; 4];
        for seq in 0..256u64 {
            hit[spray_rail(3, 11, seq, 4) as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "spray left a rail cold: {hit:?}");
        // and different flows decorrelate
        let same = (0..256u64)
            .filter(|&s| spray_rail(3, 11, s, 4) == spray_rail(4, 11, s, 4))
            .count();
        assert!(same < 160, "flows correlate: {same}/256 collisions");
    }
}
