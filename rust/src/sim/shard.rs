//! Sharded conservative execution of the streamed simulation: the fabric
//! is partitioned into topology-derived domains (rack/leaf subtrees, see
//! [`Topology::partition_domains`](crate::fabric::Topology::partition_domains)),
//! each shard owns the class-aware [`ClassedServer`]s of its links and
//! runs its own calendar [`Engine`] on a scoped worker thread, and
//! transactions whose next hop leaves the shard are handed off through
//! per-shard mailboxes. QoS arbitration is shard-local state: a queued
//! transaction waits in its link's virtual channel and the `Depart`
//! chain (see [`super::qos`]) restarts it, so a handoff is still stamped
//! `service_done + fixed + switch` and the lookahead bound below holds
//! under every policy.
//!
//! # Conservative synchronization
//!
//! Parallelism is *conservative* (no rollback): simulation advances in
//! epochs `[T0, T0 + L)` where `T0` is the earliest pending event or
//! injection anywhere and `L` is the **lookahead** — the minimum latency
//! any transaction needs to cross a partition boundary, computed as the
//! minimum over boundary-forwarding link directions of
//! `fixed_ns + switch_traversal` (a handoff's arrival time is
//! `server_done + fixed + switch`, and `server_done >= now`, so every
//! cross-shard message generated inside an epoch is stamped `>= T0 + L`
//! and can safely be delivered at the epoch barrier). With `L <= 0` or a
//! single domain the caller falls back to the serial loop.
//!
//! # Coupled-domain scheduling of reactive sources
//!
//! **Open-loop** sources ([`TrafficSource::open_loop`]) stay on the
//! coordinator thread: injections are staged ahead of the window and
//! `on_complete` is telemetry-only (invoked at the barrier in
//! completion-time order). A **reactive** source's zero-delay
//! completion→emission chain could cross shards faster than any fabric
//! lookahead — so a reactive source is only admitted when it declares a
//! static [`TrafficSource::footprint`]. [`plan`] closes each footprint
//! over the *owners* of every link its traffic can ride (all ordered
//! endpoint pairs × all rails it can spray over) and hands the closures
//! to [`Topology::partition_domains_coupled`](crate::fabric::Topology::partition_domains_coupled),
//! which merges the touched domains before balanced packing. The source
//! is then **pinned to its owning shard's worker**: pull, injection,
//! `on_complete` and the unblock chain all run inside that worker's
//! event loop (an exact port of the serial pump), and by construction
//! none of its transactions ever generates a cross-shard handoff. When
//! *every* source is pinned no traffic crosses a boundary at all, the
//! lookahead is `INFINITY` and the whole run is one fully parallel
//! epoch. A reactive source without a footprint — or one whose closure
//! collapses the partition to a single shard (e.g. a fabric-wide ring) —
//! falls the whole run back to the serial loop, reported through
//! [`ShardMode::SerialFallback`].
//!
//! # Multi-rail routing
//!
//! Rails are resolved at injection — by the coordinator at staging time
//! for open-loop sources, by the owning worker for pinned sources —
//! hashing the identical `(src, dst, flow-or-emission-index)` key (a
//! source that stamps
//! [`SourcedTx::with_flow`](super::traffic::SourcedTx::with_flow)
//! pins the whole flow to one rail; otherwise the per-source emission
//! index sprays per transaction), so
//! [`RailSelector::HashSpray`](super::rails::RailSelector) picks the
//! same rail for every transaction on both backends (pinned by
//! `prop_sharded_matches_serial`'s policy sweep).
//! [`RailSelector::Adaptive`](super::rails::RailSelector) needs the live
//! link-server backlog, which lives on the workers — remote queue state
//! is not visible across shard boundaries — so the sharded backend
//! degrades it to the deterministic spray. The conservative lookahead is
//! unchanged by multipath: `plan` minimizes `fixed + switch` over
//! *every* link direction whose receiver is a gateway node, a superset
//! of the union of boundary-crossing rails, so every rail a transaction
//! can ride is already inside the bound; footprint closures walk the
//! same rail set, so a pinned source's sprayed traffic is co-located on
//! every rail it can pick.
//!
//! # Equivalence
//!
//! Within a shard events dispatch in `(time, seq)` order and every
//! per-server admission sequence is time-ordered exactly as in the serial
//! loop (including the same-timestamp same-link-direction
//! [`ClassedServer::admit_batch`] coalescing the serial loop uses), so
//! per-class completed counts, byte totals and the sorted
//! per-transaction latency multiset match the serial backend
//! (`tests/prop_invariants.rs::prop_sharded_matches_serial`). Event
//! *counts* use the same convention as the serial streamed loop (one
//! injection event per transaction on top of the hop events).

use super::engine::{Engine, EventKind};
use super::memsim::{path_key, rail_hops, rail_step, LinkConsts, MemSim};
use super::qos::{Admission, BatchAdmit, ClassedServer, LinkTier};
use super::rails::spray_rail;
use super::traffic::{
    Pull, ShardMode, ShardStats, SourcedTx, StreamReport, TrafficClass, TrafficSource,
};
use crate::fabric::{Fabric, NodeId, NodeKind};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

/// Per-source injections staged beyond the current window are bounded, so
/// streamed memory stays O(peak in-flight) even under infinite lookahead
/// (fully disjoint shards).
const MAX_STAGE_PER_SOURCE: usize = 4096;

/// What [`plan`] needs to know about each source: whether it is
/// open-loop (stays on the coordinator) and, for reactive sources, the
/// static footprint to co-locate (`None` = undeclared → serial fallback).
pub(crate) struct SourceMeta {
    pub(crate) open: bool,
    pub(crate) footprint: Option<Vec<NodeId>>,
}

/// [`plan`]'s verdict: a runnable partition, or the reason the run must
/// stay serial (surfaced as [`ShardMode::SerialFallback`]).
pub(crate) enum PlanOutcome {
    Sharded(ShardPlan),
    Fallback(String),
}

impl PlanOutcome {
    #[cfg(test)]
    pub(crate) fn sharded(self) -> Option<ShardPlan> {
        match self {
            PlanOutcome::Sharded(p) => Some(p),
            PlanOutcome::Fallback(_) => None,
        }
    }
}

/// The partition and its conservative bound.
pub(crate) struct ShardPlan {
    pub(crate) node_shard: Vec<u32>,
    pub(crate) link_shard: Vec<u32>,
    pub(crate) nshards: usize,
    /// Owning shard per source: `Some(shard)` pins a reactive source to
    /// that shard's worker, `None` keeps an open-loop source on the
    /// coordinator.
    pub(crate) pinned: Vec<Option<u32>>,
    /// Minimum cross-partition hop latency, ns (`f64::INFINITY` when no
    /// traffic can cross a boundary — every source pinned — so shards
    /// run fully decoupled in a single epoch).
    pub(crate) lookahead: f64,
}

/// Transaction state carried across shard boundaries by value (each shard
/// interns paths locally, so messages stay plain scalars).
#[derive(Clone, Copy)]
struct ShardTx {
    issued: f64,
    bytes: f64,
    device_ns: f64,
    src: u32,
    dst: u32,
    source: u32,
    class: TrafficClass,
    token: u64,
    /// Equal-cost rail this transaction rides, resolved once at
    /// injection (see the multi-rail note above).
    rail: u16,
}

/// A mailbox message: "transaction `tx` arrives at hop `hop` at `at`".
/// Injections are the `hop == 0` case.
struct Handoff {
    at: f64,
    hop: u32,
    tx: ShardTx,
}

struct LocalTx {
    tx: ShardTx,
    path_start: u32,
    path_len: u32,
}

enum Cmd {
    /// Run one epoch `[.., t1)`. `inbox` carries this epoch's deliveries;
    /// `out` and `completions` are empty recycled buffers the worker
    /// fills and returns (mailbox memory is reused across epochs instead
    /// of reallocated).
    Epoch { t1: f64, inbox: Vec<Handoff>, out: Vec<(u32, Handoff)>, completions: Vec<Completion> },
    Finish,
}

struct Completion {
    at: f64,
    latency: f64,
    bytes: f64,
    source: u32,
    token: u64,
}

enum Resp {
    Epoch {
        shard: usize,
        /// Cross-shard handoffs generated this epoch: `(target, message)`.
        out: Vec<(u32, Handoff)>,
        completions: Vec<Completion>,
        /// The drained inbox buffer, returned for recycling.
        spent: Vec<Handoff>,
        /// Earliest still-pending local event (INFINITY when idle).
        next_event: f64,
    },
    Final {
        shard: usize,
        servers: Vec<[ClassedServer; 2]>,
        now: f64,
        dispatched: u64,
        peak_slots: usize,
        /// Wall-clock seconds this worker spent waiting on the barrier.
        idle_s: f64,
    },
}

/// The shard that owns link `l`: the endpoint side's subtree when one
/// side is an endpoint, else node `a`'s domain. Every link is owned by
/// exactly one shard, which owns both direction servers. The footprint
/// closure in [`plan`] MUST use the same rule, so it closes over the
/// node whose `node_shard` entry decides each traversed link.
#[inline]
fn link_owner(topo: &crate::fabric::Topology, a: NodeId, b: NodeId) -> NodeId {
    if topo.node(a).kind != NodeKind::Switch {
        a
    } else if topo.node(b).kind != NodeKind::Switch {
        b
    } else {
        a
    }
}

/// Derive the shard plan: topology domains (coupled over reactive
/// footprints), link ownership, source pinning and the conservative
/// lookahead. `rails` is the effective rail fan at injection (1 when the
/// run does not spray) — footprint closures walk every rail a pinned
/// source's traffic can ride. Returns [`PlanOutcome::Fallback`] with the
/// reason when sharding cannot help or cannot be conservative.
pub(crate) fn plan(
    fabric: &Fabric,
    consts: &[LinkConsts],
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    rails: u16,
    meta: &[SourceMeta],
    max_shards: usize,
) -> PlanOutcome {
    if max_shards <= 1 {
        return PlanOutcome::Fallback("sharding disabled (max_shards <= 1)".into());
    }
    let topo = &fabric.topo;
    // footprint closure per reactive source: the declared nodes plus the
    // owner node of every link any of its transactions can traverse, on
    // every rail it can spray over — co-locating the owners co-locates
    // the link servers, so the source's events never leave its shard
    let mut groups: Vec<Vec<NodeId>> = Vec::new();
    for (i, m) in meta.iter().enumerate() {
        if m.open {
            continue;
        }
        let fp = match &m.footprint {
            Some(fp) => fp,
            None => {
                return PlanOutcome::Fallback(format!(
                    "reactive source {i} has no static footprint"
                ))
            }
        };
        if fp.is_empty() {
            continue; // emits nothing: pinned to shard 0 below
        }
        let mut closure: Vec<NodeId> = fp.clone();
        let mut seen = vec![false; topo.nodes.len()];
        for &n in &closure {
            seen[n] = true;
        }
        for &a in fp {
            for &b in fp {
                if a == b {
                    continue;
                }
                for rail in 0..rails.max(1) {
                    let mut at = a;
                    let mut steps = 0usize;
                    while at != b {
                        let Some((next, link)) = rail_step(fabric, tiers, spread, at, b, rail)
                        else {
                            break; // unreachable pair: injection will panic, not here
                        };
                        let l = &topo.links[link];
                        let owner = link_owner(topo, l.a, l.b);
                        if !seen[owner] {
                            seen[owner] = true;
                            closure.push(owner);
                        }
                        at = next;
                        steps += 1;
                        if steps > topo.nodes.len() {
                            break; // routing loop guard
                        }
                    }
                }
            }
        }
        groups.push(closure);
    }
    let node_shard = if groups.is_empty() {
        topo.partition_domains(max_shards)
    } else {
        topo.partition_domains_coupled(max_shards, &groups)
    };
    let nshards = node_shard.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    if nshards <= 1 {
        return PlanOutcome::Fallback(if groups.is_empty() {
            "topology yields a single domain".into()
        } else {
            "reactive footprints span the whole fabric (single merged domain)".into()
        });
    }
    let link_shard: Vec<u32> =
        topo.links.iter().map(|l| node_shard[link_owner(topo, l.a, l.b)]).collect();
    let first = link_shard.first().copied();
    if link_shard.iter().all(|&s| Some(s) == first) {
        return PlanOutcome::Fallback("every link owned by one shard".into());
    }
    // pin each reactive source to the shard holding its (merged) closure
    let mut pinned: Vec<Option<u32>> = Vec::with_capacity(meta.len());
    let mut g = 0usize;
    for m in meta {
        if m.open {
            pinned.push(None);
        } else if m.footprint.as_ref().map(|fp| fp.is_empty()).unwrap_or(false) {
            pinned.push(Some(0));
        } else {
            let group = &groups[g];
            g += 1;
            let shard = node_shard[group[0]];
            debug_assert!(
                group.iter().all(|&n| node_shard[n] == shard),
                "coupled partition split a reactive footprint closure"
            );
            pinned.push(Some(shard));
        }
    }
    let any_open = meta.iter().any(|m| m.open);
    if !any_open && !meta.is_empty() {
        let first_pin = pinned.first().copied().flatten();
        if pinned.iter().all(|&p| p == first_pin) {
            return PlanOutcome::Fallback(
                "every reactive source pinned to one shard (nothing to parallelize)".into(),
            );
        }
    }
    // lookahead: only open-loop traffic can cross shard boundaries (a
    // pinned source's closure keeps its whole path inside one shard), so
    // with no open sources the bound is INFINITY — one decoupled epoch.
    // Otherwise a handoff out of link (l, dir) arrives at
    // done + fixed + switch_at_receiver with done >= now, so minimize
    // fixed + switch over directions whose receiving node is a gateway
    // (usually a switch; a non-switch gateway contributes switch_ns = 0,
    // which keeps the bound conservative on graphs that route through
    // endpoints). Multipath-safe by construction: this minimizes over
    // EVERY gateway-receiving link direction — a superset of the union
    // of boundary-crossing rails — so whichever equal-cost rail a
    // transaction rides, its handoffs are stamped >= T0 + L
    let lookahead = if !any_open {
        f64::INFINITY
    } else {
        let mut gateway = vec![false; topo.nodes.len()];
        for (n, gw) in gateway.iter_mut().enumerate() {
            let mut s0 = None;
            for &(_, l) in topo.neighbors(n) {
                match s0 {
                    None => s0 = Some(link_shard[l]),
                    Some(x) if x != link_shard[l] => {
                        *gw = true;
                        break;
                    }
                    _ => {}
                }
            }
        }
        let mut lookahead = f64::INFINITY;
        for (li, l) in topo.links.iter().enumerate() {
            for (side, node) in [(0usize, l.a), (1usize, l.b)] {
                if gateway[node] {
                    lookahead = lookahead.min(consts[li].fixed_ns + consts[li].switch_ns[side]);
                }
            }
        }
        if lookahead <= 0.0 {
            return PlanOutcome::Fallback(
                "non-positive conservative lookahead (zero-latency boundary hop)".into(),
            );
        }
        lookahead
    };
    PlanOutcome::Sharded(ShardPlan { node_shard, link_shard, nshards, pinned, lookahead })
}

/// Pull coordinator-owned source `i` once so it is staged one
/// transaction ahead (the `(clamped issue time, tx)` pair), marking it
/// done when exhausted. The clamp `at = tx.at.max(last_issue)` replicates
/// the serial pump, whose `now` at pull time is the source's previous
/// injection time. Pinned sources (slot `None`) are staged by their
/// worker, never here.
fn stage_next(
    i: usize,
    sources: &mut [Option<&mut dyn TrafficSource>],
    staged: &mut [Option<(f64, SourcedTx)>],
    src_done: &mut [bool],
    last_issue: &[f64],
    classes: &[TrafficClass],
) {
    if src_done[i] || staged[i].is_some() {
        return;
    }
    let Some(src) = sources[i].as_mut() else {
        src_done[i] = true;
        return;
    };
    match src.pull(last_issue[i]) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(last_issue[i]);
            staged[i] = Some((at, stx));
        }
        Pull::Done => src_done[i] = true,
        Pull::Blocked => panic!(
            "traffic source {i} (class {}) returned Blocked but declared itself open-loop",
            classes[i].name()
        ),
    }
}

/// A reactive source pinned to one shard's worker: the worker runs the
/// exact serial pump for it (stage one ahead as a `Custom` injection
/// event, inject at issue time, `on_complete` + unblock on local
/// completions).
struct PinnedSrc<'s> {
    global: u32,
    src: &'s mut dyn TrafficSource,
    staged: Option<SourcedTx>,
    state: PinState,
    inflight: usize,
    emitted: u64,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum PinState {
    Active,
    Blocked,
    Done,
}

/// Read-only run parameters shared by every worker.
struct WorkerCtx<'e> {
    shard: usize,
    fabric: &'e Fabric,
    consts: &'e [LinkConsts],
    tiers: &'e [LinkTier],
    spread: [bool; LinkTier::COUNT],
    link_shard: &'e [u32],
    granularity: f64,
    rail_fan: usize,
    spraying: bool,
    /// Links this shard owns — sizes the slab arena up front.
    owned_links: usize,
    classes: &'e [TrafficClass],
}

/// Run the sharded simulation. Callers have already verified the plan
/// (every reactive source carries a `pinned` shard).
pub(crate) fn run(
    sim: &mut MemSim,
    sources: &mut [&mut dyn TrafficSource],
    plan: &ShardPlan,
) -> StreamReport {
    let fabric: &Fabric = sim.fabric;
    let consts: &[LinkConsts] = &sim.consts;
    let tiers: &[LinkTier] = &sim.tiers;
    let spread = sim.spread;
    let granularity = sim.granularity;
    let k = plan.nshards;
    let nsrc = sources.len();
    let classes: Vec<TrafficClass> = sources.iter().map(|s| s.class()).collect();
    // multi-rail resolution at injection: spray for any spreading policy
    // (Adaptive degrades to HashSpray here — worker-owned queue state is
    // not visible across shard boundaries)
    let rail_fan = fabric.router().max_rails();
    let spraying = rail_fan > 1
        && spread != [false; LinkTier::COUNT]
        && sim.routing_policy().resolution().spreads();
    let pinned_total = plan.pinned.iter().flatten().count();

    // split the source slice: pinned sources move onto their owning
    // shard's worker, open-loop sources stay with the coordinator
    let mut pinned_lists: Vec<Vec<PinnedSrc<'_>>> = (0..k).map(|_| Vec::new()).collect();
    let mut coord_srcs: Vec<Option<&mut dyn TrafficSource>> = Vec::with_capacity(nsrc);
    for (i, s) in sources.iter_mut().enumerate() {
        match plan.pinned[i] {
            Some(shard) => {
                pinned_lists[shard as usize].push(PinnedSrc {
                    global: i as u32,
                    src: &mut **s,
                    staged: None,
                    state: PinState::Active,
                    inflight: 0,
                    emitted: 0,
                });
                coord_srcs.push(None);
            }
            None => coord_srcs.push(Some(&mut **s)),
        }
    }

    let mut owned_links = vec![0usize; k];
    for &s in &plan.link_shard {
        owned_links[s as usize] += 1;
    }

    let mut report = StreamReport::new();
    report.mode = ShardMode::Sharded { shards: k, pinned_sources: pinned_total };
    let mut merged_servers = sim.servers.clone();
    let mut makespan = 0.0f64;
    let mut events = 0u64;
    let mut peak_inflight = 0usize;
    let mut epochs = 0u64;
    let mut barriers = 0u64;
    let mut shard_stats: Vec<ShardStats> = Vec::with_capacity(k);

    std::thread::scope(|scope| {
        let link_shard: &[u32] = &plan.link_shard;
        let classes_ref: &[TrafficClass] = &classes;
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(k);
        // one response channel per worker: a dead worker (panic on one of
        // its diagnostic paths) surfaces as a recv error on ITS channel
        // instead of deadlocking the coordinator behind the survivors'
        // still-open clones of a shared sender; shard-ordered collection
        // also makes mailbox fill order deterministic
        let mut res_rxs: Vec<mpsc::Receiver<Resp>> = Vec::with_capacity(k);
        for (shard, pinned) in pinned_lists.into_iter().enumerate() {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (res_tx, res_rx) = mpsc::channel::<Resp>();
            cmd_txs.push(cmd_tx);
            res_rxs.push(res_rx);
            let servers0 = sim.servers.clone();
            let ctx = WorkerCtx {
                shard,
                fabric,
                consts,
                tiers,
                spread,
                link_shard,
                granularity,
                rail_fan,
                spraying,
                owned_links: owned_links[shard],
                classes: classes_ref,
            };
            scope.spawn(move || worker(ctx, cmd_rx, res_tx, servers0, pinned));
        }

        // coordinator state: one staged transaction per open-loop source
        // plus the per-shard mailboxes carrying next-epoch deliveries
        let mut staged: Vec<Option<(f64, SourcedTx)>> = (0..nsrc).map(|_| None).collect();
        let mut src_done: Vec<bool> = plan.pinned.iter().map(|p| p.is_some()).collect();
        let mut last_issue = vec![0.0f64; nsrc];
        // per-source emission index: the spray hash's tx_seq, identical
        // to the serial loop's injection order
        let mut emitted = vec![0u64; nsrc];
        let mut inboxes: Vec<Vec<Handoff>> = (0..k).map(|_| Vec::new()).collect();
        let mut next_events = vec![f64::INFINITY; k];
        // recycled mailbox buffers: epochs reuse drained Vecs instead of
        // reallocating them
        let mut spare_inbox: Vec<Vec<Handoff>> = Vec::new();
        let mut spare_out: Vec<Vec<(u32, Handoff)>> = Vec::new();
        let mut spare_comp: Vec<Vec<Completion>> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();

        // initial barrier: every worker pumps its pinned sources at t=0
        // and reports its earliest injection event, so a fully-pinned
        // workload (no staged coordinator traffic at all) still opens
        // the first window
        for rx in &res_rxs {
            match rx.recv().expect("shard worker alive") {
                Resp::Epoch { shard, out, completions: c, spent, next_event } => {
                    debug_assert!(out.is_empty() && c.is_empty() && spent.is_empty());
                    next_events[shard] = next_event;
                }
                Resp::Final { .. } => unreachable!("Final before Finish"),
            }
        }

        loop {
            // keep every active coordinator source staged one ahead
            for i in 0..nsrc {
                stage_next(i, &mut coord_srcs, &mut staged, &mut src_done, &last_issue, &classes);
            }
            let t_staged =
                staged.iter().flatten().map(|(at, _)| *at).fold(f64::INFINITY, f64::min);
            let t_inbox = inboxes
                .iter()
                .flat_map(|b| b.iter().map(|h| h.at))
                .fold(f64::INFINITY, f64::min);
            let t_engines = next_events.iter().copied().fold(f64::INFINITY, f64::min);
            let t0 = t_staged.min(t_inbox).min(t_engines);
            if !t0.is_finite() {
                break; // sources drained, mailboxes empty, engines idle
            }
            let mut t1 = t0 + plan.lookahead; // INFINITY lookahead: one epoch

            // stage every injection below the window into its first-hop
            // shard's mailbox; the per-source cap bounds memory, shrinking
            // the window to the first unstaged issue time when it bites
            for i in 0..nsrc {
                let mut staged_here = 0usize;
                loop {
                    stage_next(
                        i, &mut coord_srcs, &mut staged, &mut src_done, &last_issue, &classes,
                    );
                    if src_done[i] {
                        break;
                    }
                    let at = staged[i].as_ref().expect("staged above").0;
                    if at >= t1 {
                        break;
                    }
                    // soft cap: shrinking the window below `at` is only
                    // allowed while it stays strictly above t0, or the
                    // epoch could stall on a same-timestamp burst
                    if staged_here >= MAX_STAGE_PER_SOURCE && at > t0 {
                        t1 = t1.min(at); // keep the window conservative
                        break;
                    }
                    let (at, stx) = staged[i].take().expect("staged above");
                    last_issue[i] = at;
                    let tx = stx.tx;
                    let seq = emitted[i];
                    emitted[i] += 1;
                    // flow-keyed when the source stamped one: same hash
                    // input as the serial injection path
                    let spray_key = stx.flow.unwrap_or(seq);
                    let rail =
                        if spraying { spray_rail(tx.src, tx.dst, spray_key, rail_fan) } else { 0 };
                    // the first hop is rail-dependent: different rails may
                    // enter the fabric through links owned by different shards
                    let target = if tx.src == tx.dst {
                        plan.node_shard[tx.src] as usize
                    } else {
                        match rail_step(fabric, tiers, spread, tx.src, tx.dst, rail) {
                            Some((_, link)) => plan.link_shard[link] as usize,
                            None => panic!(
                                "no path {} ({}) -> {} ({}) for traffic source {} (class {})",
                                tx.src,
                                fabric.topo.node(tx.src).label,
                                tx.dst,
                                fabric.topo.node(tx.dst).label,
                                i,
                                classes[i].name()
                            ),
                        }
                    };
                    inboxes[target].push(Handoff {
                        at,
                        hop: 0,
                        tx: ShardTx {
                            issued: at,
                            bytes: tx.bytes,
                            device_ns: tx.device_ns,
                            src: tx.src as u32,
                            dst: tx.dst as u32,
                            source: i as u32,
                            class: classes[i],
                            token: stx.token,
                            rail,
                        },
                    });
                    staged_here += 1;
                }
            }

            // wake only shards with deliveries or events inside the window
            let mut pinged = vec![false; k];
            for s in 0..k {
                if !inboxes[s].is_empty() || next_events[s] < t1 {
                    let inbox = std::mem::replace(
                        &mut inboxes[s],
                        spare_inbox.pop().unwrap_or_default(),
                    );
                    next_events[s] = f64::INFINITY; // refreshed by the response
                    cmd_txs[s]
                        .send(Cmd::Epoch {
                            t1,
                            inbox,
                            out: spare_out.pop().unwrap_or_default(),
                            completions: spare_comp.pop().unwrap_or_default(),
                        })
                        .expect("shard worker alive");
                    pinged[s] = true;
                    barriers += 1;
                }
            }
            assert!(
                pinged.iter().any(|&p| p),
                "conservative window made no progress (t0={t0}, t1={t1})"
            );
            epochs += 1;

            completions.clear();
            for s in (0..k).filter(|&s| pinged[s]) {
                match res_rxs[s].recv().expect("shard worker alive") {
                    Resp::Epoch { shard, mut out, completions: mut c, spent, next_event } => {
                        debug_assert_eq!(shard, s);
                        next_events[shard] = next_event;
                        // a pinned-only run has no conservative bound at
                        // all — the plan proved no handoff can exist
                        assert!(
                            plan.lookahead.is_finite() || out.is_empty(),
                            "cross-shard handoff under infinite lookahead"
                        );
                        for (target, h) in out.drain(..) {
                            inboxes[target as usize].push(h);
                        }
                        completions.append(&mut c);
                        spare_out.push(out);
                        spare_comp.push(c);
                        spare_inbox.push(spent);
                    }
                    Resp::Final { .. } => unreachable!("Final before Finish"),
                }
            }
            // merge the barrier's completions in global time order so the
            // report streams identically to the serial loop (ties broken
            // by (source, token), which can only collide inside one
            // shard's already-ordered stream)
            completions.sort_by(|a, b| {
                a.at
                    .total_cmp(&b.at)
                    .then_with(|| a.source.cmp(&b.source))
                    .then_with(|| a.token.cmp(&b.token))
            });
            for c in completions.drain(..) {
                report.record(classes[c.source as usize], c.latency, c.bytes);
                // pinned sources already saw on_complete inside their
                // worker, at the exact dispatch instant
                if plan.pinned[c.source as usize].is_none() {
                    coord_srcs[c.source as usize]
                        .as_mut()
                        .expect("open-loop source owned by coordinator")
                        .on_complete(c.token, c.at);
                }
            }
        }

        for tx in &cmd_txs {
            tx.send(Cmd::Finish).expect("shard worker alive");
        }
        for (s, rx) in res_rxs.iter().enumerate() {
            match rx.recv().expect("shard worker alive") {
                Resp::Final { shard, servers, now, dispatched, peak_slots, idle_s } => {
                    debug_assert_eq!(shard, s);
                    makespan = makespan.max(now);
                    events += dispatched;
                    // the sum of per-shard slot high-waters: the slot
                    // memory actually allocated, an upper bound on the
                    // serial definition (true peak concurrency) since the
                    // shards peak at different times and a multi-shard
                    // path occupies one slot per visited shard
                    peak_inflight += peak_slots;
                    shard_stats.push(ShardStats {
                        shard,
                        events: dispatched,
                        pinned_sources: plan
                            .pinned
                            .iter()
                            .flatten()
                            .filter(|&&p| p as usize == shard)
                            .count(),
                        idle_s,
                    });
                    for (li, srv) in servers.into_iter().enumerate() {
                        if plan.link_shard[li] as usize == shard {
                            merged_servers[li] = srv;
                        }
                    }
                }
                Resp::Epoch { .. } => unreachable!("Epoch after Finish"),
            }
        }
    });

    sim.servers = merged_servers;
    report.total.makespan_ns = makespan;
    // same count as the serial streamed loop: its per-transaction
    // injection event is the sharded loop's hop-0 arrival event (and a
    // pinned source's injection is a Custom event on its worker)
    report.total.events = events;
    report.peak_inflight = peak_inflight;
    report.epochs = epochs;
    report.barriers = barriers;
    shard_stats.sort_by_key(|s| s.shard);
    report.shards = shard_stats;
    report.qos = sim.collect_qos_stats();
    report
}

/// Pull pinned source `li` once (if active and unstaged) and schedule
/// its injection as a `Custom { tag: li }` event — the exact serial pump,
/// run inside the owning worker.
fn pump_pinned(li: usize, now: f64, pinned: &mut [PinnedSrc<'_>], engine: &mut Engine) {
    let p = &mut pinned[li];
    if p.state != PinState::Active || p.staged.is_some() {
        return;
    }
    match p.src.pull(now) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(now);
            engine.schedule(at, EventKind::Custom { tag: li as u64 });
            p.staged = Some(stx);
        }
        Pull::Blocked => {
            assert!(
                p.inflight > 0,
                "pinned traffic source {} blocked with nothing in flight (deadlock)",
                p.global
            );
            p.state = PinState::Blocked;
        }
        Pull::Done => p.state = PinState::Done,
    }
}

/// One shard: a calendar engine over the shard's link servers and its
/// pinned reactive sources, draining events strictly below each epoch's
/// `t1` and emitting cross-shard handoffs for the barrier.
fn worker(
    ctx: WorkerCtx<'_>,
    cmds: mpsc::Receiver<Cmd>,
    res: mpsc::Sender<Resp>,
    mut servers: Vec<[ClassedServer; 2]>,
    mut pinned: Vec<PinnedSrc<'_>>,
) {
    // slab arena sized from the shard's link count: the calendar queue
    // and slot table for a shard serving L links rarely need more than a
    // few transactions per link direction in flight at once
    let cap = (ctx.owned_links * 8 + 64).min(1 << 16);
    let mut engine = Engine::with_granularity_and_capacity(ctx.granularity, cap);
    let mut slots: Vec<LocalTx> = Vec::with_capacity(cap);
    let mut free: Vec<u32> = Vec::with_capacity(cap / 4);
    // shard-local path interning (same arena layout as the serial path;
    // a path crossing three shards is interned by each of the three)
    let mut arena: Vec<u32> = Vec::new();
    let mut cache: HashMap<u64, (u32, u32)> = HashMap::new();
    // global source index -> local pinned index (completions carry the
    // global id; only locally pinned sources get the reactive unblock)
    let mut pin_of: Vec<Option<u32>> = vec![None; ctx.classes.len()];
    for (li, p) in pinned.iter().enumerate() {
        pin_of[p.global as usize] = Some(li as u32);
    }
    // epoch-batching scratch (ported from the serial loop §Perf):
    // consecutive same-timestamp arrivals on one link direction admit as
    // one batch, amortizing the per-admission ClassedServer bookkeeping
    let mut carried: Option<(f64, EventKind)> = None;
    let mut batch_ids: Vec<(usize, usize)> = Vec::new();
    let mut batch_items: Vec<BatchAdmit> = Vec::new();
    let mut admissions: Vec<Admission> = Vec::new();
    let mut idle = 0.0f64;

    // initial barrier: pump every pinned source at t=0 and report the
    // earliest injection, so the coordinator's first window sees pinned
    // traffic even when nothing is staged on the coordinator itself
    for li in 0..pinned.len() {
        pump_pinned(li, 0.0, &mut pinned, &mut engine);
    }
    if res
        .send(Resp::Epoch {
            shard: ctx.shard,
            out: Vec::new(),
            completions: Vec::new(),
            spent: Vec::new(),
            next_event: engine.peek_time().unwrap_or(f64::INFINITY),
        })
        .is_err()
    {
        return; // coordinator gone (panic unwinding)
    }

    loop {
        let wait = Instant::now();
        let Ok(cmd) = cmds.recv() else { return };
        idle += wait.elapsed().as_secs_f64();
        match cmd {
            Cmd::Epoch { t1, mut inbox, mut out, mut completions } => {
                for h in inbox.drain(..) {
                    let (path_start, path_len) =
                        intern_local(ctx.fabric, ctx.tiers, ctx.spread, &mut arena, &mut cache, &h.tx);
                    let entry = LocalTx { tx: h.tx, path_start, path_len };
                    let id = match free.pop() {
                        Some(s) => {
                            slots[s as usize] = entry;
                            s as usize
                        }
                        None => {
                            slots.push(entry);
                            slots.len() - 1
                        }
                    };
                    engine.schedule(h.at, EventKind::Arrive { id, hop: h.hop as usize });
                }
                loop {
                    let Some((now, ev)) = carried.take().or_else(|| match engine.peek_time() {
                        Some(t) if t < t1 => engine.next(),
                        _ => None,
                    }) else {
                        break;
                    };
                    match ev {
                        // injection: a pinned source's staged transaction
                        // reaches its issue time — the serial Custom arm,
                        // run shard-locally (rail resolution, interning,
                        // inline hop-0 admission, re-pump)
                        EventKind::Custom { tag } => {
                            let li = tag as usize;
                            let stx =
                                pinned[li].staged.take().expect("staged pinned injection");
                            let tx = stx.tx;
                            let seq = pinned[li].emitted;
                            pinned[li].emitted += 1;
                            let rail = if ctx.spraying {
                                spray_rail(tx.src, tx.dst, stx.flow.unwrap_or(seq), ctx.rail_fan)
                            } else {
                                0
                            };
                            let global = pinned[li].global;
                            let stx_tx = ShardTx {
                                issued: now,
                                bytes: tx.bytes,
                                device_ns: tx.device_ns,
                                src: tx.src as u32,
                                dst: tx.dst as u32,
                                source: global,
                                class: ctx.classes[global as usize],
                                token: stx.token,
                                rail,
                            };
                            let (path_start, path_len) = intern_local(
                                ctx.fabric, ctx.tiers, ctx.spread, &mut arena, &mut cache,
                                &stx_tx,
                            );
                            let entry = LocalTx { tx: stx_tx, path_start, path_len };
                            let id = match free.pop() {
                                Some(s) => {
                                    slots[s as usize] = entry;
                                    s as usize
                                }
                                None => {
                                    slots.push(entry);
                                    slots.len() - 1
                                }
                            };
                            pinned[li].inflight += 1;
                            admit_one(
                                &mut engine, &mut out, &mut free, &arena, &ctx, &mut servers,
                                &slots, id, 0, now,
                            );
                            pump_pinned(li, now, &mut pinned, &mut engine);
                        }
                        EventKind::Arrive { id, hop } => {
                            let fl = &slots[id];
                            if hop >= fl.path_len as usize {
                                // reached destination: pay device service
                                engine.after(fl.tx.device_ns, EventKind::Complete { id });
                                continue;
                            }
                            // epoch batching: coalesce the consecutive
                            // arrivals at exactly `now` that land on the
                            // same link direction (the serial loop's
                            // admit_batch optimization, now worker-side)
                            let h = arena[fl.path_start as usize + hop];
                            batch_ids.clear();
                            batch_ids.push((id, hop));
                            while engine.peek_time() == Some(now) {
                                let (t2, ev2) = engine.next().expect("peeked event");
                                if let EventKind::Arrive { id: id2, hop: hop2 } = ev2 {
                                    let fl2 = &slots[id2];
                                    if hop2 < fl2.path_len as usize
                                        && arena[fl2.path_start as usize + hop2] == h
                                    {
                                        batch_ids.push((id2, hop2));
                                        continue;
                                    }
                                }
                                // not a batch member: defer to the next
                                // iteration (popped after the batch, so
                                // flushing the batch first preserves the
                                // serial handler order; its timestamp is
                                // `now < t1`, so it stays in this epoch)
                                carried = Some((t2, ev2));
                                break;
                            }
                            let link = (h >> 1) as usize;
                            let dir = (h & 1) as usize;
                            debug_assert_eq!(
                                ctx.link_shard[link] as usize, ctx.shard,
                                "event for a foreign link reached shard {}",
                                ctx.shard
                            );
                            let c = ctx.consts[link];
                            batch_items.clear();
                            for &(bid, bhop) in &batch_ids {
                                let fl = &slots[bid];
                                batch_items.push(BatchAdmit {
                                    service: c.flit.wire_bytes(fl.tx.bytes) * c.inv_rate,
                                    bytes: fl.tx.bytes,
                                    class: fl.tx.class,
                                    id: bid as u32,
                                    hop: bhop as u32,
                                });
                            }
                            admissions.clear();
                            servers[link][dir].admit_batch(now, &batch_items, &mut admissions);
                            for (adm, &(bid, bhop)) in admissions.iter().zip(&batch_ids) {
                                match *adm {
                                    Admission::Release { done } => forward(
                                        &mut engine, &mut out, &mut free, &arena, &ctx, &slots,
                                        bid, link, dir, bhop, done,
                                    ),
                                    Admission::Start { done } => {
                                        engine.schedule(
                                            done,
                                            EventKind::Depart {
                                                link: link as u32,
                                                dir: dir as u8,
                                            },
                                        );
                                        forward(
                                            &mut engine, &mut out, &mut free, &arena, &ctx,
                                            &slots, bid, link, dir, bhop, done,
                                        );
                                    }
                                    Admission::Queued => {}
                                }
                            }
                        }
                        // a queued-mode link freed: arbitrate, start the
                        // next VC's head, keep the depart chain alive
                        EventKind::Depart { link, dir } => {
                            let (li, di) = (link as usize, dir as usize);
                            if let Some((id, hop, done)) = servers[li][di].depart(now) {
                                engine.schedule(done, EventKind::Depart { link, dir });
                                forward(
                                    &mut engine, &mut out, &mut free, &arena, &ctx, &slots,
                                    id as usize, li, di, hop as usize, done,
                                );
                            }
                        }
                        EventKind::Complete { id } => {
                            let lt = &slots[id];
                            completions.push(Completion {
                                at: now,
                                latency: now - lt.tx.issued,
                                bytes: lt.tx.bytes,
                                source: lt.tx.source,
                                token: lt.tx.token,
                            });
                            let source = lt.tx.source as usize;
                            let token = lt.tx.token;
                            free.push(id as u32);
                            // a pinned source completes shard-locally: the
                            // serial Complete arm (on_complete, unblock,
                            // re-pump) runs here at the dispatch instant,
                            // preserving zero-delay reactive chains
                            if let Some(li) = pin_of[source] {
                                let li = li as usize;
                                pinned[li].inflight -= 1;
                                pinned[li].src.on_complete(token, now);
                                if pinned[li].state == PinState::Blocked {
                                    pinned[li].state = PinState::Active;
                                }
                                pump_pinned(li, now, &mut pinned, &mut engine);
                            }
                        }
                    }
                }
                debug_assert!(carried.is_none(), "batch probe leaked across the epoch barrier");
                let next_event = engine.peek_time().unwrap_or(f64::INFINITY);
                if res
                    .send(Resp::Epoch {
                        shard: ctx.shard,
                        out,
                        completions,
                        spent: inbox,
                        next_event,
                    })
                    .is_err()
                {
                    return; // coordinator gone (panic unwinding)
                }
            }
            Cmd::Finish => {
                debug_assert!(
                    pinned.iter().all(|p| p.inflight == 0 && p.staged.is_none()),
                    "pinned source still live at Finish"
                );
                let _ = res.send(Resp::Final {
                    shard: ctx.shard,
                    servers,
                    now: engine.now(),
                    dispatched: engine.dispatched(),
                    peak_slots: slots.len(),
                    idle_s: idle,
                });
                return;
            }
        }
    }
}

/// Admit transaction `id` at `hop` on its path — the single-admission
/// mirror of `MemSim::step`, used for a pinned source's inline hop-0
/// admission (the batched Arrive arm covers everything else). Shares
/// [`forward`]'s cross-shard branch, though a pinned transaction's path
/// is shard-local by plan construction.
#[allow(clippy::too_many_arguments)]
fn admit_one(
    engine: &mut Engine,
    out: &mut Vec<(u32, Handoff)>,
    free: &mut Vec<u32>,
    arena: &[u32],
    ctx: &WorkerCtx<'_>,
    servers: &mut [[ClassedServer; 2]],
    slots: &[LocalTx],
    id: usize,
    hop: usize,
    now: f64,
) {
    let lt = &slots[id];
    if hop >= lt.path_len as usize {
        engine.after(lt.tx.device_ns, EventKind::Complete { id });
        return;
    }
    let h = arena[lt.path_start as usize + hop];
    let link = (h >> 1) as usize;
    let dir = (h & 1) as usize;
    debug_assert_eq!(
        ctx.link_shard[link] as usize, ctx.shard,
        "pinned injection on a foreign link in shard {}",
        ctx.shard
    );
    let c = &ctx.consts[link];
    let service = c.flit.wire_bytes(lt.tx.bytes) * c.inv_rate;
    match servers[link][dir].admit(now, service, lt.tx.bytes, lt.tx.class, id as u32, hop as u32) {
        Admission::Release { done } => {
            forward(engine, out, free, arena, ctx, slots, id, link, dir, hop, done)
        }
        Admission::Start { done } => {
            engine.schedule(done, EventKind::Depart { link: link as u32, dir: dir as u8 });
            forward(engine, out, free, arena, ctx, slots, id, link, dir, hop, done);
        }
        Admission::Queued => {}
    }
}

/// After a service on `(served_link, dir)` completes at `done`: put
/// transaction `id` onto its next hop — a cross-shard handoff when the
/// next link belongs to another shard (freeing the local slot), a local
/// Arrive event otherwise. Shared by the admit and depart paths; a
/// handoff's arrival time is `done + fixed + switch >= now + L`, so the
/// conservative-lookahead argument is unchanged under queued arbitration.
#[allow(clippy::too_many_arguments)]
fn forward(
    engine: &mut Engine,
    out: &mut Vec<(u32, Handoff)>,
    free: &mut Vec<u32>,
    arena: &[u32],
    ctx: &WorkerCtx<'_>,
    slots: &[LocalTx],
    id: usize,
    served_link: usize,
    dir: usize,
    hop: usize,
    done: f64,
) {
    let lt = &slots[id];
    let c = &ctx.consts[served_link];
    let t_next = done + c.fixed_ns + c.switch_ns[1 - dir];
    let nh = hop + 1;
    if nh < lt.path_len as usize {
        let next_link = (arena[lt.path_start as usize + nh] >> 1) as usize;
        let target = ctx.link_shard[next_link];
        if target as usize != ctx.shard {
            out.push((target, Handoff { at: t_next, hop: nh as u32, tx: lt.tx }));
            free.push(id as u32);
            return;
        }
    }
    engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
}

/// Shard-local twin of `MemSim::intern_path` (same arena packing:
/// `(link << 1) | direction`, direction decided once at build time; same
/// `(src, dst, rail)` cache key, same rail-aware walk — a path crossing
/// three shards is interned by each of the three).
fn intern_local(
    fabric: &Fabric,
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    arena: &mut Vec<u32>,
    cache: &mut HashMap<u64, (u32, u32)>,
    tx: &ShardTx,
) -> (u32, u32) {
    let key = path_key(tx.src as usize, tx.dst as usize, tx.rail);
    if let Some(&r) = cache.get(&key) {
        return r;
    }
    let start = arena.len() as u32;
    if !rail_hops(fabric, tiers, spread, tx.src as usize, tx.dst as usize, tx.rail, arena) {
        // the coordinator verified the first hop, so this means the
        // PBR table lost the route mid-path — name the flow anyway
        panic!(
            "no path {} ({}) -> {} ({}) on rail {} for traffic source {}",
            tx.src,
            fabric.topo.node(tx.src as usize).label,
            tx.dst,
            fabric.topo.node(tx.dst as usize).label,
            tx.rail,
            tx.source
        );
    }
    let entry = (start, arena.len() as u32 - start);
    cache.insert(key, entry);
    entry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, Topology};
    use crate::sim::memsim::MemSim;
    use crate::sim::{BatchSource, Transaction};

    /// A pod-shaped Clos: `leaves` leaf switches, endpoints per leaf.
    fn clos(leaves: usize, spines: usize, eps: usize) -> (Fabric, Vec<usize>) {
        let (mut t, leaf_ids) = Topology::clos(leaves, spines, LinkKind::CxlCoherent, "f");
        let mut out = Vec::new();
        for (i, &l) in leaf_ids.iter().enumerate() {
            for e in 0..eps {
                let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                t.connect(n, l, LinkKind::CxlCoherent);
                out.push(n);
            }
        }
        (Fabric::new(t), out)
    }

    fn workload(eps: &[usize], n: usize, seed: u64) -> Vec<Transaction> {
        let mut rng = crate::util::Rng::new(seed);
        let mut at = 0.0;
        (0..n)
            .map(|_| {
                at += rng.exp(1.0 / 25.0) + 1e-6;
                let s = rng.below(eps.len() as u64) as usize;
                let mut d = rng.below(eps.len() as u64) as usize;
                if d == s {
                    d = (d + 1) % eps.len();
                }
                Transaction { src: eps[s], dst: eps[d], at, bytes: 2048.0, device_ns: 90.0 }
            })
            .collect()
    }

    /// A ping-pong reactive chain: one transaction in flight at a time,
    /// next emission unblocked by the completion. With `footprint` it is
    /// eligible for coupled-domain pinning.
    struct Chain {
        src: usize,
        dst: usize,
        left: usize,
        waiting: bool,
        declared: bool,
    }

    impl TrafficSource for Chain {
        fn class(&self) -> TrafficClass {
            TrafficClass::Generic
        }
        fn pull(&mut self, now: f64) -> Pull {
            if self.left == 0 {
                return Pull::Done;
            }
            if self.waiting {
                return Pull::Blocked;
            }
            self.left -= 1;
            self.waiting = true;
            Pull::Tx(SourcedTx::new(
                Transaction { src: self.src, dst: self.dst, at: now, bytes: 512.0, device_ns: 0.0 },
                self.left as u64,
            ))
        }
        fn on_complete(&mut self, _token: u64, _now: f64) {
            self.waiting = false;
        }
        // open_loop() stays false: reactive
        fn footprint(&self) -> Option<Vec<NodeId>> {
            if self.declared {
                Some(vec![self.src, self.dst])
            } else {
                None
            }
        }
    }

    fn no_meta() -> Vec<SourceMeta> {
        Vec::new()
    }

    #[test]
    fn plan_reflects_topology() {
        let (f, _) = clos(8, 2, 4);
        let sim = MemSim::new(&f);
        let p = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &no_meta(), 4)
            .sharded()
            .expect("clos must shard");
        assert!(p.nshards >= 2 && p.nshards <= 4);
        assert!(p.lookahead > 0.0 && p.lookahead.is_finite());
        assert_eq!(p.link_shard.len(), f.topo.links.len());
        // single-hop rack: one domain, no plan
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let f1 = Fabric::new(t);
        let s1 = MemSim::new(&f1);
        assert!(plan(&f1, &s1.consts, &s1.tiers, s1.spread, 1, &no_meta(), 4)
            .sharded()
            .is_none());
        // one requested shard: no plan
        assert!(plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &no_meta(), 1)
            .sharded()
            .is_none());
    }

    #[test]
    fn plan_pins_reactive_footprints() {
        let (f, eps) = clos(8, 2, 4);
        let sim = MemSim::new(&f);
        // two rack-local footprints on far-apart leaves + one open source
        let meta = vec![
            SourceMeta { open: false, footprint: Some(vec![eps[0], eps[1]]) },
            SourceMeta { open: false, footprint: Some(vec![eps[4 * 6], eps[4 * 6 + 1]]) },
            SourceMeta { open: true, footprint: None },
        ];
        let p = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta, 4)
            .sharded()
            .expect("rack-local footprints must shard");
        assert!(p.pinned[0].is_some() && p.pinned[1].is_some());
        assert_eq!(p.pinned[2], None);
        // rack-local pairs on different leaves land on different shards
        assert_ne!(p.pinned[0], p.pinned[1]);
        // the open source keeps the conservative bound finite
        assert!(p.lookahead.is_finite() && p.lookahead > 0.0);
        // every node of each closure lives on the pinned shard
        assert_eq!(p.node_shard[eps[0]], p.pinned[0].unwrap());
        assert_eq!(p.node_shard[eps[1]], p.pinned[0].unwrap());

        // without open sources the shards are fully decoupled
        let meta2 = vec![
            SourceMeta { open: false, footprint: Some(vec![eps[0], eps[1]]) },
            SourceMeta { open: false, footprint: Some(vec![eps[4 * 6], eps[4 * 6 + 1]]) },
        ];
        let p2 = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta2, 4)
            .sharded()
            .expect("disjoint pinned-only footprints must shard");
        assert!(p2.lookahead.is_infinite());

        // an undeclared reactive source forces the serial fallback
        let meta3 = vec![SourceMeta { open: false, footprint: None }];
        match plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta3, 4) {
            PlanOutcome::Fallback(reason) => assert!(reason.contains("footprint")),
            PlanOutcome::Sharded(_) => panic!("undeclared footprint must not shard"),
        }

        // a fabric-wide footprint collapses the partition: fallback
        let meta4 = vec![SourceMeta { open: false, footprint: Some(eps.clone()) }];
        match plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta4, 4) {
            PlanOutcome::Fallback(_) => {}
            PlanOutcome::Sharded(p) => {
                // acceptable only if the closure still left >= 2 shards;
                // on this Clos every leaf is touched, so it must not
                panic!("fabric-wide footprint produced {} shards", p.nshards)
            }
        }
    }

    #[test]
    fn sharded_matches_serial_on_clos() {
        let (f, eps) = clos(6, 2, 6);
        let txs = workload(&eps, 600, 0x5AA5);

        let mut serial_sim = MemSim::new(&f);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert!(sharded.mode.is_sharded(), "open-loop clos run must shard");
        assert!(sharded.epochs > 0 && sharded.barriers >= sharded.epochs);
        assert!(sharded.shards.len() >= 2, "per-shard telemetry missing");
        assert_eq!(
            sharded.shards.iter().map(|s| s.events).sum::<u64>(),
            sharded.total.events,
            "per-shard event telemetry must sum to the total"
        );
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        assert!(close(serial.latency.min(), sharded.total.latency.min()));
        // per-link utilization state merged back from the workers
        assert!(sharded_sim.peak_utilization(sharded.total.makespan_ns) > 0.0);
    }

    #[test]
    fn sharded_spray_matches_serial_spray() {
        // the multi-rail twin of sharded_matches_serial_on_clos: rails
        // resolved at injection hash identically to the serial loop
        use crate::sim::{RailSelector, RoutingPolicy};
        let (mut f, eps) = clos(6, 2, 6);
        f.enable_multipath(4);
        let txs = workload(&eps, 600, 0xB1A5);
        let policy = RoutingPolicy::uniform(RailSelector::HashSpray);

        let mut serial_sim = MemSim::with_routing(&f, policy);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::with_routing(&f, policy);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        // the spray actually spread: more ridden paths than pairs
        assert!(
            serial_sim.used_path_count() > serial_sim.used_pair_count(),
            "spray rode no extra rails"
        );
    }

    #[test]
    fn pinned_reactive_sources_match_serial() {
        // rack-local ping-pong chains on three different leaves, plus
        // open-loop background: the chains pin to their leaf shards and
        // the whole mix must reproduce the serial run exactly
        let (f, eps) = clos(6, 2, 4);
        let chain_at = |leaf: usize| (eps[4 * leaf], eps[4 * leaf + 1]);
        let txs = workload(&eps, 300, 0xC0DE);

        let run_with = |sharded: bool| {
            let mut sim = MemSim::new(&f);
            let mut chains: Vec<Chain> = [0usize, 2, 5]
                .iter()
                .map(|&l| {
                    let (src, dst) = chain_at(l);
                    Chain { src, dst, left: 50, waiting: false, declared: true }
                })
                .collect();
            let mut bg = BatchSource::new(txs.clone(), crate::sim::TrafficClass::Generic);
            let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
            for c in &mut chains {
                sources.push(c);
            }
            sources.push(&mut bg);
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, 3)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let serial = run_with(false);
        let sharded = run_with(true);
        assert!(
            matches!(sharded.mode, ShardMode::Sharded { pinned_sources: 3, .. }),
            "chains must pin, got {:?}",
            sharded.mode
        );
        assert_eq!(serial.total.completed, sharded.total.completed);
        assert_eq!(serial.total.events, sharded.total.events);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.total.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.total.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.total.latency.max(), sharded.total.latency.max()));
    }

    #[test]
    fn fully_pinned_run_is_one_decoupled_epoch() {
        // chains only — no open-loop traffic: the plan proves no handoff
        // can exist, the lookahead is infinite and the run is one epoch
        let (f, eps) = clos(4, 2, 4);
        let run_with = |sharded: bool| {
            let mut sim = MemSim::new(&f);
            let mut chains: Vec<Chain> = (0..4)
                .map(|l| Chain {
                    src: eps[4 * l],
                    dst: eps[4 * l + 1],
                    left: 40,
                    waiting: false,
                    declared: true,
                })
                .collect();
            let mut sources: Vec<&mut dyn TrafficSource> =
                chains.iter_mut().map(|c| c as &mut dyn TrafficSource).collect();
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, 4)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let serial = run_with(false);
        let sharded = run_with(true);
        assert!(sharded.mode.is_sharded(), "disjoint chains must shard: {:?}", sharded.mode);
        assert_eq!(sharded.epochs, 1, "fully-pinned run must be a single epoch");
        assert_eq!(serial.total.completed, sharded.total.completed);
        assert_eq!(serial.total.events, sharded.total.events);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.total.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.total.latency.mean(), sharded.total.latency.mean()));
    }

    #[test]
    fn reactive_sources_fall_back_to_serial() {
        // a reactive source WITHOUT a declared footprint keeps the exact
        // serial loop, and the report says why
        let (f, eps) = clos(4, 2, 2);
        let mut sim = MemSim::new(&f);
        let mut chain =
            Chain { src: eps[0], dst: eps[eps.len() - 1], left: 4, waiting: false, declared: false };
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut chain];
            sim.run_streamed_sharded(&mut sources)
        };
        // the serial fallback must run the reactive chain to completion
        assert_eq!(rep.total.completed, 4);
        match &rep.mode {
            ShardMode::SerialFallback { reason } => assert!(reason.contains("footprint")),
            other => panic!("expected SerialFallback, got {other:?}"),
        }
    }

    #[test]
    fn zero_hop_transactions_shard_cleanly() {
        let (f, eps) = clos(4, 2, 3);
        let txs: Vec<Transaction> = (0..40)
            .map(|i| Transaction {
                src: eps[i % eps.len()],
                dst: eps[i % eps.len()],
                at: 1.0 + i as f64,
                bytes: 64.0,
                device_ns: 250.0,
            })
            .collect();
        let mut sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed_sharded_with(&mut sources, 4)
        };
        assert_eq!(rep.total.completed, 40);
        assert!((rep.total.latency.mean() - 250.0).abs() < 1e-9);
    }
}
