//! Sharded conservative execution of the streamed simulation: the fabric
//! is partitioned into topology-derived domains (rack/leaf subtrees, see
//! [`Topology::partition_domains`](crate::fabric::Topology::partition_domains)),
//! each shard owns the class-aware [`ClassedServer`]s of its links and
//! runs its own calendar [`Engine`] on a scoped worker thread, and
//! transactions whose next hop leaves the shard are handed off through
//! per-shard mailboxes. QoS arbitration is shard-local state: a queued
//! transaction waits in its link's virtual channel and the `Depart`
//! chain (see [`super::qos`]) restarts it, so a handoff is still stamped
//! `service_done + fixed + switch` and the lookahead bound below holds
//! under every policy.
//!
//! # Conservative synchronization
//!
//! Parallelism is *conservative* (no rollback): simulation advances in
//! epochs `[T0, T0 + L)` where `T0` is the earliest pending event or
//! injection anywhere and `L` is the **lookahead** — the minimum latency
//! any transaction needs to cross a partition boundary, computed as the
//! minimum over boundary-forwarding link directions of
//! `fixed_ns + switch_traversal` (a handoff's arrival time is
//! `server_done + fixed + switch`, and `server_done >= now`, so every
//! cross-shard message generated inside an epoch is stamped `>= T0 + L`
//! and can safely be delivered at the epoch barrier). With `L <= 0` or a
//! single domain the caller falls back to the serial loop.
//!
//! Sources stay on the coordinator thread: only **open-loop** sources
//! ([`TrafficSource::open_loop`]) are eligible, so injections can be
//! staged ahead of the window and `on_complete` is telemetry-only
//! (invoked at the barrier in completion-time order). A reactive source's
//! zero-delay completion→emission chain could cross shards faster than
//! any fabric lookahead — those workloads keep the exact serial loop.
//!
//! # Multi-rail routing
//!
//! Rails are resolved by the coordinator at staging time — the same
//! injection-time contract as the serial loop, hashing the identical
//! `(src, dst, flow-or-emission-index)` key (a source that stamps
//! [`SourcedTx::with_flow`](super::traffic::SourcedTx::with_flow)
//! pins the whole flow to one rail; otherwise the per-source emission
//! index sprays per transaction), so
//! [`RailSelector::HashSpray`](super::rails::RailSelector) picks the
//! same rail for every transaction on both backends (pinned by
//! `prop_sharded_matches_serial`'s policy sweep).
//! [`RailSelector::Adaptive`](super::rails::RailSelector) needs the live
//! link-server backlog, which lives on the workers — remote queue state
//! is not visible across shard boundaries — so the sharded backend
//! degrades it to the deterministic spray. The conservative lookahead is
//! unchanged by multipath: `plan` minimizes `fixed + switch` over
//! *every* link direction whose receiver is a gateway node, a superset
//! of the union of boundary-crossing rails, so every rail a transaction
//! can ride is already inside the bound.
//!
//! # Equivalence
//!
//! Within a shard events dispatch in `(time, seq)` order and every
//! per-server admission sequence is time-ordered exactly as in the serial
//! loop, so per-class completed counts, byte totals and the sorted
//! per-transaction latency multiset match the serial backend
//! (`tests/prop_invariants.rs::prop_sharded_matches_serial`). Event
//! *counts* use the same convention as the serial streamed loop (one
//! injection event per transaction on top of the hop events).

use super::engine::{Engine, EventKind};
use super::memsim::{path_key, rail_hops, rail_step, LinkConsts, MemSim};
use super::qos::{Admission, ClassedServer, LinkTier};
use super::rails::spray_rail;
use super::traffic::{Pull, SourcedTx, StreamReport, TrafficClass, TrafficSource};
use crate::fabric::{Fabric, NodeKind};
use std::collections::HashMap;
use std::sync::mpsc;

/// Per-source injections staged beyond the current window are bounded, so
/// streamed memory stays O(peak in-flight) even under infinite lookahead
/// (fully disjoint shards).
const MAX_STAGE_PER_SOURCE: usize = 4096;

/// The partition and its conservative bound.
pub(crate) struct ShardPlan {
    pub(crate) node_shard: Vec<u32>,
    pub(crate) link_shard: Vec<u32>,
    pub(crate) nshards: usize,
    /// Minimum cross-partition hop latency, ns (`f64::INFINITY` when no
    /// path crosses a boundary — shards then run fully decoupled).
    pub(crate) lookahead: f64,
}

/// Transaction state carried across shard boundaries by value (each shard
/// interns paths locally, so messages stay plain scalars).
#[derive(Clone, Copy)]
struct ShardTx {
    issued: f64,
    bytes: f64,
    device_ns: f64,
    src: u32,
    dst: u32,
    source: u32,
    class: TrafficClass,
    token: u64,
    /// Equal-cost rail this transaction rides, resolved once by the
    /// coordinator at staging time (see the multi-rail note below).
    rail: u16,
}

/// A mailbox message: "transaction `tx` arrives at hop `hop` at `at`".
/// Injections are the `hop == 0` case.
struct Handoff {
    at: f64,
    hop: u32,
    tx: ShardTx,
}

struct LocalTx {
    tx: ShardTx,
    path_start: u32,
    path_len: u32,
}

enum Cmd {
    Epoch { t1: f64, inbox: Vec<Handoff> },
    Finish,
}

struct Completion {
    at: f64,
    latency: f64,
    bytes: f64,
    source: u32,
    token: u64,
}

enum Resp {
    Epoch {
        shard: usize,
        /// Cross-shard handoffs generated this epoch: `(target, message)`.
        out: Vec<(u32, Handoff)>,
        completions: Vec<Completion>,
        /// Earliest still-pending local event (INFINITY when idle).
        next_event: f64,
    },
    Final {
        shard: usize,
        servers: Vec<[ClassedServer; 2]>,
        now: f64,
        dispatched: u64,
        peak_slots: usize,
    },
}

/// Derive the shard plan: topology domains, link ownership and the
/// conservative lookahead. `None` when sharding cannot help (one domain,
/// one requested shard, or a non-positive lookahead) — callers fall back
/// to the serial loop.
pub(crate) fn plan(fabric: &Fabric, consts: &[LinkConsts], max_shards: usize) -> Option<ShardPlan> {
    if max_shards <= 1 {
        return None;
    }
    let topo = &fabric.topo;
    let node_shard = topo.partition_domains(max_shards);
    let nshards = node_shard.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    if nshards <= 1 {
        return None;
    }
    // a link lives with its endpoint's subtree (the endpoint side when one
    // side is an endpoint, else node `a`'s domain) — every link is owned
    // by exactly one shard, which owns both direction servers
    let link_shard: Vec<u32> = topo
        .links
        .iter()
        .map(|l| {
            if topo.node(l.a).kind != NodeKind::Switch {
                node_shard[l.a]
            } else if topo.node(l.b).kind != NodeKind::Switch {
                node_shard[l.b]
            } else {
                node_shard[l.a]
            }
        })
        .collect();
    let first = link_shard.first().copied();
    if link_shard.iter().all(|&s| Some(s) == first) {
        return None; // every link in one shard: nothing to parallelize
    }
    // gateway nodes: incident links span more than one shard — the only
    // places a path can change shards
    let mut gateway = vec![false; topo.nodes.len()];
    for (n, g) in gateway.iter_mut().enumerate() {
        let mut s0 = None;
        for &(_, l) in topo.neighbors(n) {
            match s0 {
                None => s0 = Some(link_shard[l]),
                Some(x) if x != link_shard[l] => {
                    *g = true;
                    break;
                }
                _ => {}
            }
        }
    }
    // lookahead: a handoff out of link (l, dir) arrives at
    // done + fixed + switch_at_receiver with done >= now, so minimize
    // fixed + switch over directions whose receiving node is a gateway
    // (usually a switch; a non-switch gateway contributes switch_ns = 0,
    // which keeps the bound conservative on graphs that route through
    // endpoints). Multipath-safe by construction: this minimizes over
    // EVERY gateway-receiving link direction — a superset of the union
    // of boundary-crossing rails — so whichever equal-cost rail a
    // transaction rides, its handoffs are stamped >= T0 + L
    let mut lookahead = f64::INFINITY;
    for (li, l) in topo.links.iter().enumerate() {
        for (side, node) in [(0usize, l.a), (1usize, l.b)] {
            if gateway[node] {
                lookahead = lookahead.min(consts[li].fixed_ns + consts[li].switch_ns[side]);
            }
        }
    }
    if lookahead <= 0.0 {
        return None; // a zero-latency boundary hop: cannot be conservative
    }
    Some(ShardPlan { node_shard, link_shard, nshards, lookahead })
}

/// Pull source `i` once so it is staged one transaction ahead (the
/// `(clamped issue time, tx)` pair), marking it done when exhausted.
/// The clamp `at = tx.at.max(last_issue)` replicates the serial pump,
/// whose `now` at pull time is the source's previous injection time.
fn stage_next(
    i: usize,
    sources: &mut [&mut dyn TrafficSource],
    staged: &mut [Option<(f64, SourcedTx)>],
    src_done: &mut [bool],
    last_issue: &[f64],
    classes: &[TrafficClass],
) {
    if src_done[i] || staged[i].is_some() {
        return;
    }
    match sources[i].pull(last_issue[i]) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(last_issue[i]);
            staged[i] = Some((at, stx));
        }
        Pull::Done => src_done[i] = true,
        Pull::Blocked => panic!(
            "traffic source {i} (class {}) returned Blocked but declared itself open-loop",
            classes[i].name()
        ),
    }
}

/// Run the sharded simulation. Callers have already verified the plan and
/// that every source is open-loop.
pub(crate) fn run(
    sim: &mut MemSim,
    sources: &mut [&mut dyn TrafficSource],
    plan: &ShardPlan,
) -> StreamReport {
    let fabric: &Fabric = sim.fabric;
    let consts: &[LinkConsts] = &sim.consts;
    let tiers: &[LinkTier] = &sim.tiers;
    let spread = sim.spread;
    let granularity = sim.granularity;
    let k = plan.nshards;
    let nsrc = sources.len();
    let classes: Vec<TrafficClass> = sources.iter().map(|s| s.class()).collect();
    // multi-rail resolution at the coordinator: spray for any spreading
    // policy (Adaptive degrades to HashSpray here — worker-owned queue
    // state is not visible across shard boundaries)
    let rail_fan = fabric.router().max_rails();
    let spraying = rail_fan > 1
        && spread != [false; LinkTier::COUNT]
        && sim.routing_policy().resolution().spreads();

    let mut report = StreamReport::new();
    let mut merged_servers = sim.servers.clone();
    let mut makespan = 0.0f64;
    let mut events = 0u64;
    let mut peak_inflight = 0usize;

    std::thread::scope(|scope| {
        let link_shard: &[u32] = &plan.link_shard;
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(k);
        // one response channel per worker: a dead worker (panic on one of
        // its diagnostic paths) surfaces as a recv error on ITS channel
        // instead of deadlocking the coordinator behind the survivors'
        // still-open clones of a shared sender; shard-ordered collection
        // also makes mailbox fill order deterministic
        let mut res_rxs: Vec<mpsc::Receiver<Resp>> = Vec::with_capacity(k);
        for shard in 0..k {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (res_tx, res_rx) = mpsc::channel::<Resp>();
            cmd_txs.push(cmd_tx);
            res_rxs.push(res_rx);
            let servers0 = sim.servers.clone();
            scope.spawn(move || {
                worker(shard, cmd_rx, res_tx, servers0, fabric, consts, tiers, spread, link_shard, granularity)
            });
        }

        // coordinator state: one staged transaction per source plus the
        // per-shard mailboxes carrying next-epoch deliveries
        let mut staged: Vec<Option<(f64, SourcedTx)>> = (0..nsrc).map(|_| None).collect();
        let mut src_done = vec![false; nsrc];
        let mut last_issue = vec![0.0f64; nsrc];
        // per-source emission index: the spray hash's tx_seq, identical
        // to the serial loop's injection order
        let mut emitted = vec![0u64; nsrc];
        let mut inboxes: Vec<Vec<Handoff>> = (0..k).map(|_| Vec::new()).collect();
        let mut next_events = vec![f64::INFINITY; k];

        loop {
            // keep every active source staged one transaction ahead
            for i in 0..nsrc {
                stage_next(i, sources, &mut staged, &mut src_done, &last_issue, &classes);
            }
            let t_staged =
                staged.iter().flatten().map(|(at, _)| *at).fold(f64::INFINITY, f64::min);
            let t_inbox = inboxes
                .iter()
                .flat_map(|b| b.iter().map(|h| h.at))
                .fold(f64::INFINITY, f64::min);
            let t_engines = next_events.iter().copied().fold(f64::INFINITY, f64::min);
            let t0 = t_staged.min(t_inbox).min(t_engines);
            if !t0.is_finite() {
                break; // sources drained, mailboxes empty, engines idle
            }
            let mut t1 = t0 + plan.lookahead; // INFINITY lookahead: one epoch

            // stage every injection below the window into its first-hop
            // shard's mailbox; the per-source cap bounds memory, shrinking
            // the window to the first unstaged issue time when it bites
            for i in 0..nsrc {
                let mut staged_here = 0usize;
                loop {
                    stage_next(i, sources, &mut staged, &mut src_done, &last_issue, &classes);
                    if src_done[i] {
                        break;
                    }
                    let at = staged[i].as_ref().expect("staged above").0;
                    if at >= t1 {
                        break;
                    }
                    // soft cap: shrinking the window below `at` is only
                    // allowed while it stays strictly above t0, or the
                    // epoch could stall on a same-timestamp burst
                    if staged_here >= MAX_STAGE_PER_SOURCE && at > t0 {
                        t1 = t1.min(at); // keep the window conservative
                        break;
                    }
                    let (at, stx) = staged[i].take().expect("staged above");
                    last_issue[i] = at;
                    let tx = stx.tx;
                    let seq = emitted[i];
                    emitted[i] += 1;
                    // flow-keyed when the source stamped one: same hash
                    // input as the serial injection path
                    let spray_key = stx.flow.unwrap_or(seq);
                    let rail =
                        if spraying { spray_rail(tx.src, tx.dst, spray_key, rail_fan) } else { 0 };
                    // the first hop is rail-dependent: different rails may
                    // enter the fabric through links owned by different shards
                    let target = if tx.src == tx.dst {
                        plan.node_shard[tx.src] as usize
                    } else {
                        match rail_step(fabric, tiers, spread, tx.src, tx.dst, rail) {
                            Some((_, link)) => plan.link_shard[link] as usize,
                            None => panic!(
                                "no path {} ({}) -> {} ({}) for traffic source {} (class {})",
                                tx.src,
                                fabric.topo.node(tx.src).label,
                                tx.dst,
                                fabric.topo.node(tx.dst).label,
                                i,
                                classes[i].name()
                            ),
                        }
                    };
                    inboxes[target].push(Handoff {
                        at,
                        hop: 0,
                        tx: ShardTx {
                            issued: at,
                            bytes: tx.bytes,
                            device_ns: tx.device_ns,
                            src: tx.src as u32,
                            dst: tx.dst as u32,
                            source: i as u32,
                            class: classes[i],
                            token: stx.token,
                            rail,
                        },
                    });
                    staged_here += 1;
                }
            }

            // wake only shards with deliveries or events inside the window
            let mut pinged = vec![false; k];
            for s in 0..k {
                if !inboxes[s].is_empty() || next_events[s] < t1 {
                    let inbox = std::mem::take(&mut inboxes[s]);
                    next_events[s] = f64::INFINITY; // refreshed by the response
                    cmd_txs[s].send(Cmd::Epoch { t1, inbox }).expect("shard worker alive");
                    pinged[s] = true;
                }
            }
            assert!(
                pinged.iter().any(|&p| p),
                "conservative window made no progress (t0={t0}, t1={t1})"
            );

            let mut completions: Vec<Completion> = Vec::new();
            for s in (0..k).filter(|&s| pinged[s]) {
                match res_rxs[s].recv().expect("shard worker alive") {
                    Resp::Epoch { shard, out, completions: c, next_event } => {
                        debug_assert_eq!(shard, s);
                        next_events[shard] = next_event;
                        for (target, h) in out {
                            inboxes[target as usize].push(h);
                        }
                        completions.extend(c);
                    }
                    Resp::Final { .. } => unreachable!("Final before Finish"),
                }
            }
            // merge the barrier's completions in global time order so the
            // report streams identically to the serial loop
            completions.sort_by(|a, b| {
                a.at
                    .total_cmp(&b.at)
                    .then_with(|| a.source.cmp(&b.source))
                    .then_with(|| a.token.cmp(&b.token))
            });
            for c in completions {
                report.record(classes[c.source as usize], c.latency, c.bytes);
                sources[c.source as usize].on_complete(c.token, c.at);
            }
        }

        for tx in &cmd_txs {
            tx.send(Cmd::Finish).expect("shard worker alive");
        }
        for (s, rx) in res_rxs.iter().enumerate() {
            match rx.recv().expect("shard worker alive") {
                Resp::Final { shard, servers, now, dispatched, peak_slots } => {
                    debug_assert_eq!(shard, s);
                    makespan = makespan.max(now);
                    events += dispatched;
                    // the sum of per-shard slot high-waters: the slot
                    // memory actually allocated, an upper bound on the
                    // serial definition (true peak concurrency) since the
                    // shards peak at different times and a multi-shard
                    // path occupies one slot per visited shard
                    peak_inflight += peak_slots;
                    for (li, srv) in servers.into_iter().enumerate() {
                        if plan.link_shard[li] as usize == shard {
                            merged_servers[li] = srv;
                        }
                    }
                }
                Resp::Epoch { .. } => unreachable!("Epoch after Finish"),
            }
        }
    });

    sim.servers = merged_servers;
    report.total.makespan_ns = makespan;
    // same count as the serial streamed loop: its per-transaction
    // injection event is the sharded loop's hop-0 arrival event
    report.total.events = events;
    report.peak_inflight = peak_inflight;
    report.qos = sim.collect_qos_stats();
    report
}

/// One shard: a calendar engine over the shard's link servers, draining
/// events strictly below each epoch's `t1` and emitting cross-shard
/// handoffs for the barrier.
#[allow(clippy::too_many_arguments)]
fn worker(
    shard: usize,
    cmds: mpsc::Receiver<Cmd>,
    res: mpsc::Sender<Resp>,
    mut servers: Vec<[ClassedServer; 2]>,
    fabric: &Fabric,
    consts: &[LinkConsts],
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    link_shard: &[u32],
    granularity: f64,
) {
    let mut engine = Engine::with_granularity(granularity);
    let mut slots: Vec<LocalTx> = Vec::new();
    let mut free: Vec<u32> = Vec::new();
    // shard-local path interning (same arena layout as the serial path;
    // a path crossing three shards is interned by each of the three)
    let mut arena: Vec<u32> = Vec::new();
    let mut cache: HashMap<u64, (u32, u32)> = HashMap::new();

    while let Ok(cmd) = cmds.recv() {
        match cmd {
            Cmd::Epoch { t1, inbox } => {
                let mut out: Vec<(u32, Handoff)> = Vec::new();
                let mut completions: Vec<Completion> = Vec::new();
                for h in inbox {
                    let (path_start, path_len) =
                        intern_local(fabric, tiers, spread, &mut arena, &mut cache, &h.tx);
                    let entry = LocalTx { tx: h.tx, path_start, path_len };
                    let id = match free.pop() {
                        Some(s) => {
                            slots[s as usize] = entry;
                            s as usize
                        }
                        None => {
                            slots.push(entry);
                            slots.len() - 1
                        }
                    };
                    engine.schedule(h.at, EventKind::Arrive { id, hop: h.hop as usize });
                }
                while let Some(t) = engine.peek_time() {
                    if t >= t1 {
                        break;
                    }
                    let (now, ev) = engine.next().expect("peeked event");
                    match ev {
                        EventKind::Arrive { id, hop } => {
                            // mirror of MemSim::step, with the cross-shard
                            // branch on the next hop's link owner
                            let lt = &slots[id];
                            let path_len = lt.path_len as usize;
                            if hop >= path_len {
                                engine.after(lt.tx.device_ns, EventKind::Complete { id });
                                continue;
                            }
                            let h = arena[lt.path_start as usize + hop];
                            let link = (h >> 1) as usize;
                            let dir = (h & 1) as usize;
                            debug_assert_eq!(
                                link_shard[link] as usize, shard,
                                "event for a foreign link reached shard {shard}"
                            );
                            let c = &consts[link];
                            let service = c.flit.wire_bytes(lt.tx.bytes) * c.inv_rate;
                            match servers[link][dir].admit(
                                now,
                                service,
                                lt.tx.bytes,
                                lt.tx.class,
                                id as u32,
                                hop as u32,
                            ) {
                                Admission::Release { done } => forward(
                                    &mut engine, &mut out, &mut free, &arena, link_shard, consts,
                                    shard, &slots, id, link, dir, hop, done,
                                ),
                                Admission::Start { done } => {
                                    engine.schedule(
                                        done,
                                        EventKind::Depart { link: link as u32, dir: dir as u8 },
                                    );
                                    forward(
                                        &mut engine, &mut out, &mut free, &arena, link_shard,
                                        consts, shard, &slots, id, link, dir, hop, done,
                                    );
                                }
                                Admission::Queued => {}
                            }
                        }
                        // a queued-mode link freed: arbitrate, start the
                        // next VC's head, keep the depart chain alive
                        EventKind::Depart { link, dir } => {
                            let (li, di) = (link as usize, dir as usize);
                            if let Some((id, hop, done)) = servers[li][di].depart(now) {
                                engine.schedule(done, EventKind::Depart { link, dir });
                                forward(
                                    &mut engine, &mut out, &mut free, &arena, link_shard, consts,
                                    shard, &slots, id as usize, li, di, hop as usize, done,
                                );
                            }
                        }
                        EventKind::Complete { id } => {
                            let lt = &slots[id];
                            completions.push(Completion {
                                at: now,
                                latency: now - lt.tx.issued,
                                bytes: lt.tx.bytes,
                                source: lt.tx.source,
                                token: lt.tx.token,
                            });
                            free.push(id as u32);
                        }
                        EventKind::Custom { .. } => {
                            unreachable!("sharded shards schedule no custom events")
                        }
                    }
                }
                let next_event = engine.peek_time().unwrap_or(f64::INFINITY);
                if res.send(Resp::Epoch { shard, out, completions, next_event }).is_err() {
                    return; // coordinator gone (panic unwinding)
                }
            }
            Cmd::Finish => {
                let _ = res.send(Resp::Final {
                    shard,
                    servers,
                    now: engine.now(),
                    dispatched: engine.dispatched(),
                    peak_slots: slots.len(),
                });
                return;
            }
        }
    }
}

/// After a service on `(served_link, dir)` completes at `done`: put
/// transaction `id` onto its next hop — a cross-shard handoff when the
/// next link belongs to another shard (freeing the local slot), a local
/// Arrive event otherwise. Shared by the admit and depart paths; a
/// handoff's arrival time is `done + fixed + switch >= now + L`, so the
/// conservative-lookahead argument is unchanged under queued arbitration.
#[allow(clippy::too_many_arguments)]
fn forward(
    engine: &mut Engine,
    out: &mut Vec<(u32, Handoff)>,
    free: &mut Vec<u32>,
    arena: &[u32],
    link_shard: &[u32],
    consts: &[LinkConsts],
    shard: usize,
    slots: &[LocalTx],
    id: usize,
    served_link: usize,
    dir: usize,
    hop: usize,
    done: f64,
) {
    let lt = &slots[id];
    let c = &consts[served_link];
    let t_next = done + c.fixed_ns + c.switch_ns[1 - dir];
    let nh = hop + 1;
    if nh < lt.path_len as usize {
        let next_link = (arena[lt.path_start as usize + nh] >> 1) as usize;
        let target = link_shard[next_link];
        if target as usize != shard {
            out.push((target, Handoff { at: t_next, hop: nh as u32, tx: lt.tx }));
            free.push(id as u32);
            return;
        }
    }
    engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
}

/// Shard-local twin of `MemSim::intern_path` (same arena packing:
/// `(link << 1) | direction`, direction decided once at build time; same
/// `(src, dst, rail)` cache key, same rail-aware walk — a path crossing
/// three shards is interned by each of the three).
fn intern_local(
    fabric: &Fabric,
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    arena: &mut Vec<u32>,
    cache: &mut HashMap<u64, (u32, u32)>,
    tx: &ShardTx,
) -> (u32, u32) {
    let key = path_key(tx.src as usize, tx.dst as usize, tx.rail);
    if let Some(&r) = cache.get(&key) {
        return r;
    }
    let start = arena.len() as u32;
    if !rail_hops(fabric, tiers, spread, tx.src as usize, tx.dst as usize, tx.rail, arena) {
        // the coordinator verified the first hop, so this means the
        // PBR table lost the route mid-path — name the flow anyway
        panic!(
            "no path {} ({}) -> {} ({}) on rail {} for traffic source {}",
            tx.src,
            fabric.topo.node(tx.src as usize).label,
            tx.dst,
            fabric.topo.node(tx.dst as usize).label,
            tx.rail,
            tx.source
        );
    }
    let entry = (start, arena.len() as u32 - start);
    cache.insert(key, entry);
    entry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, Topology};
    use crate::sim::memsim::MemSim;
    use crate::sim::{BatchSource, Transaction};

    /// A pod-shaped Clos: `leaves` leaf switches, endpoints per leaf.
    fn clos(leaves: usize, spines: usize, eps: usize) -> (Fabric, Vec<usize>) {
        let (mut t, leaf_ids) = Topology::clos(leaves, spines, LinkKind::CxlCoherent, "f");
        let mut out = Vec::new();
        for (i, &l) in leaf_ids.iter().enumerate() {
            for e in 0..eps {
                let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                t.connect(n, l, LinkKind::CxlCoherent);
                out.push(n);
            }
        }
        (Fabric::new(t), out)
    }

    fn workload(eps: &[usize], n: usize, seed: u64) -> Vec<Transaction> {
        let mut rng = crate::util::Rng::new(seed);
        let mut at = 0.0;
        (0..n)
            .map(|_| {
                at += rng.exp(1.0 / 25.0) + 1e-6;
                let s = rng.below(eps.len() as u64) as usize;
                let mut d = rng.below(eps.len() as u64) as usize;
                if d == s {
                    d = (d + 1) % eps.len();
                }
                Transaction { src: eps[s], dst: eps[d], at, bytes: 2048.0, device_ns: 90.0 }
            })
            .collect()
    }

    #[test]
    fn plan_reflects_topology() {
        let (f, _) = clos(8, 2, 4);
        let sim = MemSim::new(&f);
        let p = plan(&f, &sim.consts, 4).expect("clos must shard");
        assert!(p.nshards >= 2 && p.nshards <= 4);
        assert!(p.lookahead > 0.0 && p.lookahead.is_finite());
        assert_eq!(p.link_shard.len(), f.topo.links.len());
        // single-hop rack: one domain, no plan
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let f1 = Fabric::new(t);
        let s1 = MemSim::new(&f1);
        assert!(plan(&f1, &s1.consts, 4).is_none());
        // one requested shard: no plan
        assert!(plan(&f, &sim.consts, 1).is_none());
    }

    #[test]
    fn sharded_matches_serial_on_clos() {
        let (f, eps) = clos(6, 2, 6);
        let txs = workload(&eps, 600, 0x5AA5);

        let mut serial_sim = MemSim::new(&f);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        assert!(close(serial.latency.min(), sharded.total.latency.min()));
        // per-link utilization state merged back from the workers
        assert!(sharded_sim.peak_utilization(sharded.total.makespan_ns) > 0.0);
    }

    #[test]
    fn sharded_spray_matches_serial_spray() {
        // the multi-rail twin of sharded_matches_serial_on_clos: rails
        // resolved at the coordinator hash identically to the serial
        // loop's injection-time resolution
        use crate::sim::{RailSelector, RoutingPolicy};
        let (mut f, eps) = clos(6, 2, 6);
        f.enable_multipath(4);
        let txs = workload(&eps, 600, 0xB1A5);
        let policy = RoutingPolicy::uniform(RailSelector::HashSpray);

        let mut serial_sim = MemSim::with_routing(&f, policy);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::with_routing(&f, policy);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        // the spray actually spread: more ridden paths than pairs
        assert!(
            serial_sim.used_path_count() > serial_sim.used_pair_count(),
            "spray rode no extra rails"
        );
    }

    #[test]
    fn reactive_sources_fall_back_to_serial() {
        struct Chain {
            src: usize,
            dst: usize,
            left: usize,
            waiting: bool,
        }
        impl TrafficSource for Chain {
            fn class(&self) -> TrafficClass {
                TrafficClass::Generic
            }
            fn pull(&mut self, now: f64) -> Pull {
                if self.left == 0 {
                    return Pull::Done;
                }
                if self.waiting {
                    return Pull::Blocked;
                }
                self.left -= 1;
                self.waiting = true;
                Pull::Tx(super::super::traffic::SourcedTx::new(
                    Transaction { src: self.src, dst: self.dst, at: now, bytes: 512.0, device_ns: 0.0 },
                    0,
                ))
            }
            fn on_complete(&mut self, _token: u64, _now: f64) {
                self.waiting = false;
            }
            // open_loop() stays false: reactive
        }
        let (f, eps) = clos(4, 2, 2);
        let mut sim = MemSim::new(&f);
        let mut chain = Chain { src: eps[0], dst: eps[eps.len() - 1], left: 4, waiting: false };
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut chain];
            sim.run_streamed_sharded(&mut sources)
        };
        // the serial fallback must run the reactive chain to completion
        assert_eq!(rep.total.completed, 4);
    }

    #[test]
    fn zero_hop_transactions_shard_cleanly() {
        let (f, eps) = clos(4, 2, 3);
        let txs: Vec<Transaction> = (0..40)
            .map(|i| Transaction {
                src: eps[i % eps.len()],
                dst: eps[i % eps.len()],
                at: 1.0 + i as f64,
                bytes: 64.0,
                device_ns: 250.0,
            })
            .collect();
        let mut sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed_sharded_with(&mut sources, 4)
        };
        assert_eq!(rep.total.completed, 40);
        assert!((rep.total.latency.mean() - 250.0).abs() < 1e-9);
    }
}
