//! Sharded conservative execution of the streamed simulation: the fabric
//! is partitioned into topology-derived domains (rack/leaf subtrees, see
//! [`Topology::partition_domains`](crate::fabric::Topology::partition_domains)),
//! each shard owns the class-aware [`ClassedServer`]s of its links and
//! runs its own calendar [`Engine`] on a scoped worker thread, and
//! transactions whose next hop leaves the shard are handed off through
//! per-shard mailboxes. QoS arbitration is shard-local state: a queued
//! transaction waits in its link's virtual channel and the `Depart`
//! chain (see [`super::qos`]) restarts it, so a handoff is still stamped
//! `service_done + fixed + switch` and the lookahead bound below holds
//! under every policy.
//!
//! # Conservative synchronization
//!
//! Parallelism is *conservative* (no rollback): simulation advances in
//! epochs `[T0, T0 + L)` where `T0` is the earliest pending event or
//! injection anywhere and `L` is the **lookahead** — the minimum latency
//! any transaction needs to cross a partition boundary, computed as the
//! minimum over boundary-forwarding link directions of
//! `fixed_ns + switch_traversal` (a handoff's arrival time is
//! `server_done + fixed + switch`, and `server_done >= now`, so every
//! cross-shard message generated inside an epoch is stamped `>= T0 + L`
//! and can safely be delivered at the epoch barrier). With `L <= 0` or a
//! single domain the caller falls back to the serial loop.
//!
//! # Coupled-domain scheduling of reactive sources
//!
//! **Open-loop** sources ([`TrafficSource::open_loop`]) stay on the
//! coordinator thread: injections are staged ahead of the window and
//! `on_complete` is telemetry-only (invoked at the barrier in
//! completion-time order). A **reactive** source's zero-delay
//! completion→emission chain could cross shards faster than any fabric
//! lookahead — so a reactive source is only admitted when it declares a
//! static [`TrafficSource::footprint`]. [`plan`] closes each footprint
//! over the *owners* of every link its traffic can ride (all ordered
//! endpoint pairs × all rails it can spray over) and hands the closures
//! to [`Topology::partition_domains_coupled`](crate::fabric::Topology::partition_domains_coupled),
//! which merges the touched domains before balanced packing. The source
//! is then **pinned to its owning shard's worker**: pull, injection,
//! `on_complete` and the unblock chain all run inside that worker's
//! event loop (an exact port of the serial pump), and by construction
//! none of its transactions ever generates a cross-shard handoff. When
//! *every* source is pinned no traffic crosses a boundary at all, the
//! lookahead is `INFINITY` and the whole run is one fully parallel
//! epoch. A reactive source without a footprint falls the whole run
//! back to the serial loop, reported through
//! [`ShardMode::SerialFallback`]. A *declared* footprint whose closure
//! would collapse the partition to a single shard (e.g. a fabric-wide
//! ring) no longer does: the group is excluded from coupling by
//! [`Topology::partition_domains_coupled_spanning`](crate::fabric::Topology::partition_domains_coupled_spanning)
//! and the source runs on the coordinator under the optimistic protocol
//! below — provided every reactive source supports
//! [`TrafficSource::checkpoint`]; otherwise the run stays serial and the
//! fallback reason names the offending source.
//!
//! # Optimistic execution of spanning footprints
//!
//! A spanning source's completion→emission chain can cross shards
//! faster than any lookahead, so conservative windows cannot contain
//! it. Instead the run turns *optimistic* (time-warp-lite, rollback at
//! epoch granularity) for exactly the windows where a spanning source
//! can act — an injection staged below `t1`, or one of its
//! transactions in flight:
//!
//! * **Checkpoint.** At the window's barrier the coordinator snapshots
//!   each spanning source ([`TrafficSource::checkpoint`]) plus its
//!   staging bookkeeping, and every participating worker snapshots its
//!   mutable shard state (calendar [`Engine`], [`ClassedServer`] link
//!   state, in-flight slot table, pinned-source cursors) before
//!   executing the window.
//! * **Speculate.** Spanning injections staged below `t1` are recorded
//!   as a speculative set and delivered like ordinary hop-0 handoffs;
//!   the window then executes normally. Worker outputs (handoffs,
//!   completions) are held per attempt and only routed at commit, and
//!   the conservative bound stamps every cross-shard handoff `>= t1`,
//!   so a rollback never has to chase messages into other shards.
//! * **Validate.** After the barrier the coordinator rewinds the
//!   spanning sources to the checkpoint and replays their decision
//!   procedure against the completions the attempt actually produced
//!   (merged in time order, completions before same-instant injections
//!   — the serial pump's dispatch order). If the replayed injection
//!   set equals the speculative set the epoch commits; otherwise every
//!   participating worker rolls back, the speculative set is
//!   *replaced* by the replayed one, inboxes are rebuilt canonically
//!   (committed deliveries first, then speculative injections
//!   source-major) and the window re-executes. The earliest divergence
//!   strictly advances each round, so the fixpoint terminates;
//!   [`StreamReport`] counts `checkpoints` and `rollbacks`.
//!
//! Windows where no spanning source can act skip all of this and run
//! as plain conservative epochs — an optimistic run degenerates to the
//! conservative protocol at zero cost while spanning traffic is idle.
//! The serial loop stays the byte-exact oracle
//! (`tests/prop_invariants.rs::prop_optimistic_matches_serial`).
//!
//! # Multi-rail routing
//!
//! Rails are resolved at injection — by the coordinator at staging time
//! for open-loop sources, by the owning worker for pinned sources —
//! hashing the identical `(src, dst, flow-or-emission-index)` key (a
//! source that stamps
//! [`SourcedTx::with_flow`](super::traffic::SourcedTx::with_flow)
//! pins the whole flow to one rail; otherwise the per-source emission
//! index sprays per transaction), so
//! [`RailSelector::HashSpray`](super::rails::RailSelector) picks the
//! same rail for every transaction on both backends (pinned by
//! `prop_sharded_matches_serial`'s policy sweep).
//! [`RailSelector::Adaptive`](super::rails::RailSelector) needs the
//! link-server backlog, which lives on the workers — so each worker
//! piggybacks a per-owned-link
//! [`pending_ns`](super::qos::ClassedServer::pending_ns) digest on its
//! epoch-barrier response, the coordinator folds the digests into one
//! global table (applied only at commit, so replay attempts see
//! identical state), and both the coordinator's staging and the
//! workers' pinned-source injections score candidate rails against
//! that table (strict `<`, ties to the lowest rail — the serial
//! tie-break). The digest is one barrier stale by design: runs are
//! deterministic and work-conserving, but rail choices can differ from
//! the serial backend's live-state scoring, so byte parity is pinned
//! for Deterministic and HashSpray only. The conservative lookahead is
//! unchanged by multipath: `plan` minimizes `fixed + switch` over
//! *every* link direction whose receiver is a gateway node, a superset
//! of the union of boundary-crossing rails, so every rail a transaction
//! can ride is already inside the bound; footprint closures walk the
//! same rail set, so a pinned source's sprayed traffic is co-located on
//! every rail it can pick.
//!
//! # Equivalence
//!
//! Within a shard events dispatch in `(time, seq)` order and every
//! per-server admission sequence is time-ordered exactly as in the serial
//! loop (including the same-timestamp same-link-direction
//! [`ClassedServer::admit_batch`] coalescing the serial loop uses), so
//! per-class completed counts, byte totals and the sorted
//! per-transaction latency multiset match the serial backend
//! (`tests/prop_invariants.rs::prop_sharded_matches_serial`). Event
//! *counts* use the same convention as the serial streamed loop (one
//! injection event per transaction on top of the hop events).

use super::engine::{Engine, EngineSnapshot, EventKind};
use super::memsim::{path_key, rail_hops, rail_step, LinkConsts, MemSim};
use super::qos::{Admission, BatchAdmit, ClassedServer, LinkTier};
use super::rails::{spray_rail, RailSelector};
use super::trace::{GaugeSample, InstantEvent, InstantKind, TraceData, TraceSink};
use super::traffic::{
    Pull, ShardMode, ShardStats, SourcedTx, StreamReport, TrafficClass, TrafficSource,
};
use crate::fabric::{Fabric, NodeId, NodeKind};
use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Replay attempts per optimistic window before declaring the fixpoint
/// broken. The earliest divergence strictly advances every round (each
/// replay only appends or corrects decisions at or after the previous
/// round's first divergence), so hitting this cap means a bug — panic
/// loudly instead of spinning.
const MAX_REPLAY_ATTEMPTS: usize = 1000;

/// Per-source injections staged beyond the current window are bounded, so
/// streamed memory stays O(peak in-flight) even under infinite lookahead
/// (fully disjoint shards).
const MAX_STAGE_PER_SOURCE: usize = 4096;

/// Cap on the coordinator-side epoch/checkpoint/rollback instant events a
/// traced run retains (the protocol record, never rolled back). Bounded so
/// a pathological barrier count cannot grow the trace O(epochs).
const MAX_COORD_INSTANTS: usize = 1 << 16;

/// What [`plan`] needs to know about each source: whether it is
/// open-loop (stays on the coordinator), for reactive sources the static
/// footprint to co-locate (`None` = undeclared → serial fallback), the
/// traffic class (named in fallback reasons) and whether the source
/// supports the checkpoint/restore protocol a spanning footprint needs.
pub(crate) struct SourceMeta {
    pub(crate) open: bool,
    pub(crate) footprint: Option<Vec<NodeId>>,
    pub(crate) class: TrafficClass,
    pub(crate) checkpointable: bool,
}

/// [`plan`]'s verdict: a runnable partition, or the reason the run must
/// stay serial (surfaced as [`ShardMode::SerialFallback`]).
pub(crate) enum PlanOutcome {
    Sharded(ShardPlan),
    Fallback(String),
}

impl PlanOutcome {
    #[cfg(test)]
    pub(crate) fn sharded(self) -> Option<ShardPlan> {
        match self {
            PlanOutcome::Sharded(p) => Some(p),
            PlanOutcome::Fallback(_) => None,
        }
    }
}

/// The partition and its conservative bound.
pub(crate) struct ShardPlan {
    pub(crate) node_shard: Vec<u32>,
    pub(crate) link_shard: Vec<u32>,
    pub(crate) nshards: usize,
    /// Owning shard per source: `Some(shard)` pins a reactive source to
    /// that shard's worker, `None` keeps an open-loop or spanning source
    /// on the coordinator.
    pub(crate) pinned: Vec<Option<u32>>,
    /// Reactive sources whose footprint closure spans the partition:
    /// they run on the coordinator under the optimistic
    /// checkpoint/rollback protocol (see the module docs) instead of
    /// collapsing the whole run to the serial loop.
    pub(crate) spanning: Vec<bool>,
    /// Minimum cross-partition hop latency, ns (`f64::INFINITY` when no
    /// traffic can cross a boundary — every source pinned — so shards
    /// run fully decoupled in a single epoch).
    pub(crate) lookahead: f64,
}

/// Transaction state carried across shard boundaries by value (each shard
/// interns paths locally, so messages stay plain scalars).
#[derive(Clone, Copy, PartialEq)]
struct ShardTx {
    issued: f64,
    bytes: f64,
    device_ns: f64,
    src: u32,
    dst: u32,
    source: u32,
    class: TrafficClass,
    token: u64,
    /// Equal-cost rail this transaction rides, resolved once at
    /// injection (see the multi-rail note above).
    rail: u16,
}

/// A mailbox message: "transaction `tx` arrives at hop `hop` at `at`".
/// Injections are the `hop == 0` case. `Copy` so an optimistic window's
/// committed deliveries can be snapshotted and replayed cheaply.
#[derive(Clone, Copy)]
struct Handoff {
    at: f64,
    hop: u32,
    tx: ShardTx,
}

#[derive(Clone)]
struct LocalTx {
    tx: ShardTx,
    path_start: u32,
    path_len: u32,
}

/// One speculative spanning injection: everything the attempt's workers
/// saw of it. Two attempts whose `SpecTx` sequences compare equal ran
/// the same window, so equality is the optimistic commit criterion.
#[derive(Clone, Copy, PartialEq)]
struct SpecTx {
    at: f64,
    /// First-hop shard the hop-0 handoff was delivered to.
    target: u32,
    tx: ShardTx,
}

/// Coordinator-side snapshot of one spanning source at an optimistic
/// window's barrier: the source's own state plus the staging cursors the
/// validation replay rewinds to.
struct SpanCkpt {
    snap: Box<dyn std::any::Any + Send>,
    staged: Option<(f64, SourcedTx)>,
    blocked: bool,
    done: bool,
    inflight: usize,
    last_issue: f64,
    emitted: u64,
}

enum Cmd {
    /// Run one epoch `[.., t1)`. `inbox` carries this epoch's deliveries;
    /// `out` and `completions` are empty recycled buffers the worker
    /// fills and returns (mailbox memory is reused across epochs instead
    /// of reallocated). `checkpoint` asks the worker to snapshot its
    /// mutable state before executing (optimistic window, first
    /// participation); `rollback` asks it to restore that snapshot first
    /// (replay attempt). `digest` is the epoch-start backlog table for
    /// adaptive rail resolution (`None` when the run is not adaptive).
    Epoch {
        t1: f64,
        inbox: Vec<Handoff>,
        out: Vec<(u32, Handoff)>,
        completions: Vec<Completion>,
        checkpoint: bool,
        rollback: bool,
        digest: Option<Arc<Vec<[f64; 2]>>>,
    },
    Finish,
}

struct Completion {
    at: f64,
    latency: f64,
    bytes: f64,
    source: u32,
    token: u64,
}

enum Resp {
    Epoch {
        shard: usize,
        /// Cross-shard handoffs generated this epoch: `(target, message)`.
        out: Vec<(u32, Handoff)>,
        completions: Vec<Completion>,
        /// The drained inbox buffer, returned for recycling.
        spent: Vec<Handoff>,
        /// Earliest still-pending local event (INFINITY when idle).
        next_event: f64,
        /// Per owned link: `pending_ns` of both direction servers at the
        /// window edge, for the coordinator's adaptive-routing table.
        /// Empty unless the epoch command carried a digest.
        digest: Vec<(u32, [f64; 2])>,
    },
    Final {
        shard: usize,
        servers: Vec<[ClassedServer; 2]>,
        now: f64,
        dispatched: u64,
        /// Hops this worker's express chains admitted inline (each one a
        /// calendar event its engine never dispatched).
        fused: u64,
        peak_slots: usize,
        /// Wall-clock seconds this worker spent waiting on the barrier.
        idle_s: f64,
        /// The worker's flight-recorder sink, handed back for the
        /// coordinator's shard-ordered merge (`None` when not tracing).
        trace: Option<Box<TraceSink>>,
    },
}

/// The shard that owns link `l`: the endpoint side's subtree when one
/// side is an endpoint, else node `a`'s domain. Every link is owned by
/// exactly one shard, which owns both direction servers. The footprint
/// closure in [`plan`] MUST use the same rule, so it closes over the
/// node whose `node_shard` entry decides each traversed link.
#[inline]
fn link_owner(topo: &crate::fabric::Topology, a: NodeId, b: NodeId) -> NodeId {
    if topo.node(a).kind != NodeKind::Switch {
        a
    } else if topo.node(b).kind != NodeKind::Switch {
        b
    } else {
        a
    }
}

/// Derive the shard plan: topology domains (coupled over reactive
/// footprints), link ownership, source pinning and the conservative
/// lookahead. `rails` is the effective rail fan at injection (1 when the
/// run does not spray) — footprint closures walk every rail a pinned
/// source's traffic can ride. Returns [`PlanOutcome::Fallback`] with the
/// reason when sharding cannot help or cannot be conservative.
pub(crate) fn plan(
    fabric: &Fabric,
    consts: &[LinkConsts],
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    rails: u16,
    meta: &[SourceMeta],
    max_shards: usize,
) -> PlanOutcome {
    if max_shards <= 1 {
        return PlanOutcome::Fallback("sharding disabled (max_shards <= 1)".into());
    }
    let topo = &fabric.topo;
    // footprint closure per reactive source: the declared nodes plus the
    // owner node of every link any of its transactions can traverse, on
    // every rail it can spray over — co-locating the owners co-locates
    // the link servers, so the source's events never leave its shard
    let mut groups: Vec<Vec<NodeId>> = Vec::new();
    // group index -> source index, so per-group spanning verdicts map
    // back onto sources
    let mut group_src: Vec<usize> = Vec::new();
    for (i, m) in meta.iter().enumerate() {
        if m.open {
            continue;
        }
        let fp = match &m.footprint {
            Some(fp) => fp,
            None => {
                return PlanOutcome::Fallback(format!(
                    "reactive source {i} (class {}) has no static footprint",
                    m.class.name()
                ))
            }
        };
        if fp.is_empty() {
            continue; // emits nothing: pinned to shard 0 below
        }
        let mut closure: Vec<NodeId> = fp.clone();
        let mut seen = vec![false; topo.nodes.len()];
        for &n in &closure {
            seen[n] = true;
        }
        for &a in fp {
            for &b in fp {
                if a == b {
                    continue;
                }
                for rail in 0..rails.max(1) {
                    let mut at = a;
                    let mut steps = 0usize;
                    while at != b {
                        let Some((next, link)) = rail_step(fabric, tiers, spread, at, b, rail)
                        else {
                            break; // unreachable pair: injection will panic, not here
                        };
                        let l = &topo.links[link];
                        let owner = link_owner(topo, l.a, l.b);
                        if !seen[owner] {
                            seen[owner] = true;
                            closure.push(owner);
                        }
                        at = next;
                        steps += 1;
                        if steps > topo.nodes.len() {
                            break; // routing loop guard
                        }
                    }
                }
            }
        }
        groups.push(closure);
        group_src.push(i);
    }
    // a closure that would collapse the partition (e.g. a fabric-wide
    // ring) is excluded from coupling and marked *spanning* — it runs on
    // the coordinator under the optimistic protocol instead of forcing
    // the serial loop
    let (node_shard, span_groups) = if groups.is_empty() {
        (topo.partition_domains(max_shards), Vec::new())
    } else {
        topo.partition_domains_coupled_spanning(max_shards, &groups)
    };
    let nshards = node_shard.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    if nshards <= 1 {
        return PlanOutcome::Fallback("topology yields a single domain".into());
    }
    let mut spanning = vec![false; meta.len()];
    for (g, &src) in group_src.iter().enumerate() {
        if span_groups.get(g).copied().unwrap_or(false) {
            spanning[src] = true;
        }
    }
    let any_span = spanning.iter().any(|&s| s);
    if any_span {
        // optimistic windows snapshot EVERY reactive source at the
        // barrier (spanning ones on the coordinator, pinned ones inside
        // their worker's rollback path), so all of them must support
        // the checkpoint/restore protocol
        if let Some(i) = (0..meta.len()).find(|&i| !meta[i].open && !meta[i].checkpointable) {
            let s = spanning.iter().position(|&s| s).expect("any_span implies a spanning source");
            return PlanOutcome::Fallback(format!(
                "reactive source {s} (class {}) has a footprint spanning the partition and \
                 reactive source {i} (class {}) does not support checkpoint/rollback",
                meta[s].class.name(),
                meta[i].class.name()
            ));
        }
    }
    let link_shard: Vec<u32> =
        topo.links.iter().map(|l| node_shard[link_owner(topo, l.a, l.b)]).collect();
    let first = link_shard.first().copied();
    if link_shard.iter().all(|&s| Some(s) == first) {
        return PlanOutcome::Fallback("every link owned by one shard".into());
    }
    // pin each non-spanning reactive source to the shard holding its
    // (merged) closure; spanning sources stay coordinator-owned
    let mut pinned: Vec<Option<u32>> = Vec::with_capacity(meta.len());
    let mut g = 0usize;
    for (i, m) in meta.iter().enumerate() {
        if m.open {
            pinned.push(None);
        } else if m.footprint.as_ref().map(|fp| fp.is_empty()).unwrap_or(false) {
            pinned.push(Some(0));
        } else if spanning[i] {
            g += 1;
            pinned.push(None); // coordinator-owned, optimistic
        } else {
            let group = &groups[g];
            g += 1;
            let shard = node_shard[group[0]];
            debug_assert!(
                group.iter().all(|&n| node_shard[n] == shard),
                "coupled partition split a reactive footprint closure"
            );
            pinned.push(Some(shard));
        }
    }
    let any_open = meta.iter().any(|m| m.open);
    if !any_open && !any_span && !meta.is_empty() {
        let first_pin = pinned.first().copied().flatten();
        if pinned.iter().all(|&p| p == first_pin) {
            return PlanOutcome::Fallback(
                "every reactive source pinned to one shard (nothing to parallelize)".into(),
            );
        }
    }
    // lookahead: only open-loop and spanning traffic can cross shard
    // boundaries (a pinned source's closure keeps its whole path inside
    // one shard), so with neither the bound is INFINITY — one decoupled
    // epoch. Otherwise a handoff out of link (l, dir) arrives at
    // done + fixed + switch_at_receiver with done >= now, so minimize
    // fixed + switch over directions whose receiving node is a gateway
    // (usually a switch; a non-switch gateway contributes switch_ns = 0,
    // which keeps the bound conservative on graphs that route through
    // endpoints). Multipath-safe by construction: this minimizes over
    // EVERY gateway-receiving link direction — a superset of the union
    // of boundary-crossing rails — so whichever equal-cost rail a
    // transaction rides, its handoffs are stamped >= T0 + L
    let lookahead = if !any_open && !any_span {
        f64::INFINITY
    } else {
        let mut gateway = vec![false; topo.nodes.len()];
        for (n, gw) in gateway.iter_mut().enumerate() {
            let mut s0 = None;
            for &(_, l) in topo.neighbors(n) {
                match s0 {
                    None => s0 = Some(link_shard[l]),
                    Some(x) if x != link_shard[l] => {
                        *gw = true;
                        break;
                    }
                    _ => {}
                }
            }
        }
        let mut lookahead = f64::INFINITY;
        for (li, l) in topo.links.iter().enumerate() {
            for (side, node) in [(0usize, l.a), (1usize, l.b)] {
                if gateway[node] {
                    lookahead = lookahead.min(consts[li].fixed_ns + consts[li].switch_ns[side]);
                }
            }
        }
        if lookahead <= 0.0 {
            return PlanOutcome::Fallback(
                "non-positive conservative lookahead (zero-latency boundary hop)".into(),
            );
        }
        lookahead
    };
    PlanOutcome::Sharded(ShardPlan { node_shard, link_shard, nshards, pinned, spanning, lookahead })
}

/// Pull coordinator-owned source `i` once so it is staged one
/// transaction ahead (the `(clamped issue time, tx)` pair), marking it
/// done when exhausted. The clamp `at = tx.at.max(last_issue)` replicates
/// the serial pump, whose `now` at pull time is the source's previous
/// injection time. Pinned sources (slot `None`) are staged by their
/// worker, never here.
fn stage_next(
    i: usize,
    sources: &mut [Option<&mut dyn TrafficSource>],
    staged: &mut [Option<(f64, SourcedTx)>],
    src_done: &mut [bool],
    last_issue: &[f64],
    classes: &[TrafficClass],
) {
    if src_done[i] || staged[i].is_some() {
        return;
    }
    let Some(src) = sources[i].as_mut() else {
        src_done[i] = true;
        return;
    };
    match src.pull(last_issue[i]) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(last_issue[i]);
            staged[i] = Some((at, stx));
        }
        Pull::Done => src_done[i] = true,
        Pull::Blocked => panic!(
            "traffic source {i} (class {}) returned Blocked but declared itself open-loop",
            classes[i].name()
        ),
    }
}

/// Pull coordinator-owned *spanning* source `i` once at `now` — the
/// serial pump for a reactive source, run on the coordinator: stage one
/// ahead, park on `Blocked` (a completion unblocks it during
/// validation), mark done on exhaustion. Shared by window staging and
/// the validation replay, so both advance the source identically.
fn stage_span(
    i: usize,
    now: f64,
    sources: &mut [Option<&mut dyn TrafficSource>],
    staged: &mut [Option<(f64, SourcedTx)>],
    src_done: &mut [bool],
    blocked: &mut [bool],
    inflight: &[usize],
) {
    if src_done[i] || blocked[i] || staged[i].is_some() {
        return;
    }
    let src = sources[i].as_mut().expect("spanning source owned by coordinator");
    match src.pull(now) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(now);
            staged[i] = Some((at, stx));
        }
        Pull::Blocked => {
            assert!(
                inflight[i] > 0,
                "spanning traffic source {i} blocked with nothing in flight (deadlock)"
            );
            blocked[i] = true;
        }
        Pull::Done => src_done[i] = true,
    }
}

/// How an injection resolves its rail, bundled so the coordinator's
/// staging, the validation replay and the workers' pinned-source pumps
/// all pick through the identical procedure.
struct RailChoice<'a> {
    fabric: &'a Fabric,
    tiers: &'a [LinkTier],
    spread: [bool; LinkTier::COUNT],
    spraying: bool,
    adaptive: bool,
    rail_fan: usize,
    /// Barrier-piggybacked backlog per `(link, direction)`; empty unless
    /// `adaptive`. Updated only at epoch commits, so every replay
    /// attempt of a window scores against the same table.
    digest: &'a [[f64; 2]],
}

impl RailChoice<'_> {
    /// Resolve one injection's rail: least-digest-backlog candidate
    /// under Adaptive, the ECMP spray hash under HashSpray, rail 0 when
    /// the run does not spread.
    fn pick(&self, src: usize, dst: usize, key: u64, scratch: &mut Vec<u32>) -> u16 {
        if !self.spraying {
            return 0;
        }
        if !self.adaptive {
            return spray_rail(src, dst, key, self.rail_fan);
        }
        // score every candidate rail by the digest backlog along its
        // path; strict `<` keeps ties on the lowest rail, mirroring the
        // serial resolver's tie-break
        let mut best = 0u16;
        let mut best_cost = f64::INFINITY;
        for rail in 0..self.rail_fan as u16 {
            scratch.clear();
            if !rail_hops(self.fabric, self.tiers, self.spread, src, dst, rail, scratch) {
                continue; // unreachable on this rail: interning names it later
            }
            let cost: f64 = scratch
                .iter()
                .map(|&h| self.digest[(h >> 1) as usize][(h & 1) as usize])
                .sum();
            if cost < best_cost {
                best_cost = cost;
                best = rail;
            }
        }
        best
    }
}

/// Turn spanning source `i`'s staged pull at `at` into the speculative
/// injection record: advance the emission cursor, resolve the rail and
/// the first-hop shard. The caller pushes the hop-0 [`Handoff`] (window
/// staging) or only the record (validation replay) — both derive
/// bit-identical `SpecTx`es from identical source state, which is what
/// makes the fixpoint comparison sound.
#[allow(clippy::too_many_arguments)]
fn speculate_span(
    i: usize,
    at: f64,
    stx: &SourcedTx,
    plan: &ShardPlan,
    classes: &[TrafficClass],
    rc: &RailChoice<'_>,
    scratch: &mut Vec<u32>,
    emitted: &mut [u64],
    inflight: &mut [usize],
) -> SpecTx {
    let tx = stx.tx;
    let seq = emitted[i];
    emitted[i] += 1;
    let rail = rc.pick(tx.src, tx.dst, stx.flow.unwrap_or(seq), scratch);
    let target = if tx.src == tx.dst {
        plan.node_shard[tx.src]
    } else {
        match rail_step(rc.fabric, rc.tiers, rc.spread, tx.src, tx.dst, rail) {
            Some((_, link)) => plan.link_shard[link],
            None => panic!(
                "no path {} ({}) -> {} ({}) for traffic source {} (class {})",
                tx.src,
                rc.fabric.topo.node(tx.src).label,
                tx.dst,
                rc.fabric.topo.node(tx.dst).label,
                i,
                classes[i].name()
            ),
        }
    };
    inflight[i] += 1;
    SpecTx {
        at,
        target,
        tx: ShardTx {
            issued: at,
            bytes: tx.bytes,
            device_ns: tx.device_ns,
            src: tx.src as u32,
            dst: tx.dst as u32,
            source: i as u32,
            class: classes[i],
            token: stx.token,
            rail,
        },
    }
}

/// A reactive source pinned to one shard's worker: the worker runs the
/// exact serial pump for it (stage one ahead as a `Custom` injection
/// event, inject at issue time, `on_complete` + unblock on local
/// completions).
struct PinnedSrc<'s> {
    global: u32,
    src: &'s mut dyn TrafficSource,
    staged: Option<SourcedTx>,
    state: PinState,
    inflight: usize,
    emitted: u64,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum PinState {
    Active,
    Blocked,
    Done,
}

/// Read-only run parameters shared by every worker.
struct WorkerCtx<'e> {
    shard: usize,
    fabric: &'e Fabric,
    consts: &'e [LinkConsts],
    tiers: &'e [LinkTier],
    spread: [bool; LinkTier::COUNT],
    link_shard: &'e [u32],
    granularity: f64,
    rail_fan: usize,
    spraying: bool,
    /// Links this shard owns — sizes the slab arena up front.
    owned_links: usize,
    classes: &'e [TrafficClass],
    /// Express dispatch enabled ([`MemSim::set_fusion`]); each worker
    /// applies the same peek gate against its own engine + epoch horizon.
    fuse: bool,
}

/// Run the sharded simulation. Callers have already verified the plan
/// (every reactive source carries a `pinned` shard).
pub(crate) fn run(
    sim: &mut MemSim,
    sources: &mut [&mut dyn TrafficSource],
    plan: &ShardPlan,
) -> StreamReport {
    let fabric: &Fabric = sim.fabric;
    let consts: &[LinkConsts] = &sim.consts;
    let tiers: &[LinkTier] = &sim.tiers;
    let spread = sim.spread;
    let granularity = sim.granularity;
    let k = plan.nshards;
    let nsrc = sources.len();
    let classes: Vec<TrafficClass> = sources.iter().map(|s| s.class()).collect();
    // multi-rail resolution at injection: spray for any spreading
    // policy; under Adaptive the choice is steered by the barrier
    // -piggybacked backlog digests instead of the hash (see module docs)
    let rail_fan = fabric.router().max_rails();
    let resolution = sim.routing_policy().resolution();
    let spraying = rail_fan > 1 && spread != [false; LinkTier::COUNT] && resolution.spreads();
    let adaptive = spraying && resolution == RailSelector::Adaptive;
    let pinned_total = plan.pinned.iter().flatten().count();
    // spanning sources run on the coordinator under the optimistic
    // checkpoint/rollback protocol (see the module docs)
    let optimistic = plan.spanning.iter().any(|&s| s);
    let span_idx: Vec<usize> = (0..nsrc).filter(|&i| plan.spanning[i]).collect();

    // split the source slice: pinned sources move onto their owning
    // shard's worker, open-loop sources stay with the coordinator
    let mut pinned_lists: Vec<Vec<PinnedSrc<'_>>> = (0..k).map(|_| Vec::new()).collect();
    let mut coord_srcs: Vec<Option<&mut dyn TrafficSource>> = Vec::with_capacity(nsrc);
    for (i, s) in sources.iter_mut().enumerate() {
        match plan.pinned[i] {
            Some(shard) => {
                pinned_lists[shard as usize].push(PinnedSrc {
                    global: i as u32,
                    src: &mut **s,
                    staged: None,
                    state: PinState::Active,
                    inflight: 0,
                    emitted: 0,
                });
                coord_srcs.push(None);
            }
            None => coord_srcs.push(Some(&mut **s)),
        }
    }

    let mut owned_links = vec![0usize; k];
    for &s in &plan.link_shard {
        owned_links[s as usize] += 1;
    }

    let mut report = StreamReport::new();
    report.mode = ShardMode::Sharded { shards: k, pinned_sources: pinned_total };
    // flight recorder: each worker gets a shard-stamped sink (the span
    // budget splits across shards); the coordinator keeps the protocol
    // instants, which commit immediately and are never rolled back
    let trace_cfg = sim.trace_cfg;
    let mut trace_data: Option<TraceData> = trace_cfg.map(|_| TraceData::default());
    let mut trace_instants: Vec<InstantEvent> = Vec::new();
    let mut merged_servers = sim.servers.clone();
    let mut makespan = 0.0f64;
    let mut events = 0u64;
    let mut fused_hops = 0u64;
    let mut peak_inflight = 0usize;
    let mut epochs = 0u64;
    let mut barriers = 0u64;
    let mut checkpoints = 0u64;
    let mut rollbacks = 0u64;
    let mut shard_stats: Vec<ShardStats> = Vec::with_capacity(k);

    std::thread::scope(|scope| {
        let link_shard: &[u32] = &plan.link_shard;
        let classes_ref: &[TrafficClass] = &classes;
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(k);
        // one response channel per worker: a dead worker (panic on one of
        // its diagnostic paths) surfaces as a recv error on ITS channel
        // instead of deadlocking the coordinator behind the survivors'
        // still-open clones of a shared sender; shard-ordered collection
        // also makes mailbox fill order deterministic
        let mut res_rxs: Vec<mpsc::Receiver<Resp>> = Vec::with_capacity(k);
        for (shard, pinned) in pinned_lists.into_iter().enumerate() {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (res_tx, res_rx) = mpsc::channel::<Resp>();
            cmd_txs.push(cmd_tx);
            res_rxs.push(res_rx);
            let servers0 = sim.servers.clone();
            let ctx = WorkerCtx {
                shard,
                fabric,
                consts,
                tiers,
                spread,
                link_shard,
                granularity,
                rail_fan,
                spraying,
                owned_links: owned_links[shard],
                classes: classes_ref,
                fuse: sim.fuse,
            };
            let trace0 = trace_cfg.map(|cfg| {
                let cap = (cfg.capacity / k).max(1024).min(cfg.capacity);
                Box::new(TraceSink::new(&cfg, shard as u16, cap, tiers))
            });
            scope.spawn(move || worker(ctx, cmd_rx, res_tx, servers0, pinned, trace0));
        }

        // coordinator state: one staged transaction per open-loop source
        // plus the per-shard mailboxes carrying next-epoch deliveries
        let mut staged: Vec<Option<(f64, SourcedTx)>> = (0..nsrc).map(|_| None).collect();
        let mut src_done: Vec<bool> = plan.pinned.iter().map(|p| p.is_some()).collect();
        let mut last_issue = vec![0.0f64; nsrc];
        // per-source emission index: the spray hash's tx_seq, identical
        // to the serial loop's injection order
        let mut emitted = vec![0u64; nsrc];
        let mut inboxes: Vec<Vec<Handoff>> = (0..k).map(|_| Vec::new()).collect();
        let mut next_events = vec![f64::INFINITY; k];
        // recycled mailbox buffers: epochs reuse drained Vecs instead of
        // reallocating them
        let mut spare_inbox: Vec<Vec<Handoff>> = Vec::new();
        let mut spare_out: Vec<Vec<(u32, Handoff)>> = Vec::new();
        let mut spare_comp: Vec<Vec<Completion>> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();
        // optimistic state: per-spanning-source block flags and in-flight
        // counts, the speculative injection sets, the committed-inbox
        // snapshots and the barrier checkpoints (all idle when no source
        // spans); plus the adaptive-routing digest table
        let mut blocked = vec![false; nsrc];
        let mut inflight = vec![0usize; nsrc];
        let mut speculative: Vec<Vec<SpecTx>> = (0..nsrc).map(|_| Vec::new()).collect();
        let mut new_spec: Vec<Vec<SpecTx>> = (0..nsrc).map(|_| Vec::new()).collect();
        let mut span_ckpt: Vec<Option<SpanCkpt>> = (0..nsrc).map(|_| None).collect();
        let mut epoch_inbox: Vec<Vec<Handoff>> = (0..k).map(|_| Vec::new()).collect();
        let mut participated = vec![false; k];
        let mut pinged = vec![false; k];
        let mut held_out: Vec<Vec<(u32, Handoff)>> = Vec::new();
        let mut digests: Vec<(u32, [f64; 2])> = Vec::new();
        let mut digest_table: Vec<[f64; 2]> = vec![[0.0; 2]; fabric.topo.links.len()];
        let mut rail_scratch: Vec<u32> = Vec::new();

        // initial barrier: every worker pumps its pinned sources at t=0
        // and reports its earliest injection event, so a fully-pinned
        // workload (no staged coordinator traffic at all) still opens
        // the first window
        for rx in &res_rxs {
            match rx.recv().expect("shard worker alive") {
                Resp::Epoch { shard, out, completions: c, spent, next_event, digest } => {
                    debug_assert!(
                        out.is_empty() && c.is_empty() && spent.is_empty() && digest.is_empty()
                    );
                    next_events[shard] = next_event;
                }
                Resp::Final { .. } => unreachable!("Final before Finish"),
            }
        }

        loop {
            // keep every active coordinator source staged one ahead:
            // open sources via the serial clamp, spanning sources via
            // the optimistic pump (both pull at their last injection
            // time, which only committed completions can precede — so
            // this staging itself is never rolled back)
            for i in 0..nsrc {
                if plan.spanning[i] {
                    stage_span(
                        i,
                        last_issue[i],
                        &mut coord_srcs,
                        &mut staged,
                        &mut src_done,
                        &mut blocked,
                        &inflight,
                    );
                } else {
                    stage_next(
                        i, &mut coord_srcs, &mut staged, &mut src_done, &last_issue, &classes,
                    );
                }
            }
            let t_staged =
                staged.iter().flatten().map(|(at, _)| *at).fold(f64::INFINITY, f64::min);
            let t_inbox = inboxes
                .iter()
                .flat_map(|b| b.iter().map(|h| h.at))
                .fold(f64::INFINITY, f64::min);
            let t_engines = next_events.iter().copied().fold(f64::INFINITY, f64::min);
            let t0 = t_staged.min(t_inbox).min(t_engines);
            if !t0.is_finite() {
                break; // sources drained, mailboxes empty, engines idle
            }
            let mut t1 = t0 + plan.lookahead; // INFINITY lookahead: one epoch

            // optimistic gate: checkpoint only for windows where a
            // spanning source can act — an injection staged below t1, or
            // a transaction in flight whose completion could unblock a
            // pull inside the already-executed window. Everything else
            // runs as a plain conservative epoch, rollback machinery idle.
            let gate = optimistic
                && span_idx.iter().any(|&i| {
                    inflight[i] > 0 || staged[i].as_ref().map(|(at, _)| *at < t1).unwrap_or(false)
                });
            if gate {
                checkpoints += 1;
                for &i in &span_idx {
                    span_ckpt[i] = Some(SpanCkpt {
                        snap: coord_srcs[i]
                            .as_ref()
                            .expect("spanning source owned by coordinator")
                            .checkpoint()
                            .expect("plan verified checkpoint support"),
                        staged: staged[i].clone(),
                        blocked: blocked[i],
                        done: src_done[i],
                        inflight: inflight[i],
                        last_issue: last_issue[i],
                        emitted: emitted[i],
                    });
                }
            }
            let rc = RailChoice {
                fabric,
                tiers,
                spread,
                spraying,
                adaptive,
                rail_fan,
                digest: &digest_table,
            };

            // stage every injection below the window into its first-hop
            // shard's mailbox; the per-source cap bounds memory, shrinking
            // the window to the first unstaged issue time when it bites
            for i in 0..nsrc {
                if plan.spanning[i] {
                    continue; // staged below, speculatively
                }
                let mut staged_here = 0usize;
                loop {
                    stage_next(
                        i, &mut coord_srcs, &mut staged, &mut src_done, &last_issue, &classes,
                    );
                    if src_done[i] {
                        break;
                    }
                    let at = staged[i].as_ref().expect("staged above").0;
                    if at >= t1 {
                        break;
                    }
                    // soft cap: shrinking the window below `at` is only
                    // allowed while it stays strictly above t0, or the
                    // epoch could stall on a same-timestamp burst
                    if staged_here >= MAX_STAGE_PER_SOURCE && at > t0 {
                        t1 = t1.min(at); // keep the window conservative
                        break;
                    }
                    let (at, stx) = staged[i].take().expect("staged above");
                    last_issue[i] = at;
                    let tx = stx.tx;
                    let seq = emitted[i];
                    emitted[i] += 1;
                    // flow-keyed when the source stamped one: same hash
                    // input as the serial injection path
                    let spray_key = stx.flow.unwrap_or(seq);
                    let rail = rc.pick(tx.src, tx.dst, spray_key, &mut rail_scratch);
                    // the first hop is rail-dependent: different rails may
                    // enter the fabric through links owned by different shards
                    let target = if tx.src == tx.dst {
                        plan.node_shard[tx.src] as usize
                    } else {
                        match rail_step(fabric, tiers, spread, tx.src, tx.dst, rail) {
                            Some((_, link)) => plan.link_shard[link] as usize,
                            None => panic!(
                                "no path {} ({}) -> {} ({}) for traffic source {} (class {})",
                                tx.src,
                                fabric.topo.node(tx.src).label,
                                tx.dst,
                                fabric.topo.node(tx.dst).label,
                                i,
                                classes[i].name()
                            ),
                        }
                    };
                    inboxes[target].push(Handoff {
                        at,
                        hop: 0,
                        tx: ShardTx {
                            issued: at,
                            bytes: tx.bytes,
                            device_ns: tx.device_ns,
                            src: tx.src as u32,
                            dst: tx.dst as u32,
                            source: i as u32,
                            class: classes[i],
                            token: stx.token,
                            rail,
                        },
                    });
                    staged_here += 1;
                }
            }

            // capture the window's committed deliveries before the
            // speculative spanning injections go in: replay attempts
            // rebuild each inbox as this snapshot plus the (replaced)
            // speculative set, in the same order
            if gate {
                for (snap, inbox) in epoch_inbox.iter_mut().zip(&inboxes) {
                    snap.clear();
                    snap.extend_from_slice(inbox);
                }
            }
            // stage spanning injections below the window: each is
            // recorded as speculative and delivered like an ordinary
            // hop-0 handoff. No MAX_STAGE cap here — a spanning source
            // keeps the lookahead finite, so the window bounds the burst
            // exactly as the serial loop's own flow control does.
            for &i in &span_idx {
                loop {
                    stage_span(
                        i,
                        last_issue[i],
                        &mut coord_srcs,
                        &mut staged,
                        &mut src_done,
                        &mut blocked,
                        &inflight,
                    );
                    let Some(at) = staged[i].as_ref().map(|(at, _)| *at) else { break };
                    if at >= t1 {
                        break;
                    }
                    let (at, stx) = staged[i].take().expect("staged above");
                    last_issue[i] = at;
                    let st = speculate_span(
                        i, at, &stx, plan, &classes, &rc, &mut rail_scratch, &mut emitted,
                        &mut inflight,
                    );
                    inboxes[st.target as usize].push(Handoff { at: st.at, hop: 0, tx: st.tx });
                    speculative[i].push(st);
                }
            }

            epochs += 1;
            participated.fill(false);
            // the epoch-start digest every participating worker steers by
            // this window (one Arc shared across replay attempts, so
            // every attempt scores rails against identical state)
            let epoch_digest: Option<Arc<Vec<[f64; 2]>>> =
                if adaptive { Some(Arc::new(digest_table.clone())) } else { None };
            let mut attempts = 0usize;
            loop {
                attempts += 1;
                // recycle the previous attempt's held outputs: a rolled
                // -back attempt's handoffs are dropped (their producers
                // re-execute), never routed
                for mut o in held_out.drain(..) {
                    o.clear();
                    spare_out.push(o);
                }
                // wake shards with deliveries or events inside the
                // window; once a shard participates in an optimistic
                // window it is re-pinged (rollback + replay) on every
                // further attempt, so its committed state and next-event
                // report always come from the final attempt
                pinged.fill(false);
                for s in 0..k {
                    if !participated[s] && inboxes[s].is_empty() && next_events[s] >= t1 {
                        continue;
                    }
                    let inbox = std::mem::replace(
                        &mut inboxes[s],
                        spare_inbox.pop().unwrap_or_default(),
                    );
                    next_events[s] = f64::INFINITY; // refreshed by the response
                    let ckpt = gate && !participated[s];
                    let replay = participated[s];
                    cmd_txs[s]
                        .send(Cmd::Epoch {
                            t1,
                            inbox,
                            out: spare_out.pop().unwrap_or_default(),
                            completions: spare_comp.pop().unwrap_or_default(),
                            checkpoint: ckpt,
                            rollback: replay,
                            digest: epoch_digest.clone(),
                        })
                        .expect("shard worker alive");
                    // the protocol's own trace: an epoch mark per ping plus
                    // the checkpoint / rollback marks the flags imply
                    if trace_cfg.is_some() && trace_instants.len() + 3 <= MAX_COORD_INSTANTS {
                        let sh = s as u16;
                        trace_instants.push(InstantEvent {
                            at: t0,
                            kind: InstantKind::Epoch,
                            shard: sh,
                        });
                        if ckpt {
                            trace_instants.push(InstantEvent {
                                at: t0,
                                kind: InstantKind::Checkpoint,
                                shard: sh,
                            });
                        }
                        if replay {
                            trace_instants.push(InstantEvent {
                                at: t0,
                                kind: InstantKind::Rollback,
                                shard: sh,
                            });
                        }
                    }
                    pinged[s] = true;
                    participated[s] = true;
                    barriers += 1;
                }
                assert!(
                    pinged.iter().any(|&p| p),
                    "conservative window made no progress (t0={t0}, t1={t1})"
                );

                completions.clear();
                digests.clear();
                for s in (0..k).filter(|&s| pinged[s]) {
                    match res_rxs[s].recv().expect("shard worker alive") {
                        Resp::Epoch { shard, out, completions: mut c, spent, next_event, digest } => {
                            debug_assert_eq!(shard, s);
                            next_events[shard] = next_event;
                            // a pinned-only run has no conservative bound at
                            // all — the plan proved no handoff can exist
                            assert!(
                                plan.lookahead.is_finite() || out.is_empty(),
                                "cross-shard handoff under infinite lookahead"
                            );
                            held_out.push(out);
                            completions.append(&mut c);
                            spare_comp.push(c);
                            spare_inbox.push(spent);
                            digests.extend(digest);
                        }
                        Resp::Final { .. } => unreachable!("Final before Finish"),
                    }
                }
                if !gate {
                    break; // plain conservative epoch: commit directly
                }

                // ----- validate: rewind the spanning sources to the
                // barrier and replay their decision procedure against the
                // completions this attempt actually produced
                completions.sort_by(|a, b| {
                    a.at
                        .total_cmp(&b.at)
                        .then_with(|| a.source.cmp(&b.source))
                        .then_with(|| a.token.cmp(&b.token))
                });
                for &i in &span_idx {
                    let ck = span_ckpt[i].as_ref().expect("gated window checkpointed");
                    coord_srcs[i]
                        .as_mut()
                        .expect("spanning source owned by coordinator")
                        .restore(ck.snap.as_ref());
                    staged[i].clone_from(&ck.staged);
                    blocked[i] = ck.blocked;
                    src_done[i] = ck.done;
                    inflight[i] = ck.inflight;
                    last_issue[i] = ck.last_issue;
                    emitted[i] = ck.emitted;
                    new_spec[i].clear();
                }
                let mut ci = 0usize;
                loop {
                    // earliest staged spanning injection below t1 (ties
                    // to the lowest source index) ...
                    let mut inj: Option<(usize, f64)> = None;
                    for &i in &span_idx {
                        if let Some((at, _)) = &staged[i] {
                            let at = *at;
                            let best = inj.map(|(_, b)| b).unwrap_or(f64::INFINITY);
                            if at < t1 && at < best {
                                inj = Some((i, at));
                            }
                        }
                    }
                    // ... merged against the next spanning completion
                    while ci < completions.len()
                        && !plan.spanning[completions[ci].source as usize]
                    {
                        ci += 1;
                    }
                    let comp = completions.get(ci);
                    let take_inj = match (inj, comp) {
                        (None, None) => break,
                        (Some(_), None) => true,
                        (None, Some(_)) => false,
                        // completion first on ties: the serial engine
                        // dispatches the Complete before the same-instant
                        // injection the pump stages in response
                        (Some((_, at)), Some(c)) => at < c.at,
                    };
                    if take_inj {
                        let (i, _) = inj.expect("injection selected");
                        let (at, stx) = staged[i].take().expect("selected above");
                        last_issue[i] = at;
                        let st = speculate_span(
                            i, at, &stx, plan, &classes, &rc, &mut rail_scratch, &mut emitted,
                            &mut inflight,
                        );
                        new_spec[i].push(st);
                        stage_span(
                            i, at, &mut coord_srcs, &mut staged, &mut src_done, &mut blocked,
                            &inflight,
                        );
                    } else {
                        let c = &completions[ci];
                        let (i, at, token) = (c.source as usize, c.at, c.token);
                        ci += 1;
                        inflight[i] -= 1;
                        coord_srcs[i]
                            .as_mut()
                            .expect("spanning source owned by coordinator")
                            .on_complete(token, at);
                        blocked[i] = false;
                        stage_span(
                            i, at, &mut coord_srcs, &mut staged, &mut src_done, &mut blocked,
                            &inflight,
                        );
                    }
                }
                if span_idx.iter().all(|&i| speculative[i] == new_spec[i]) {
                    break; // fixpoint: the attempt saw exactly these injections
                }
                // diverged: REPLACE the speculative set with the replay's
                // (merging would resurrect dead injections and never
                // converge), roll every participant back and re-execute
                rollbacks += 1;
                assert!(
                    attempts < MAX_REPLAY_ATTEMPTS,
                    "optimistic replay failed to converge after {attempts} attempts \
                     (t0={t0}, t1={t1})"
                );
                for &i in &span_idx {
                    std::mem::swap(&mut speculative[i], &mut new_spec[i]);
                }
                // rebuild every inbox canonically: committed deliveries
                // first, then speculative injections source-major — the
                // exact construction the first attempt used, so a
                // converged replay is bit-identical to a clean run
                for (inbox, snap) in inboxes.iter_mut().zip(&epoch_inbox) {
                    debug_assert!(inbox.is_empty(), "undelivered inbox at replay");
                    inbox.extend_from_slice(snap);
                }
                for &i in &span_idx {
                    for st in &speculative[i] {
                        inboxes[st.target as usize].push(Handoff {
                            at: st.at,
                            hop: 0,
                            tx: st.tx,
                        });
                    }
                }
            }

            // ----- commit: route the final attempt's handoffs, fold the
            // digests into the adaptive table, stream the completions
            for mut o in held_out.drain(..) {
                for (target, h) in o.drain(..) {
                    inboxes[target as usize].push(h);
                }
                spare_out.push(o);
            }
            if adaptive {
                for &(link, d) in &digests {
                    digest_table[link as usize] = d;
                }
            }
            if gate {
                for &i in &span_idx {
                    speculative[i].clear();
                }
            }
            // merge the barrier's completions in global time order so the
            // report streams identically to the serial loop (ties broken
            // by (source, token), which can only collide inside one
            // shard's already-ordered stream)
            completions.sort_by(|a, b| {
                a.at
                    .total_cmp(&b.at)
                    .then_with(|| a.source.cmp(&b.source))
                    .then_with(|| a.token.cmp(&b.token))
            });
            for c in completions.drain(..) {
                report.record(classes[c.source as usize], c.latency, c.bytes);
                // pinned sources already saw on_complete inside their
                // worker, spanning sources inside the validation replay —
                // only open-loop sources are notified here
                let i = c.source as usize;
                if plan.pinned[i].is_none() && !plan.spanning[i] {
                    coord_srcs[i]
                        .as_mut()
                        .expect("open-loop source owned by coordinator")
                        .on_complete(c.token, c.at);
                }
            }
        }

        for tx in &cmd_txs {
            tx.send(Cmd::Finish).expect("shard worker alive");
        }
        for (s, rx) in res_rxs.iter().enumerate() {
            match rx.recv().expect("shard worker alive") {
                Resp::Final { shard, servers, now, dispatched, fused, peak_slots, idle_s, trace } => {
                    debug_assert_eq!(shard, s);
                    makespan = makespan.max(now);
                    events += dispatched + fused;
                    fused_hops += fused;
                    // the sum of per-shard slot high-waters: the slot
                    // memory actually allocated, an upper bound on the
                    // serial definition (true peak concurrency) since the
                    // shards peak at different times and a multi-shard
                    // path occupies one slot per visited shard
                    peak_inflight += peak_slots;
                    shard_stats.push(ShardStats {
                        shard,
                        events: dispatched + fused,
                        pinned_sources: plan
                            .pinned
                            .iter()
                            .flatten()
                            .filter(|&&p| p as usize == shard)
                            .count(),
                        idle_s,
                    });
                    for (li, srv) in servers.into_iter().enumerate() {
                        if plan.link_shard[li] as usize == shard {
                            merged_servers[li] = srv;
                        }
                    }
                    // shard-ordered collection makes the merged span order
                    // deterministic (shard-major, push order within)
                    if let (Some(td), Some(tr)) = (trace_data.as_mut(), trace) {
                        td.merge(tr.into_data());
                    }
                }
                Resp::Epoch { .. } => unreachable!("Epoch after Finish"),
            }
        }
    });

    sim.servers = merged_servers;
    report.total.makespan_ns = makespan;
    // same count as the serial streamed loop: its per-transaction
    // injection event is the sharded loop's hop-0 arrival event (and a
    // pinned source's injection is a Custom event on its worker);
    // fused hops count as the events they replaced, exactly as serial
    report.total.events = events;
    report.fused_hops = fused_hops;
    report.peak_inflight = peak_inflight;
    report.epochs = epochs;
    report.barriers = barriers;
    report.optimistic_sources = plan.spanning.iter().filter(|&&s| s).count();
    report.checkpoints = checkpoints;
    report.rollbacks = rollbacks;
    shard_stats.sort_by_key(|s| s.shard);
    report.shards = shard_stats;
    report.qos = sim.collect_qos_stats();
    if let Some(mut data) = trace_data {
        data.instants.extend(trace_instants);
        report.dropped_spans = data.dropped_spans;
        report.trace_overhead_ns = data.overhead_ns;
        sim.trace_out = Some(data);
    }
    report
}

/// Pull pinned source `li` once (if active and unstaged) and schedule
/// its injection as a `Custom { tag: li }` event — the exact serial pump,
/// run inside the owning worker.
fn pump_pinned(li: usize, now: f64, pinned: &mut [PinnedSrc<'_>], engine: &mut Engine) {
    let p = &mut pinned[li];
    if p.state != PinState::Active || p.staged.is_some() {
        return;
    }
    match p.src.pull(now) {
        Pull::Tx(stx) => {
            let at = stx.tx.at.max(now);
            engine.schedule(at, EventKind::Custom { tag: li as u64 });
            p.staged = Some(stx);
        }
        Pull::Blocked => {
            assert!(
                p.inflight > 0,
                "pinned traffic source {} blocked with nothing in flight (deadlock)",
                p.global
            );
            p.state = PinState::Blocked;
        }
        Pull::Done => p.state = PinState::Done,
    }
}

/// A worker's epoch-barrier checkpoint: everything the shard mutates
/// while executing a window. The path arena and intern cache are
/// deliberately absent — both are append-only, so restored slots' path
/// indices stay valid and a replayed transaction re-interns as a cache
/// hit.
struct WorkerCkpt {
    engine: EngineSnapshot,
    servers: Vec<[ClassedServer; 2]>,
    slots: Vec<LocalTx>,
    free: Vec<u32>,
    pinned: Vec<PinnedCkpt>,
    /// Express-dispatch counter at the barrier: a rolled-back attempt's
    /// fused hops are not real work, so the tally rewinds with the state.
    fused: u64,
    /// Flight-recorder snapshot: a rolled-back attempt's span records roll
    /// back with the state that produced them.
    trace: Option<Box<TraceSink>>,
}

/// Barrier snapshot of one pinned source (mirrors [`SpanCkpt`] for the
/// worker-owned pump state).
struct PinnedCkpt {
    snap: Box<dyn std::any::Any + Send>,
    staged: Option<SourcedTx>,
    state: PinState,
    inflight: usize,
    emitted: u64,
}

/// One shard: a calendar engine over the shard's link servers and its
/// pinned reactive sources, draining events strictly below each epoch's
/// `t1` and emitting cross-shard handoffs for the barrier.
fn worker(
    ctx: WorkerCtx<'_>,
    cmds: mpsc::Receiver<Cmd>,
    res: mpsc::Sender<Resp>,
    mut servers: Vec<[ClassedServer; 2]>,
    mut pinned: Vec<PinnedSrc<'_>>,
    mut trace: Option<Box<TraceSink>>,
) {
    // slab arena sized from the shard's link count: the calendar queue
    // and slot table for a shard serving L links rarely need more than a
    // few transactions per link direction in flight at once
    let cap = (ctx.owned_links * 8 + 64).min(1 << 16);
    let mut engine = Engine::with_granularity_and_capacity(ctx.granularity, cap);
    let mut slots: Vec<LocalTx> = Vec::with_capacity(cap);
    let mut free: Vec<u32> = Vec::with_capacity(cap / 4);
    // shard-local path interning (same arena layout as the serial path;
    // a path crossing three shards is interned by each of the three)
    let mut arena: Vec<u32> = Vec::new();
    let mut cache: HashMap<u64, (u32, u32)> = HashMap::new();
    // global source index -> local pinned index (completions carry the
    // global id; only locally pinned sources get the reactive unblock)
    let mut pin_of: Vec<Option<u32>> = vec![None; ctx.classes.len()];
    for (li, p) in pinned.iter().enumerate() {
        pin_of[p.global as usize] = Some(li as u32);
    }
    // epoch-batching scratch (ported from the serial loop §Perf):
    // consecutive same-timestamp arrivals on one link direction admit as
    // one batch, amortizing the per-admission ClassedServer bookkeeping
    let mut carried: Option<(f64, EventKind)> = None;
    let mut batch_ids: Vec<(usize, usize)> = Vec::new();
    let mut batch_items: Vec<BatchAdmit> = Vec::new();
    let mut admissions: Vec<Admission> = Vec::new();
    let mut idle = 0.0f64;
    // hops admitted inline by express chains — logical events the engine
    // never dispatched; joins `dispatched` in the final event count
    let mut fused = 0u64;
    // optimistic support: the barrier checkpoint a rollback restores, and
    // the adaptive rail-scoring scratch (both idle on conservative runs)
    let mut ckpt: Option<WorkerCkpt> = None;
    let mut rail_scratch: Vec<u32> = Vec::new();

    // initial barrier: pump every pinned source at t=0 and report the
    // earliest injection, so the coordinator's first window sees pinned
    // traffic even when nothing is staged on the coordinator itself
    for li in 0..pinned.len() {
        pump_pinned(li, 0.0, &mut pinned, &mut engine);
    }
    if res
        .send(Resp::Epoch {
            shard: ctx.shard,
            out: Vec::new(),
            completions: Vec::new(),
            spent: Vec::new(),
            next_event: engine.peek_time().unwrap_or(f64::INFINITY),
            digest: Vec::new(),
        })
        .is_err()
    {
        return; // coordinator gone (panic unwinding)
    }

    loop {
        let wait = Instant::now();
        let Ok(cmd) = cmds.recv() else { return };
        idle += wait.elapsed().as_secs_f64();
        match cmd {
            Cmd::Epoch { t1, mut inbox, mut out, mut completions, checkpoint, rollback, digest } => {
                if rollback {
                    // replay attempt: rewind to the barrier. The engine
                    // restore drops the previous attempt's inbox events,
                    // so the coordinator resends the full rebuilt inbox.
                    let ck = ckpt.as_ref().expect("rollback without a checkpoint");
                    engine.restore(&ck.engine);
                    servers.clone_from(&ck.servers);
                    slots.clone_from(&ck.slots);
                    free.clone_from(&ck.free);
                    for (p, pc) in pinned.iter_mut().zip(&ck.pinned) {
                        p.src.restore(pc.snap.as_ref());
                        p.staged.clone_from(&pc.staged);
                        p.state = pc.state;
                        p.inflight = pc.inflight;
                        p.emitted = pc.emitted;
                    }
                    fused = ck.fused;
                    trace.clone_from(&ck.trace);
                } else if checkpoint {
                    ckpt = Some(WorkerCkpt {
                        engine: engine.snapshot(),
                        servers: servers.clone(),
                        slots: slots.clone(),
                        free: free.clone(),
                        pinned: pinned
                            .iter()
                            .map(|p| PinnedCkpt {
                                snap: p
                                    .src
                                    .checkpoint()
                                    .expect("plan verified checkpoint support"),
                                staged: p.staged.clone(),
                                state: p.state,
                                inflight: p.inflight,
                                emitted: p.emitted,
                            })
                            .collect(),
                        fused,
                        trace: trace.clone(),
                    });
                }
                let dslice: &[[f64; 2]] = match digest.as_deref() {
                    Some(d) => d,
                    None => &[],
                };
                let rc = RailChoice {
                    fabric: ctx.fabric,
                    tiers: ctx.tiers,
                    spread: ctx.spread,
                    spraying: ctx.spraying,
                    adaptive: digest.is_some(),
                    rail_fan: ctx.rail_fan,
                    digest: dslice,
                };
                for h in inbox.drain(..) {
                    let (path_start, path_len) =
                        intern_local(ctx.fabric, ctx.tiers, ctx.spread, &mut arena, &mut cache, &h.tx);
                    let entry = LocalTx { tx: h.tx, path_start, path_len };
                    let id = match free.pop() {
                        Some(s) => {
                            slots[s as usize] = entry;
                            s as usize
                        }
                        None => {
                            slots.push(entry);
                            slots.len() - 1
                        }
                    };
                    if let Some(tr) = trace.as_deref_mut() {
                        // an injection delivery opens the span chain; a
                        // mid-path handoff only re-registers slot context
                        let tx = &slots[id].tx;
                        if h.hop == 0 {
                            tr.inject(
                                id, h.at, tx.src as usize, tx.dst as usize, tx.bytes, tx.rail,
                                tx.class, tx.source as usize, tx.token,
                            );
                        } else {
                            tr.adopt(id, tx.bytes, tx.rail, tx.class, tx.source as usize, tx.token);
                        }
                    }
                    engine.schedule(h.at, EventKind::Arrive { id, hop: h.hop as usize });
                }
                loop {
                    let Some((now, ev)) = carried.take().or_else(|| match engine.peek_time() {
                        Some(t) if t < t1 => engine.next(),
                        _ => None,
                    }) else {
                        break;
                    };
                    if let Some(tr) = trace.as_deref_mut() {
                        if tr.gauge_due(now) {
                            let sweep = Instant::now();
                            let mut busy = [0.0f64; LinkTier::COUNT];
                            let mut depth = [0u32; LinkTier::COUNT];
                            for (li, pair) in servers.iter().enumerate() {
                                if ctx.link_shard[li] as usize != ctx.shard {
                                    continue;
                                }
                                let ti = tr.tier_of(li);
                                for srv in pair {
                                    busy[ti] += srv.busy_ns();
                                    depth[ti] += srv.backlog() as u32;
                                }
                            }
                            tr.gauge(GaugeSample {
                                at: now,
                                shard: ctx.shard as u16,
                                tier_busy_ns: busy,
                                tier_queued: depth,
                                inflight: (slots.len() - free.len()) as u32,
                            });
                            tr.add_overhead(sweep.elapsed().as_nanos() as f64);
                        }
                    }
                    match ev {
                        // injection: a pinned source's staged transaction
                        // reaches its issue time — the serial Custom arm,
                        // run shard-locally (rail resolution, interning,
                        // inline hop-0 admission, re-pump)
                        EventKind::Custom { tag } => {
                            let li = tag as usize;
                            let stx =
                                pinned[li].staged.take().expect("staged pinned injection");
                            let tx = stx.tx;
                            let seq = pinned[li].emitted;
                            pinned[li].emitted += 1;
                            let rail =
                                rc.pick(tx.src, tx.dst, stx.flow.unwrap_or(seq), &mut rail_scratch);
                            let global = pinned[li].global;
                            let stx_tx = ShardTx {
                                issued: now,
                                bytes: tx.bytes,
                                device_ns: tx.device_ns,
                                src: tx.src as u32,
                                dst: tx.dst as u32,
                                source: global,
                                class: ctx.classes[global as usize],
                                token: stx.token,
                                rail,
                            };
                            let (path_start, path_len) = intern_local(
                                ctx.fabric, ctx.tiers, ctx.spread, &mut arena, &mut cache,
                                &stx_tx,
                            );
                            let entry = LocalTx { tx: stx_tx, path_start, path_len };
                            let id = match free.pop() {
                                Some(s) => {
                                    slots[s as usize] = entry;
                                    s as usize
                                }
                                None => {
                                    slots.push(entry);
                                    slots.len() - 1
                                }
                            };
                            if let Some(tr) = trace.as_deref_mut() {
                                tr.inject(
                                    id, now, tx.src, tx.dst, tx.bytes, stx_tx.rail, stx_tx.class,
                                    global as usize, stx_tx.token,
                                );
                            }
                            pinned[li].inflight += 1;
                            // no fusion off an injection: the source is
                            // re-pumped only after this admission, so its
                            // next staged event is invisible to the peek
                            // gate — bound -inf forces the per-hop path
                            fused += admit_one(
                                &mut engine, &mut out, &mut free, &arena, &ctx, &mut servers,
                                &slots, id, 0, now, f64::NEG_INFINITY, &mut trace,
                            );
                            pump_pinned(li, now, &mut pinned, &mut engine);
                        }
                        EventKind::Arrive { id, hop } => {
                            let fl = &slots[id];
                            if hop >= fl.path_len as usize {
                                // reached destination: pay device service
                                engine.after(fl.tx.device_ns, EventKind::Complete { id });
                                continue;
                            }
                            // epoch batching: coalesce the consecutive
                            // arrivals at exactly `now` that land on the
                            // same link direction (the serial loop's
                            // admit_batch optimization, now worker-side)
                            let h = arena[fl.path_start as usize + hop];
                            batch_ids.clear();
                            batch_ids.push((id, hop));
                            while engine.peek_time() == Some(now) {
                                let (t2, ev2) = engine.next().expect("peeked event");
                                if let EventKind::Arrive { id: id2, hop: hop2 } = ev2 {
                                    let fl2 = &slots[id2];
                                    if hop2 < fl2.path_len as usize
                                        && arena[fl2.path_start as usize + hop2] == h
                                    {
                                        batch_ids.push((id2, hop2));
                                        continue;
                                    }
                                }
                                // not a batch member: defer to the next
                                // iteration (popped after the batch, so
                                // flushing the batch first preserves the
                                // serial handler order; its timestamp is
                                // `now < t1`, so it stays in this epoch)
                                carried = Some((t2, ev2));
                                break;
                            }
                            let link = (h >> 1) as usize;
                            let dir = (h & 1) as usize;
                            debug_assert_eq!(
                                ctx.link_shard[link] as usize, ctx.shard,
                                "event for a foreign link reached shard {}",
                                ctx.shard
                            );
                            let c = ctx.consts[link];
                            batch_items.clear();
                            for &(bid, bhop) in &batch_ids {
                                let fl = &slots[bid];
                                batch_items.push(BatchAdmit {
                                    service: c.flit.wire_bytes(fl.tx.bytes) * c.inv_rate,
                                    bytes: fl.tx.bytes,
                                    class: fl.tx.class,
                                    id: bid as u32,
                                    hop: bhop as u32,
                                });
                            }
                            admissions.clear();
                            servers[link][dir].admit_batch(now, &batch_items, &mut admissions);
                            // express dispatch: only the batch's last member
                            // may fuse, and only when no probe was carried —
                            // earlier members' continuations (and a carried
                            // same-time event) are pending work the peek
                            // gate cannot see. The fusion bound is the epoch
                            // horizon `t1`, composing with the conservative
                            // window exactly like a dispatched event.
                            let last = admissions.len() - 1;
                            for (bk, (adm, &(bid, bhop))) in
                                admissions.iter().zip(&batch_ids).enumerate()
                            {
                                let bound = if bk == last && carried.is_none() {
                                    t1
                                } else {
                                    f64::NEG_INFINITY
                                };
                                match *adm {
                                    Admission::Release { done } => {
                                        if let Some(tr) = trace.as_deref_mut() {
                                            // both admission flavors serve
                                            // over [done - service, done]
                                            tr.hop(
                                                bid, now, done - batch_items[bk].service, done,
                                                link, dir,
                                            );
                                        }
                                        fused += forward(
                                            &mut engine, &mut out, &mut free, &arena, &ctx,
                                            &mut servers, &slots, bid, link, dir, bhop, done,
                                            bound, &mut trace,
                                        );
                                    }
                                    Admission::Start { done } => {
                                        if let Some(tr) = trace.as_deref_mut() {
                                            tr.hop(
                                                bid, now, done - batch_items[bk].service, done,
                                                link, dir,
                                            );
                                        }
                                        engine.schedule(
                                            done,
                                            EventKind::Depart {
                                                link: link as u32,
                                                dir: dir as u8,
                                            },
                                        );
                                        fused += forward(
                                            &mut engine, &mut out, &mut free, &arena, &ctx,
                                            &mut servers, &slots, bid, link, dir, bhop, done,
                                            bound, &mut trace,
                                        );
                                    }
                                    Admission::Queued => {
                                        if let Some(tr) = trace.as_deref_mut() {
                                            tr.queued(bid, now);
                                        }
                                    }
                                }
                            }
                        }
                        // a queued-mode link freed: arbitrate, start the
                        // next VC's head, keep the depart chain alive
                        EventKind::Depart { link, dir } => {
                            let (li, di) = (link as usize, dir as usize);
                            if let Some((id, hop, done)) = servers[li][di].depart(now) {
                                if let Some(tr) = trace.as_deref_mut() {
                                    tr.departed(id as usize, now, done, li, di);
                                }
                                engine.schedule(done, EventKind::Depart { link, dir });
                                fused += forward(
                                    &mut engine, &mut out, &mut free, &arena, &ctx, &mut servers,
                                    &slots, id as usize, li, di, hop as usize, done, t1,
                                    &mut trace,
                                );
                            }
                        }
                        EventKind::Complete { id } => {
                            let lt = &slots[id];
                            if let Some(tr) = trace.as_deref_mut() {
                                tr.complete(id, now, now - lt.tx.issued);
                            }
                            completions.push(Completion {
                                at: now,
                                latency: now - lt.tx.issued,
                                bytes: lt.tx.bytes,
                                source: lt.tx.source,
                                token: lt.tx.token,
                            });
                            let source = lt.tx.source as usize;
                            let token = lt.tx.token;
                            free.push(id as u32);
                            // a pinned source completes shard-locally: the
                            // serial Complete arm (on_complete, unblock,
                            // re-pump) runs here at the dispatch instant,
                            // preserving zero-delay reactive chains
                            if let Some(li) = pin_of[source] {
                                let li = li as usize;
                                pinned[li].inflight -= 1;
                                pinned[li].src.on_complete(token, now);
                                if pinned[li].state == PinState::Blocked {
                                    pinned[li].state = PinState::Active;
                                }
                                pump_pinned(li, now, &mut pinned, &mut engine);
                            }
                        }
                    }
                }
                debug_assert!(carried.is_none(), "batch probe leaked across the epoch barrier");
                let next_event = engine.peek_time().unwrap_or(f64::INFINITY);
                // adaptive runs piggyback each owned link's backlog on the
                // barrier: both directions' pending_ns sampled at the
                // window edge (the instant next epoch's injections steer
                // from)
                let digest_out: Vec<(u32, [f64; 2])> = if digest.is_some() {
                    let at = if t1.is_finite() { t1 } else { engine.now() };
                    servers
                        .iter()
                        .enumerate()
                        .filter(|&(li, _)| ctx.link_shard[li] as usize == ctx.shard)
                        .map(|(li, pair)| {
                            (li as u32, [pair[0].pending_ns(at), pair[1].pending_ns(at)])
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                if res
                    .send(Resp::Epoch {
                        shard: ctx.shard,
                        out,
                        completions,
                        spent: inbox,
                        next_event,
                        digest: digest_out,
                    })
                    .is_err()
                {
                    return; // coordinator gone (panic unwinding)
                }
            }
            Cmd::Finish => {
                debug_assert!(
                    pinned.iter().all(|p| p.inflight == 0 && p.staged.is_none()),
                    "pinned source still live at Finish"
                );
                let _ = res.send(Resp::Final {
                    shard: ctx.shard,
                    servers,
                    now: engine.now(),
                    dispatched: engine.dispatched(),
                    fused,
                    peak_slots: slots.len(),
                    idle_s: idle,
                    trace,
                });
                return;
            }
        }
    }
}

/// Admit transaction `id` at `hop` on its path — the single-admission
/// mirror of `MemSim::step`, used for a pinned source's inline hop-0
/// admission (the batched Arrive arm covers everything else). Shares
/// [`forward`]'s cross-shard branch, though a pinned transaction's path
/// is shard-local by plan construction.
#[allow(clippy::too_many_arguments)]
fn admit_one(
    engine: &mut Engine,
    out: &mut Vec<(u32, Handoff)>,
    free: &mut Vec<u32>,
    arena: &[u32],
    ctx: &WorkerCtx<'_>,
    servers: &mut [[ClassedServer; 2]],
    slots: &[LocalTx],
    id: usize,
    hop: usize,
    now: f64,
    bound: f64,
    trace: &mut Option<Box<TraceSink>>,
) -> u64 {
    let lt = &slots[id];
    if hop >= lt.path_len as usize {
        engine.after(lt.tx.device_ns, EventKind::Complete { id });
        return 0;
    }
    let h = arena[lt.path_start as usize + hop];
    let link = (h >> 1) as usize;
    let dir = (h & 1) as usize;
    debug_assert_eq!(
        ctx.link_shard[link] as usize, ctx.shard,
        "pinned injection on a foreign link in shard {}",
        ctx.shard
    );
    let c = &ctx.consts[link];
    let service = c.flit.wire_bytes(lt.tx.bytes) * c.inv_rate;
    match servers[link][dir].admit(now, service, lt.tx.bytes, lt.tx.class, id as u32, hop as u32) {
        Admission::Release { done } => {
            if let Some(tr) = trace.as_deref_mut() {
                tr.hop(id, now, done - service, done, link, dir);
            }
            forward(engine, out, free, arena, ctx, servers, slots, id, link, dir, hop, done, bound, trace)
        }
        Admission::Start { done } => {
            if let Some(tr) = trace.as_deref_mut() {
                tr.hop(id, now, done - service, done, link, dir);
            }
            engine.schedule(done, EventKind::Depart { link: link as u32, dir: dir as u8 });
            forward(engine, out, free, arena, ctx, servers, slots, id, link, dir, hop, done, bound, trace)
        }
        Admission::Queued => {
            if let Some(tr) = trace.as_deref_mut() {
                tr.queued(id, now);
            }
            0
        }
    }
}

/// After a service on `(served_link, dir)` completes at `done`: put
/// transaction `id` onto its next hop — a cross-shard handoff when the
/// next link belongs to another shard (freeing the local slot), a local
/// Arrive event — or, under the express-dispatch gate, an *inline*
/// admission at the true arrival time that keeps chaining (the worker
/// twin of `MemSim::forward_local`; returns the hops fused). Shared by
/// the admit and depart paths; a handoff's arrival time is
/// `done + fixed + switch >= now + L`, so the conservative-lookahead
/// argument is unchanged under queued arbitration — and unchanged by
/// fusion, which only commits events the worker would have dispatched
/// inside this window anyway (`bound` is the epoch horizon `t1`, so a
/// fused arrival satisfies `t_next < t1` exactly like a dispatched one;
/// a foreign next link always exits through the handoff branch).
#[allow(clippy::too_many_arguments)]
fn forward(
    engine: &mut Engine,
    out: &mut Vec<(u32, Handoff)>,
    free: &mut Vec<u32>,
    arena: &[u32],
    ctx: &WorkerCtx<'_>,
    servers: &mut [[ClassedServer; 2]],
    slots: &[LocalTx],
    id: usize,
    served_link: usize,
    dir: usize,
    hop: usize,
    done: f64,
    bound: f64,
    trace: &mut Option<Box<TraceSink>>,
) -> u64 {
    let lt = &slots[id];
    let (mut hop, mut li, mut di, mut done) = (hop, served_link, dir, done);
    let mut fused = 0u64;
    loop {
        let c = &ctx.consts[li];
        // association order matches the serial hot path (`done + fixed +
        // sw`) so results stay byte-identical across backends
        let t_next = done + c.fixed_ns + c.switch_ns[1 - di];
        let nh = hop + 1;
        if nh >= lt.path_len as usize {
            // destination arrival: fuse it (device service, then a
            // pending Complete) only when it beats the horizon and every
            // pending event — the strict-`<` peek gate
            if ctx.fuse && t_next < bound && engine.would_dispatch_next(t_next) {
                engine.schedule(t_next + lt.tx.device_ns, EventKind::Complete { id });
                return fused + 1;
            }
            engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
            return fused;
        }
        let h = arena[lt.path_start as usize + nh];
        let next_link = (h >> 1) as usize;
        let target = ctx.link_shard[next_link];
        if target as usize != ctx.shard {
            out.push((target, Handoff { at: t_next, hop: nh as u32, tx: lt.tx }));
            free.push(id as u32);
            return fused;
        }
        let nd = (h & 1) as usize;
        if !(ctx.fuse
            && t_next < bound
            && engine.would_dispatch_next(t_next)
            && servers[next_link][nd].fuse_ready(t_next))
        {
            // gate failed or the downstream server is backlogged:
            // degrade to the per-hop event path
            engine.schedule(t_next, EventKind::Arrive { id, hop: nh });
            return fused;
        }
        let c2 = &ctx.consts[next_link];
        let service = c2.flit.wire_bytes(lt.tx.bytes) * c2.inv_rate;
        match servers[next_link][nd]
            .admit(t_next, service, lt.tx.bytes, lt.tx.class, id as u32, nh as u32)
        {
            Admission::Release { done: d } => {
                if let Some(tr) = trace.as_deref_mut() {
                    tr.hop(id, t_next, d - service, d, next_link, nd);
                }
                fused += 1;
                hop = nh;
                li = next_link;
                di = nd;
                done = d;
            }
            Admission::Start { done: d } => {
                if let Some(tr) = trace.as_deref_mut() {
                    tr.hop(id, t_next, d - service, d, next_link, nd);
                }
                // the Depart at `d` lands before the following arrival,
                // so the next gate check fails and the chain exits
                // through the schedule path
                engine.schedule(d, EventKind::Depart { link: next_link as u32, dir: nd as u8 });
                fused += 1;
                hop = nh;
                li = next_link;
                di = nd;
                done = d;
            }
            Admission::Queued => {
                // unreachable under fuse_ready; kept as the safe
                // degradation (identical to a dispatched arrival that
                // parked in a VC)
                if let Some(tr) = trace.as_deref_mut() {
                    tr.queued(id, t_next);
                }
                return fused + 1;
            }
        }
    }
}

/// Shard-local twin of `MemSim::intern_path` (same arena packing:
/// `(link << 1) | direction`, direction decided once at build time; same
/// `(src, dst, rail)` cache key, same rail-aware walk — a path crossing
/// three shards is interned by each of the three).
fn intern_local(
    fabric: &Fabric,
    tiers: &[LinkTier],
    spread: [bool; LinkTier::COUNT],
    arena: &mut Vec<u32>,
    cache: &mut HashMap<u64, (u32, u32)>,
    tx: &ShardTx,
) -> (u32, u32) {
    let key = path_key(tx.src as usize, tx.dst as usize, tx.rail);
    if let Some(&r) = cache.get(&key) {
        return r;
    }
    let start = arena.len() as u32;
    if !rail_hops(fabric, tiers, spread, tx.src as usize, tx.dst as usize, tx.rail, arena) {
        // the coordinator verified the first hop, so this means the
        // PBR table lost the route mid-path — name the flow anyway
        panic!(
            "no path {} ({}) -> {} ({}) on rail {} for traffic source {}",
            tx.src,
            fabric.topo.node(tx.src as usize).label,
            tx.dst,
            fabric.topo.node(tx.dst as usize).label,
            tx.rail,
            tx.source
        );
    }
    let entry = (start, arena.len() as u32 - start);
    cache.insert(key, entry);
    entry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{LinkKind, Topology};
    use crate::sim::memsim::MemSim;
    use crate::sim::{BatchSource, Transaction};

    /// A pod-shaped Clos: `leaves` leaf switches, endpoints per leaf.
    fn clos(leaves: usize, spines: usize, eps: usize) -> (Fabric, Vec<usize>) {
        let (mut t, leaf_ids) = Topology::clos(leaves, spines, LinkKind::CxlCoherent, "f");
        let mut out = Vec::new();
        for (i, &l) in leaf_ids.iter().enumerate() {
            for e in 0..eps {
                let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                t.connect(n, l, LinkKind::CxlCoherent);
                out.push(n);
            }
        }
        (Fabric::new(t), out)
    }

    fn workload(eps: &[usize], n: usize, seed: u64) -> Vec<Transaction> {
        let mut rng = crate::util::Rng::new(seed);
        let mut at = 0.0;
        (0..n)
            .map(|_| {
                at += rng.exp(1.0 / 25.0) + 1e-6;
                let s = rng.below(eps.len() as u64) as usize;
                let mut d = rng.below(eps.len() as u64) as usize;
                if d == s {
                    d = (d + 1) % eps.len();
                }
                Transaction { src: eps[s], dst: eps[d], at, bytes: 2048.0, device_ns: 90.0 }
            })
            .collect()
    }

    /// A ping-pong reactive chain: one transaction in flight at a time,
    /// next emission unblocked by the completion. With `footprint` it is
    /// eligible for coupled-domain pinning.
    #[derive(Clone, Copy)]
    struct Chain {
        src: usize,
        dst: usize,
        left: usize,
        waiting: bool,
        declared: bool,
    }

    impl TrafficSource for Chain {
        fn class(&self) -> TrafficClass {
            TrafficClass::Generic
        }
        fn pull(&mut self, now: f64) -> Pull {
            if self.left == 0 {
                return Pull::Done;
            }
            if self.waiting {
                return Pull::Blocked;
            }
            self.left -= 1;
            self.waiting = true;
            Pull::Tx(SourcedTx::new(
                Transaction { src: self.src, dst: self.dst, at: now, bytes: 512.0, device_ns: 0.0 },
                self.left as u64,
            ))
        }
        fn on_complete(&mut self, _token: u64, _now: f64) {
            self.waiting = false;
        }
        // open_loop() stays false: reactive
        fn footprint(&self) -> Option<Vec<NodeId>> {
            if self.declared {
                Some(vec![self.src, self.dst])
            } else {
                None
            }
        }
        fn checkpointable(&self) -> bool {
            true
        }
        fn checkpoint(&self) -> Option<Box<dyn std::any::Any + Send>> {
            Some(Box::new(*self))
        }
        fn restore(&mut self, snap: &(dyn std::any::Any + Send)) {
            *self = *snap.downcast_ref::<Chain>().expect("snapshot type mismatch");
        }
    }

    /// A [`Chain`] whose declared footprint is an arbitrary node set —
    /// wide enough to span every partition, forcing the optimistic path.
    #[derive(Clone)]
    struct WideChain {
        inner: Chain,
        nodes: Vec<usize>,
    }

    impl TrafficSource for WideChain {
        fn class(&self) -> TrafficClass {
            self.inner.class()
        }
        fn pull(&mut self, now: f64) -> Pull {
            self.inner.pull(now)
        }
        fn on_complete(&mut self, token: u64, now: f64) {
            self.inner.on_complete(token, now);
        }
        fn footprint(&self) -> Option<Vec<NodeId>> {
            Some(self.nodes.clone())
        }
        fn checkpointable(&self) -> bool {
            true
        }
        fn checkpoint(&self) -> Option<Box<dyn std::any::Any + Send>> {
            Some(Box::new(self.clone()))
        }
        fn restore(&mut self, snap: &(dyn std::any::Any + Send)) {
            let snap = snap.downcast_ref::<WideChain>().expect("snapshot type mismatch");
            self.clone_from(snap);
        }
    }

    fn no_meta() -> Vec<SourceMeta> {
        Vec::new()
    }

    /// Reactive-source meta (checkpoint-capable, as [`Chain`] is).
    fn rmeta(footprint: Option<Vec<NodeId>>) -> SourceMeta {
        SourceMeta {
            open: false,
            footprint,
            class: TrafficClass::Generic,
            checkpointable: true,
        }
    }

    /// Open-loop source meta.
    fn ometa() -> SourceMeta {
        SourceMeta {
            open: true,
            footprint: None,
            class: TrafficClass::Generic,
            checkpointable: false,
        }
    }

    #[test]
    fn plan_reflects_topology() {
        let (f, _) = clos(8, 2, 4);
        let sim = MemSim::new(&f);
        let p = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &no_meta(), 4)
            .sharded()
            .expect("clos must shard");
        assert!(p.nshards >= 2 && p.nshards <= 4);
        assert!(p.lookahead > 0.0 && p.lookahead.is_finite());
        assert_eq!(p.link_shard.len(), f.topo.links.len());
        // single-hop rack: one domain, no plan
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let f1 = Fabric::new(t);
        let s1 = MemSim::new(&f1);
        assert!(plan(&f1, &s1.consts, &s1.tiers, s1.spread, 1, &no_meta(), 4)
            .sharded()
            .is_none());
        // one requested shard: no plan
        assert!(plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &no_meta(), 1)
            .sharded()
            .is_none());
    }

    #[test]
    fn plan_pins_reactive_footprints() {
        let (f, eps) = clos(8, 2, 4);
        let sim = MemSim::new(&f);
        // two rack-local footprints on far-apart leaves + one open source
        let meta = vec![
            rmeta(Some(vec![eps[0], eps[1]])),
            rmeta(Some(vec![eps[4 * 6], eps[4 * 6 + 1]])),
            ometa(),
        ];
        let p = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta, 4)
            .sharded()
            .expect("rack-local footprints must shard");
        assert!(p.pinned[0].is_some() && p.pinned[1].is_some());
        assert_eq!(p.pinned[2], None);
        // rack-local pairs on different leaves land on different shards
        assert_ne!(p.pinned[0], p.pinned[1]);
        // the open source keeps the conservative bound finite
        assert!(p.lookahead.is_finite() && p.lookahead > 0.0);
        // every node of each closure lives on the pinned shard
        assert_eq!(p.node_shard[eps[0]], p.pinned[0].unwrap());
        assert_eq!(p.node_shard[eps[1]], p.pinned[0].unwrap());

        // without open sources the shards are fully decoupled
        let meta2 = vec![
            rmeta(Some(vec![eps[0], eps[1]])),
            rmeta(Some(vec![eps[4 * 6], eps[4 * 6 + 1]])),
        ];
        let p2 = plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta2, 4)
            .sharded()
            .expect("disjoint pinned-only footprints must shard");
        assert!(p2.lookahead.is_infinite());

        // an undeclared reactive source forces the serial fallback, and
        // the reason names it
        let meta3 = vec![SourceMeta {
            open: false,
            footprint: None,
            class: TrafficClass::Coherence,
            checkpointable: true,
        }];
        match plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta3, 4) {
            PlanOutcome::Fallback(reason) => {
                assert!(reason.contains("footprint"), "bad reason: {reason}");
                assert!(reason.contains("source 0"), "bad reason: {reason}");
                assert!(reason.contains("coherence"), "bad reason: {reason}");
            }
            PlanOutcome::Sharded(_) => panic!("undeclared footprint must not shard"),
        }

        // a fabric-wide footprint no longer collapses the partition: the
        // spanning group is excluded from coupling and the source runs
        // optimistically on the coordinator
        let meta4 = vec![rmeta(Some(eps.clone()))];
        match plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta4, 4) {
            PlanOutcome::Sharded(p) => {
                assert!(p.spanning[0], "fabric-wide footprint must be spanning");
                assert_eq!(p.pinned[0], None, "spanning source stays on the coordinator");
                assert!(p.nshards >= 2);
                assert!(
                    p.lookahead.is_finite() && p.lookahead > 0.0,
                    "spanning traffic needs a finite conservative bound"
                );
            }
            PlanOutcome::Fallback(reason) => {
                panic!("spanning footprint must shard optimistically, got fallback: {reason}")
            }
        }

        // ... unless some reactive source cannot checkpoint: then the
        // run stays serial and the reason names both sources
        let meta5 = vec![
            rmeta(Some(eps.clone())),
            SourceMeta {
                open: false,
                footprint: Some(vec![eps[0], eps[1]]),
                class: TrafficClass::Collective,
                checkpointable: false,
            },
        ];
        match plan(&f, &sim.consts, &sim.tiers, sim.spread, 1, &meta5, 4) {
            PlanOutcome::Fallback(reason) => {
                assert!(reason.contains("footprint"), "bad reason: {reason}");
                assert!(reason.contains("checkpoint"), "bad reason: {reason}");
                assert!(reason.contains("collective"), "bad reason: {reason}");
            }
            PlanOutcome::Sharded(_) => {
                panic!("spanning + non-checkpointable source must fall back")
            }
        }
    }

    #[test]
    fn sharded_matches_serial_on_clos() {
        let (f, eps) = clos(6, 2, 6);
        let txs = workload(&eps, 600, 0x5AA5);

        let mut serial_sim = MemSim::new(&f);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert!(sharded.mode.is_sharded(), "open-loop clos run must shard");
        assert!(sharded.epochs > 0 && sharded.barriers >= sharded.epochs);
        assert!(sharded.shards.len() >= 2, "per-shard telemetry missing");
        assert_eq!(
            sharded.shards.iter().map(|s| s.events).sum::<u64>(),
            sharded.total.events,
            "per-shard event telemetry must sum to the total"
        );
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        assert!(close(serial.latency.min(), sharded.total.latency.min()));
        // per-link utilization state merged back from the workers
        assert!(sharded_sim.peak_utilization(sharded.total.makespan_ns) > 0.0);
    }

    #[test]
    fn sharded_spray_matches_serial_spray() {
        // the multi-rail twin of sharded_matches_serial_on_clos: rails
        // resolved at injection hash identically to the serial loop
        use crate::sim::{RailSelector, RoutingPolicy};
        let (mut f, eps) = clos(6, 2, 6);
        f.enable_multipath(4);
        let txs = workload(&eps, 600, 0xB1A5);
        let policy = RoutingPolicy::uniform(RailSelector::HashSpray);

        let mut serial_sim = MemSim::with_routing(&f, policy);
        let serial = serial_sim.run(txs.clone());

        let mut sharded_sim = MemSim::with_routing(&f, policy);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let sharded = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sharded_sim.run_streamed_sharded_with(&mut sources, 3)
        };
        assert_eq!(serial.completed, sharded.total.completed);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.latency.max(), sharded.total.latency.max()));
        // the spray actually spread: more ridden paths than pairs
        assert!(
            serial_sim.used_path_count() > serial_sim.used_pair_count(),
            "spray rode no extra rails"
        );
    }

    #[test]
    fn pinned_reactive_sources_match_serial() {
        // rack-local ping-pong chains on three different leaves, plus
        // open-loop background: the chains pin to their leaf shards and
        // the whole mix must reproduce the serial run exactly
        let (f, eps) = clos(6, 2, 4);
        let chain_at = |leaf: usize| (eps[4 * leaf], eps[4 * leaf + 1]);
        let txs = workload(&eps, 300, 0xC0DE);

        let run_with = |sharded: bool| {
            let mut sim = MemSim::new(&f);
            let mut chains: Vec<Chain> = [0usize, 2, 5]
                .iter()
                .map(|&l| {
                    let (src, dst) = chain_at(l);
                    Chain { src, dst, left: 50, waiting: false, declared: true }
                })
                .collect();
            let mut bg = BatchSource::new(txs.clone(), crate::sim::TrafficClass::Generic);
            let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
            for c in &mut chains {
                sources.push(c);
            }
            sources.push(&mut bg);
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, 3)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let serial = run_with(false);
        let sharded = run_with(true);
        assert!(
            matches!(sharded.mode, ShardMode::Sharded { pinned_sources: 3, .. }),
            "chains must pin, got {:?}",
            sharded.mode
        );
        assert_eq!(serial.total.completed, sharded.total.completed);
        assert_eq!(serial.total.events, sharded.total.events);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.total.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.total.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.total.latency.max(), sharded.total.latency.max()));
    }

    #[test]
    fn fully_pinned_run_is_one_decoupled_epoch() {
        // chains only — no open-loop traffic: the plan proves no handoff
        // can exist, the lookahead is infinite and the run is one epoch
        let (f, eps) = clos(4, 2, 4);
        let run_with = |sharded: bool| {
            let mut sim = MemSim::new(&f);
            let mut chains: Vec<Chain> = (0..4)
                .map(|l| Chain {
                    src: eps[4 * l],
                    dst: eps[4 * l + 1],
                    left: 40,
                    waiting: false,
                    declared: true,
                })
                .collect();
            let mut sources: Vec<&mut dyn TrafficSource> =
                chains.iter_mut().map(|c| c as &mut dyn TrafficSource).collect();
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, 4)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let serial = run_with(false);
        let sharded = run_with(true);
        assert!(sharded.mode.is_sharded(), "disjoint chains must shard: {:?}", sharded.mode);
        assert_eq!(sharded.epochs, 1, "fully-pinned run must be a single epoch");
        assert_eq!(serial.total.completed, sharded.total.completed);
        assert_eq!(serial.total.events, sharded.total.events);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.total.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.total.latency.mean(), sharded.total.latency.mean()));
    }

    #[test]
    fn reactive_sources_fall_back_to_serial() {
        // a reactive source WITHOUT a declared footprint keeps the exact
        // serial loop, and the report says why
        let (f, eps) = clos(4, 2, 2);
        let mut sim = MemSim::new(&f);
        let mut chain =
            Chain { src: eps[0], dst: eps[eps.len() - 1], left: 4, waiting: false, declared: false };
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut chain];
            sim.run_streamed_sharded(&mut sources)
        };
        // the serial fallback must run the reactive chain to completion
        assert_eq!(rep.total.completed, 4);
        match &rep.mode {
            ShardMode::SerialFallback { reason } => assert!(reason.contains("footprint")),
            other => panic!("expected SerialFallback, got {other:?}"),
        }
    }

    #[test]
    fn zero_hop_transactions_shard_cleanly() {
        let (f, eps) = clos(4, 2, 3);
        let txs: Vec<Transaction> = (0..40)
            .map(|i| Transaction {
                src: eps[i % eps.len()],
                dst: eps[i % eps.len()],
                at: 1.0 + i as f64,
                bytes: 64.0,
                device_ns: 250.0,
            })
            .collect();
        let mut sim = MemSim::new(&f);
        let mut src = BatchSource::new(txs, crate::sim::TrafficClass::Generic);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed_sharded_with(&mut sources, 4)
        };
        assert_eq!(rep.total.completed, 40);
        assert!((rep.total.latency.mean() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn spanning_chain_matches_serial() {
        // a reactive chain whose declared footprint covers the whole
        // fabric: the partition survives, the chain runs on the
        // coordinator under checkpoint/rollback, and the mix with
        // open-loop background reproduces the serial run exactly
        let (f, eps) = clos(6, 2, 4);
        let txs = workload(&eps, 300, 0x0DDB);
        let run_with = |sharded: bool| {
            let mut sim = MemSim::new(&f);
            let mut wide = WideChain {
                inner: Chain {
                    src: eps[0],
                    dst: eps[eps.len() - 1],
                    left: 40,
                    waiting: false,
                    declared: true,
                },
                nodes: eps.clone(),
            };
            let mut bg = BatchSource::new(txs.clone(), crate::sim::TrafficClass::Generic);
            let mut sources: [&mut dyn TrafficSource; 2] = [&mut wide, &mut bg];
            if sharded {
                sim.run_streamed_sharded_with(&mut sources, 3)
            } else {
                sim.run_streamed(&mut sources)
            }
        };
        let serial = run_with(false);
        let sharded = run_with(true);
        assert!(sharded.mode.is_sharded(), "spanning chain must shard: {:?}", sharded.mode);
        assert_eq!(sharded.optimistic_sources, 1);
        assert!(sharded.checkpoints > 0, "spanning chain never gated a window");
        assert!(
            sharded.rollbacks > 0,
            "a fabric-crossing ping-pong must mispredict at least once"
        );
        assert_eq!(serial.total.completed, sharded.total.completed);
        assert_eq!(serial.total.events, sharded.total.events);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(serial.total.makespan_ns, sharded.total.makespan_ns));
        assert!(close(serial.total.latency.mean(), sharded.total.latency.mean()));
        assert!(close(serial.total.latency.max(), sharded.total.latency.max()));
    }

    #[test]
    fn sharded_adaptive_uses_digests_deterministically() {
        // Adaptive on the sharded backend steers by barrier-piggybacked
        // digests: not byte-equal to the serial live-state scoring (the
        // digest is one barrier stale), but deterministic across runs and
        // work-conserving vs the serial backend
        use crate::sim::{RailSelector, RoutingPolicy};
        let (mut f, eps) = clos(6, 2, 6);
        f.enable_multipath(4);
        let txs = workload(&eps, 600, 0xADAF);
        let policy = RoutingPolicy::uniform(RailSelector::Adaptive);

        let run_sharded = || {
            let mut sim = MemSim::with_routing(&f, policy);
            let mut src = BatchSource::new(txs.clone(), crate::sim::TrafficClass::Generic);
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed_sharded_with(&mut sources, 3)
        };
        let a = run_sharded();
        let b = run_sharded();
        assert!(a.mode.is_sharded(), "adaptive clos run must shard: {:?}", a.mode);
        assert_eq!(a.total.completed, b.total.completed);
        assert_eq!(a.total.events, b.total.events);
        assert_eq!(
            a.total.makespan_ns.to_bits(),
            b.total.makespan_ns.to_bits(),
            "adaptive sharded runs must be bit-reproducible"
        );
        assert_eq!(a.total.latency.mean().to_bits(), b.total.latency.mean().to_bits());

        // work conservation vs the serial adaptive backend
        let mut serial_sim = MemSim::with_routing(&f, policy);
        let serial = serial_sim.run(txs.clone());
        assert_eq!(serial.completed, a.total.completed);
    }
}
