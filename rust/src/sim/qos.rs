//! Fabric QoS: class-aware link arbitration. Every link direction is a
//! [`ClassedServer`] holding one virtual channel (VC) per [`TrafficClass`]
//! and a pluggable arbitration policy — the subsystem that lets the
//! coordinator *act* on the cross-class interference the `mixed`
//! experiment measures (DFabric's central result for shared hybrid
//! fabrics; CXL-CCL's observation that collectives over a CXL pool are
//! acutely sensitive to fabric sharing).
//!
//! # Policies
//!
//! * [`ArbPolicy::FcfsShared`] — the pre-QoS behavior: one class-blind
//!   FCFS queue. This is the **parity baseline**: its admission math is
//!   byte-identical to the plain [`Server`](super::server::Server)
//!   (pinned by `tests/prop_qos.rs::prop_fcfs_matches_pre_qos_server`)
//!   and it needs no extra events, so the default hot path pays nothing.
//! * [`ArbPolicy::StrictPriority`] — a configurable class order (e.g.
//!   coherence > tiering > collective > generic); when the link frees,
//!   the highest-priority backlogged VC is served, FIFO within a VC.
//!   Non-preemptive (a transaction in service finishes).
//! * [`ArbPolicy::WeightedFair`] — deficit round-robin over per-class
//!   byte credits: each VC visit adds `quantum ∝ weight` bytes of
//!   credit and the head transaction is served once the VC's deficit
//!   covers its bytes, so long-run byte shares track the weights while
//!   no backlogged class starves.
//!
//! All policies are **work-conserving**: the link never idles while any
//! VC is backlogged (`depart` always starts a queued transaction when one
//! exists — pinned by `prop_qos_work_conservation`).
//!
//! # Integration with the event engine
//!
//! FCFS admissions are *time-released*: `admit` returns the completion
//! time immediately (the classic `Server::admit` contract), because FIFO
//! order is fixed at arrival. Under Strict/WeightedFair the service order
//! of a backlog genuinely depends on later arrivals, so admission to a
//! busy link returns [`Admission::Queued`] and the driver schedules a
//! [`Depart`](super::engine::EventKind::Depart) event at each service
//! completion; `depart` then picks the next VC per policy. Per-link-tier
//! policies come from a [`QosPolicy`], applied by
//! [`MemSim::set_qos`](super::MemSim::set_qos) (usually via the
//! coordinator's [`QosManager`](crate::coordinator::QosManager)).

use super::traffic::TrafficClass;
use crate::fabric::{NodeKind, Topology};
use std::collections::VecDeque;

/// Structural tier of a fabric link, the granularity at which the
/// coordinator sets arbitration policies (paper Figure 2/4: XLink domain
/// links, rack-crossbar uplinks into the CXL fabric, CXL leaf attach,
/// CXL spine/core). Derived from the topology by [`classify_links`]; for
/// the RDMA baseline the same structural rules apply to the IB fat tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkTier {
    /// Accelerator-centric intra-rack links (NVLink/UALink).
    Xlink,
    /// Rack crossbar uplinks into the inter-cluster fabric.
    RackUplink,
    /// Endpoint attach into the fabric edge: per-accelerator CXL ports,
    /// CPU and tier-2 memory-node links.
    CxlLeaf,
    /// Fabric-internal switch-to-switch links (leaf-spine, torus,
    /// dragonfly core).
    CxlSpine,
}

impl LinkTier {
    pub const COUNT: usize = 4;
    pub const ALL: [LinkTier; 4] =
        [LinkTier::Xlink, LinkTier::RackUplink, LinkTier::CxlLeaf, LinkTier::CxlSpine];

    pub fn index(self) -> usize {
        match self {
            LinkTier::Xlink => 0,
            LinkTier::RackUplink => 1,
            LinkTier::CxlLeaf => 2,
            LinkTier::CxlSpine => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LinkTier::Xlink => "xlink",
            LinkTier::RackUplink => "rack-uplink",
            LinkTier::CxlLeaf => "cxl-leaf",
            LinkTier::CxlSpine => "cxl-spine",
        }
    }
}

/// Classify every link of a topology into its [`LinkTier`]. A switch with
/// at least one incident XLink link is a rack crossbar; switch-to-switch
/// links touching a crossbar are rack uplinks, other switch-to-switch
/// links are fabric core, and endpoint-attach links are leaf links.
pub fn classify_links(topo: &Topology) -> Vec<LinkTier> {
    let crossbar: Vec<bool> = (0..topo.nodes.len())
        .map(|n| {
            topo.node(n).kind == NodeKind::Switch
                && topo.neighbors(n).iter().any(|&(_, l)| topo.link(l).params.kind.is_xlink())
        })
        .collect();
    topo.links
        .iter()
        .map(|l| {
            if l.params.kind.is_xlink() {
                LinkTier::Xlink
            } else if topo.node(l.a).kind == NodeKind::Switch
                && topo.node(l.b).kind == NodeKind::Switch
            {
                if crossbar[l.a] || crossbar[l.b] {
                    LinkTier::RackUplink
                } else {
                    LinkTier::CxlSpine
                }
            } else {
                LinkTier::CxlLeaf
            }
        })
        .collect()
}

/// Arbitration policy of one [`ClassedServer`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArbPolicy {
    /// Class-blind FCFS — the pre-QoS parity baseline.
    FcfsShared,
    /// Serve the highest-priority backlogged VC first; the array lists
    /// classes from highest to lowest priority and must name each class
    /// exactly once.
    StrictPriority([TrafficClass; 4]),
    /// Deficit round-robin over per-class byte credits; weights are
    /// relative byte shares indexed by [`TrafficClass::index`] and are
    /// clamped to a small positive floor (a zero-weight backlogged class
    /// must still drain — work conservation).
    WeightedFair([f64; 4]),
}

impl ArbPolicy {
    /// Default strict order: coherence > tiering > collective > generic
    /// (latency-critical protocol messages first, bulk last).
    pub fn strict_default() -> ArbPolicy {
        ArbPolicy::StrictPriority([
            TrafficClass::Coherence,
            TrafficClass::Tiering,
            TrafficClass::Collective,
            TrafficClass::Generic,
        ])
    }

    /// Default weighted-fair shares: coherence-heavy but with a
    /// guaranteed collective share (the anti-starvation configuration).
    pub fn weighted_default() -> ArbPolicy {
        ArbPolicy::WeightedFair([4.0, 2.0, 2.0, 1.0])
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArbPolicy::FcfsShared => "fcfs",
            ArbPolicy::StrictPriority(_) => "strict",
            ArbPolicy::WeightedFair(_) => "wfq",
        }
    }
}

/// Per-link-tier arbitration configuration, owned by the coordinator
/// ([`QosManager`](crate::coordinator::QosManager)) and applied to a
/// simulator with [`MemSim::set_qos`](super::MemSim::set_qos).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QosPolicy {
    per_tier: [ArbPolicy; LinkTier::COUNT],
}

impl QosPolicy {
    /// The same policy on every tier.
    pub fn uniform(p: ArbPolicy) -> QosPolicy {
        QosPolicy { per_tier: [p; LinkTier::COUNT] }
    }

    /// The parity baseline: class-blind FCFS everywhere.
    pub fn fcfs() -> QosPolicy {
        QosPolicy::uniform(ArbPolicy::FcfsShared)
    }

    pub fn tier(&self, t: LinkTier) -> ArbPolicy {
        self.per_tier[t.index()]
    }

    pub fn set(&mut self, t: LinkTier, p: ArbPolicy) {
        self.per_tier[t.index()] = p;
    }
}

impl Default for QosPolicy {
    fn default() -> QosPolicy {
        QosPolicy::fcfs()
    }
}

/// What [`ClassedServer::admit`] decided.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// FCFS time-release: the completion time is fixed at admission and
    /// no depart event is needed (the pre-QoS `Server` contract).
    Release { done: f64 },
    /// The link was idle: service starts now and completes at `done`.
    /// The driver must schedule a `Depart` event at `done` so the
    /// arbiter can start the next queued transaction.
    Start { done: f64 },
    /// Backlogged in the class's VC; a later `depart` will start it.
    Queued,
}

/// Per-class service telemetry of one link direction.
#[derive(Clone, Copy, Debug, Default)]
pub struct VcStats {
    /// Transactions served.
    pub served: u64,
    /// Payload bytes served.
    pub bytes: f64,
    /// Cumulative service (busy) time, ns.
    pub busy_ns: f64,
    /// Cumulative queueing delay (service start - arrival), ns.
    pub queued_ns: f64,
}

/// One per-link per-class telemetry record, exported into
/// [`StreamReport::qos`](super::traffic::StreamReport::qos) after a run
/// (only link directions that actually served a class are listed).
#[derive(Clone, Copy, Debug)]
pub struct LinkClassStats {
    pub link: u32,
    pub dir: u8,
    pub tier: LinkTier,
    pub class: TrafficClass,
    pub served: u64,
    pub bytes: f64,
    pub busy_ns: f64,
    /// Cumulative queueing delay, ns (divide by `served` for the mean).
    pub queue_delay_ns: f64,
}

impl LinkClassStats {
    pub fn mean_queue_delay_ns(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.queue_delay_ns / self.served as f64
        }
    }

    pub fn utilization(&self, horizon_ns: f64) -> f64 {
        if horizon_ns <= 0.0 {
            0.0
        } else {
            (self.busy_ns / horizon_ns).min(1.0)
        }
    }
}

/// One entry of an epoch-batched admission
/// ([`ClassedServer::admit_batch`]): a transaction arriving on a link
/// direction at the shared batch timestamp.
#[derive(Clone, Copy, Debug)]
pub struct BatchAdmit {
    /// Serialization time on this link, ns.
    pub service: f64,
    /// Payload bytes (VC accounting + DRR credit).
    pub bytes: f64,
    pub class: TrafficClass,
    /// Echoed back by [`ClassedServer::depart`] for queued entries.
    pub id: u32,
    /// Echoed back by [`ClassedServer::depart`] for queued entries.
    pub hop: u32,
}

/// A transaction parked in a virtual channel.
#[derive(Clone, Copy, Debug)]
struct QueuedTx {
    service: f64,
    bytes: f64,
    arrived: f64,
    id: u32,
    hop: u32,
}

/// Floor for weighted-fair quanta: even a zero-weight class accumulates
/// credit, so a backlogged VC always drains (work conservation).
const MIN_QUANTUM_BYTES: f64 = 64.0;
/// Byte credit granted to the heaviest class per DRR visit.
const QUANTUM_SCALE_BYTES: f64 = 16.0 * 1024.0;

/// One link direction (or switch port) as a class-aware resource: one
/// virtual channel per [`TrafficClass`], arbitration per [`ArbPolicy`].
#[derive(Clone, Debug)]
pub struct ClassedServer {
    policy: ArbPolicy,
    /// Strict-priority rank per class index (0 = highest).
    rank: [u8; 4],
    /// DRR byte credit granted per visit, per class index.
    quantum: [f64; 4],
    /// FCFS time-release state: when the shared queue drains.
    free_at: f64,
    /// Queued-mode state: a transaction is currently in service.
    in_service: bool,
    /// Queued-mode state: when the in-service transaction completes
    /// (only meaningful while `in_service`); feeds the adaptive rail
    /// selector's backlog estimate ([`ClassedServer::pending_ns`]).
    service_end: f64,
    vcs: [VecDeque<QueuedTx>; 4],
    queued_count: usize,
    /// DRR state.
    deficit: [f64; 4],
    rr_cursor: usize,
    fresh_visit: bool,
    stats: [VcStats; 4],
}

impl ClassedServer {
    pub fn new(policy: ArbPolicy) -> ClassedServer {
        let mut rank = [0u8; 4];
        let mut quantum = [QUANTUM_SCALE_BYTES; 4];
        match policy {
            ArbPolicy::FcfsShared => {}
            ArbPolicy::StrictPriority(order) => {
                let mut seen = [false; 4];
                for (r, c) in order.iter().enumerate() {
                    rank[c.index()] = r as u8;
                    seen[c.index()] = true;
                }
                assert!(seen.iter().all(|&s| s), "strict-priority order must name every class once");
            }
            ArbPolicy::WeightedFair(weights) => {
                let max = weights.iter().copied().fold(MIN_QUANTUM_BYTES / QUANTUM_SCALE_BYTES, f64::max);
                for (q, &w) in quantum.iter_mut().zip(&weights) {
                    assert!(w.is_finite() && w >= 0.0, "weighted-fair weights must be finite and >= 0");
                    *q = (w / max * QUANTUM_SCALE_BYTES).max(MIN_QUANTUM_BYTES);
                }
            }
        }
        ClassedServer {
            policy,
            rank,
            quantum,
            free_at: 0.0,
            in_service: false,
            service_end: 0.0,
            vcs: [VecDeque::new(), VecDeque::new(), VecDeque::new(), VecDeque::new()],
            queued_count: 0,
            deficit: [0.0; 4],
            rr_cursor: 0,
            fresh_visit: true,
            stats: [VcStats::default(); 4],
        }
    }

    /// The parity baseline (class-blind FCFS).
    pub fn fcfs() -> ClassedServer {
        ClassedServer::new(ArbPolicy::FcfsShared)
    }

    pub fn policy(&self) -> ArbPolicy {
        self.policy
    }

    /// Admit a `class` transaction arriving at `now` needing `service`
    /// time to move `bytes` of payload. `id`/`hop` are echoed back by
    /// [`ClassedServer::depart`] when a queued transaction starts.
    #[inline]
    pub fn admit(
        &mut self,
        now: f64,
        service: f64,
        bytes: f64,
        class: TrafficClass,
        id: u32,
        hop: u32,
    ) -> Admission {
        let ci = class.index();
        if let ArbPolicy::FcfsShared = self.policy {
            // byte-identical to the pre-QoS Server::admit
            let start = now.max(self.free_at);
            self.free_at = start + service;
            let s = &mut self.stats[ci];
            s.queued_ns += start - now;
            s.busy_ns += service;
            s.served += 1;
            s.bytes += bytes;
            return Admission::Release { done: self.free_at };
        }
        if self.in_service {
            self.vcs[ci].push_back(QueuedTx { service, bytes, arrived: now, id, hop });
            self.queued_count += 1;
            return Admission::Queued;
        }
        self.in_service = true;
        self.service_end = now + service;
        let s = &mut self.stats[ci];
        s.busy_ns += service;
        s.served += 1;
        s.bytes += bytes;
        Admission::Start { done: now + service }
    }

    /// Admit a batch of transactions that all arrived at `now` on this
    /// link direction, appending one [`Admission`] per entry (in order)
    /// to `out`. Equivalent admission-for-admission to calling
    /// [`ClassedServer::admit`] once per entry in batch order — pinned by
    /// `admit_batch_matches_sequential_admits` — but amortizes the
    /// bookkeeping (§Perf, epoch batching): the FCFS branch chains the
    /// release horizon through a register instead of re-loading and
    /// re-storing `free_at` per transaction, and the policy dispatch is
    /// paid once per batch instead of once per admission. Used by both
    /// the serial streamed loop and the sharded workers (each shard owns
    /// its links' servers outright, so the same same-timestamp
    /// same-direction coalescing applies unchanged inside an epoch).
    pub fn admit_batch(&mut self, now: f64, batch: &[BatchAdmit], out: &mut Vec<Admission>) {
        if let ArbPolicy::FcfsShared = self.policy {
            let mut free = self.free_at;
            for b in batch {
                // byte-identical math to the single-admit FCFS branch:
                // after the first entry the chain is simply additive
                let start = now.max(free);
                free = start + b.service;
                let s = &mut self.stats[b.class.index()];
                s.queued_ns += start - now;
                s.busy_ns += b.service;
                s.served += 1;
                s.bytes += b.bytes;
                out.push(Admission::Release { done: free });
            }
            self.free_at = free;
            return;
        }
        // queued-mode policies: the VC pushes dominate and stay per
        // entry; only the dispatch above is amortized
        for b in batch {
            out.push(self.admit(now, b.service, b.bytes, b.class, b.id, b.hop));
        }
    }

    /// The in-service transaction finished at `now`: pick the next VC per
    /// the arbitration policy and start its head transaction. Returns
    /// `(id, hop, done)` of the started transaction, or `None` when every
    /// VC is empty (the link goes idle). Only meaningful for queued-mode
    /// policies — FCFS admissions never schedule departs.
    pub fn depart(&mut self, now: f64) -> Option<(u32, u32, f64)> {
        debug_assert!(self.in_service, "depart on an idle server");
        let ci = match self.pick() {
            Some(c) => c,
            None => {
                self.in_service = false;
                return None;
            }
        };
        let q = self.vcs[ci].pop_front().expect("picked VC is non-empty");
        self.queued_count -= 1;
        self.service_end = now + q.service;
        let s = &mut self.stats[ci];
        s.queued_ns += now - q.arrived;
        s.busy_ns += q.service;
        s.served += 1;
        s.bytes += q.bytes;
        Some((q.id, q.hop, now + q.service))
    }

    /// Arbitrate: which VC serves next.
    fn pick(&mut self) -> Option<usize> {
        if self.queued_count == 0 {
            return None;
        }
        match self.policy {
            ArbPolicy::FcfsShared => unreachable!("FCFS admissions are time-released"),
            ArbPolicy::StrictPriority(_) => {
                (0..4).filter(|&c| !self.vcs[c].is_empty()).min_by_key(|&c| self.rank[c])
            }
            ArbPolicy::WeightedFair(_) => {
                // deficit round-robin (Shreedhar-Varghese), one grant per
                // call: each fresh visit to a backlogged VC adds its
                // quantum; the head serves once the deficit covers its
                // bytes. Terminates because every quantum is positive.
                loop {
                    let c = self.rr_cursor;
                    if self.vcs[c].is_empty() {
                        self.deficit[c] = 0.0;
                        self.rr_cursor = (c + 1) % 4;
                        self.fresh_visit = true;
                        continue;
                    }
                    if self.fresh_visit {
                        self.deficit[c] += self.quantum[c];
                        self.fresh_visit = false;
                    }
                    let need = self.vcs[c].front().expect("non-empty").bytes;
                    if self.deficit[c] + 1e-9 >= need {
                        self.deficit[c] -= need;
                        return Some(c);
                    }
                    self.rr_cursor = (c + 1) % 4;
                    self.fresh_visit = true;
                }
            }
        }
    }

    /// Transactions currently parked in virtual channels.
    pub fn backlog(&self) -> usize {
        self.queued_count
    }

    /// Service time (ns) admitted but not yet completed as of `now` —
    /// the live congestion signal the adaptive rail selector steers on
    /// ([`crate::sim::rails`]). For FCFS this is the time-released
    /// horizon `free_at - now`; for queued-mode policies it is the
    /// residual of the in-service transaction plus every parked VC
    /// entry's service demand (O(backlog) — called on the injection
    /// path of adaptive runs only, never on the per-event hot path).
    pub fn pending_ns(&self, now: f64) -> f64 {
        if let ArbPolicy::FcfsShared = self.policy {
            return (self.free_at - now).max(0.0);
        }
        let queued: f64 = self.vcs.iter().flat_map(|q| q.iter()).map(|q| q.service).sum();
        let in_svc = if self.in_service { (self.service_end - now).max(0.0) } else { 0.0 };
        queued + in_svc
    }

    /// True while a transaction is in service (queued-mode policies).
    pub fn busy(&self) -> bool {
        self.in_service
    }

    /// Express-dispatch probe: would a transaction arriving at `now`
    /// begin service immediately, with no queueing ahead of it? FCFS:
    /// the shared queue has time-released (`free_at <= now`, so `admit`
    /// starts it at `now` exactly); queued-mode: the link is idle (so
    /// `admit` returns `Start`, never `Queued`). The hop-fusion gate in
    /// the streamed core only admits a fused hop inline when this holds
    /// — a backlogged server ends the chain and the transaction falls
    /// back to the per-hop event path unchanged.
    #[inline]
    pub fn fuse_ready(&self, now: f64) -> bool {
        if let ArbPolicy::FcfsShared = self.policy {
            self.free_at <= now
        } else {
            !self.in_service
        }
    }

    pub fn class_stats(&self, class: TrafficClass) -> &VcStats {
        &self.stats[class.index()]
    }

    /// Total transactions served across classes.
    pub fn served(&self) -> u64 {
        self.stats.iter().map(|s| s.served).sum()
    }

    /// Total busy time across classes, ns.
    pub fn busy_ns(&self) -> f64 {
        self.stats.iter().map(|s| s.busy_ns).sum()
    }

    pub fn utilization(&self, horizon_ns: f64) -> f64 {
        if horizon_ns <= 0.0 {
            0.0
        } else {
            (self.busy_ns() / horizon_ns).min(1.0)
        }
    }

    /// Mean queueing delay across classes, ns.
    pub fn mean_queue_delay(&self) -> f64 {
        let served = self.served();
        if served == 0 {
            0.0
        } else {
            self.stats.iter().map(|s| s.queued_ns).sum::<f64>() / served as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::LinkKind;
    use crate::sim::Server;

    const CO: TrafficClass = TrafficClass::Coherence;
    const TI: TrafficClass = TrafficClass::Tiering;
    const COL: TrafficClass = TrafficClass::Collective;
    const GE: TrafficClass = TrafficClass::Generic;

    #[test]
    fn fcfs_admissions_match_plain_server() {
        let mut rng = crate::util::Rng::new(0x0F5);
        let mut cs = ClassedServer::fcfs();
        let mut s = Server::new();
        let mut now = 0.0;
        for i in 0..500u32 {
            now += rng.f64() * 20.0;
            let service = 0.5 + rng.f64() * 30.0;
            let class = TrafficClass::ALL[rng.below(4) as usize];
            let want = s.admit(now, service);
            match cs.admit(now, service, 64.0, class, i, 0) {
                Admission::Release { done } => assert_eq!(done, want),
                other => panic!("FCFS must time-release, got {other:?}"),
            }
        }
        assert_eq!(cs.served(), s.served());
        assert!((cs.mean_queue_delay() - s.mean_queue_delay()).abs() < 1e-9);
        assert!((cs.utilization(now + 100.0) - s.utilization(now + 100.0)).abs() < 1e-12);
    }

    #[test]
    fn idle_queued_server_starts_immediately() {
        let mut cs = ClassedServer::new(ArbPolicy::strict_default());
        match cs.admit(10.0, 5.0, 64.0, GE, 0, 0) {
            Admission::Start { done } => assert_eq!(done, 15.0),
            other => panic!("expected Start, got {other:?}"),
        }
        assert!(cs.busy());
        // busy: the next admission queues
        assert_eq!(cs.admit(11.0, 5.0, 64.0, GE, 1, 0), Admission::Queued);
        assert_eq!(cs.backlog(), 1);
    }

    #[test]
    fn strict_priority_serves_high_class_first() {
        let mut cs = ClassedServer::new(ArbPolicy::strict_default());
        assert!(matches!(cs.admit(0.0, 10.0, 64.0, GE, 100, 0), Admission::Start { .. }));
        // backlog arrives while busy: generic, collective, coherence
        cs.admit(1.0, 10.0, 64.0, GE, 101, 0);
        cs.admit(2.0, 10.0, 64.0, COL, 102, 0);
        cs.admit(3.0, 10.0, 64.0, CO, 103, 0);
        // departs must drain coherence, then collective, then generic
        let (id1, _, d1) = cs.depart(10.0).unwrap();
        assert_eq!(id1, 103);
        assert_eq!(d1, 20.0);
        let (id2, _, _) = cs.depart(20.0).unwrap();
        assert_eq!(id2, 102);
        let (id3, _, _) = cs.depart(30.0).unwrap();
        assert_eq!(id3, 101);
        assert!(cs.depart(40.0).is_none());
        assert!(!cs.busy());
    }

    #[test]
    fn strict_priority_is_fifo_within_class() {
        let mut cs = ClassedServer::new(ArbPolicy::strict_default());
        cs.admit(0.0, 1.0, 64.0, GE, 0, 0);
        for i in 1..=5u32 {
            cs.admit(0.5, 1.0, 64.0, CO, i, 0);
        }
        let mut now = 1.0;
        for want in 1..=5u32 {
            let (id, _, done) = cs.depart(now).unwrap();
            assert_eq!(id, want);
            now = done;
        }
    }

    #[test]
    fn work_conserving_under_every_policy() {
        for policy in [ArbPolicy::strict_default(), ArbPolicy::weighted_default()] {
            let mut cs = ClassedServer::new(policy);
            assert!(matches!(cs.admit(0.0, 2.0, 128.0, CO, 0, 0), Admission::Start { .. }));
            for i in 1..40u32 {
                let class = TrafficClass::ALL[(i % 4) as usize];
                assert_eq!(cs.admit(0.1, 2.0, 128.0, class, i, 0), Admission::Queued);
            }
            // every depart while backlogged must start the next job
            let mut now = 2.0;
            let mut started = 0;
            while cs.backlog() > 0 {
                let (_, _, done) = cs.depart(now).expect("backlogged link must not idle");
                assert_eq!(done, now + 2.0);
                now = done;
                started += 1;
            }
            assert_eq!(started, 39);
            assert!(cs.depart(now).is_none());
            assert_eq!(cs.served(), 40);
        }
    }

    #[test]
    fn drr_byte_shares_track_weights() {
        // saturated link, two backlogged classes with 3:1 weights: served
        // bytes over a long run must track the ratio
        let weights = [3.0, 1.0, 0.0, 0.0];
        let mut cs = ClassedServer::new(ArbPolicy::WeightedFair(weights));
        assert!(matches!(cs.admit(0.0, 1.0, 1024.0, CO, 0, 0), Admission::Start { .. }));
        for i in 0..2000u32 {
            cs.admit(0.0, 1.0, 1024.0, CO, i, 0);
            cs.admit(0.0, 1.0, 1024.0, TI, 10_000 + i, 0);
        }
        let mut now = 1.0;
        for _ in 0..1200 {
            let (_, _, done) = cs.depart(now).unwrap();
            now = done;
        }
        let co = cs.class_stats(CO).bytes;
        let ti = cs.class_stats(TI).bytes;
        let ratio = co / ti;
        assert!((2.4..=3.6).contains(&ratio), "DRR 3:1 weights gave byte ratio {ratio:.2}");
    }

    #[test]
    fn drr_zero_weight_class_still_drains() {
        let mut cs = ClassedServer::new(ArbPolicy::WeightedFair([1.0, 0.0, 0.0, 0.0]));
        cs.admit(0.0, 1.0, 64.0, CO, 0, 0);
        cs.admit(0.0, 1.0, 64.0, TI, 1, 0);
        cs.admit(0.0, 1.0, 64.0, TI, 2, 0);
        let mut now = 1.0;
        let mut drained = 0;
        while let Some((_, _, done)) = cs.depart(now) {
            now = done;
            drained += 1;
        }
        assert_eq!(drained, 2, "zero-weight backlog must still be served");
    }

    #[test]
    #[should_panic(expected = "every class once")]
    fn strict_order_must_cover_all_classes() {
        ClassedServer::new(ArbPolicy::StrictPriority([CO, CO, TI, GE]));
    }

    #[test]
    fn per_class_telemetry_partitions() {
        let mut cs = ClassedServer::new(ArbPolicy::strict_default());
        cs.admit(0.0, 4.0, 256.0, CO, 0, 0);
        cs.admit(1.0, 6.0, 512.0, GE, 1, 0);
        let _ = cs.depart(4.0); // generic starts at 4, waited 3
        let _ = cs.depart(10.0);
        assert_eq!(cs.class_stats(CO).served, 1);
        assert_eq!(cs.class_stats(GE).served, 1);
        assert!((cs.class_stats(CO).bytes - 256.0).abs() < 1e-12);
        assert!((cs.class_stats(GE).bytes - 512.0).abs() < 1e-12);
        assert!((cs.class_stats(GE).queued_ns - 3.0).abs() < 1e-12);
        assert!((cs.busy_ns() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn pending_ns_tracks_backlog() {
        // FCFS: the time-released horizon
        let mut f = ClassedServer::fcfs();
        assert_eq!(f.pending_ns(0.0), 0.0);
        f.admit(0.0, 10.0, 64.0, CO, 0, 0);
        f.admit(0.0, 5.0, 64.0, GE, 1, 0);
        assert!((f.pending_ns(3.0) - 12.0).abs() < 1e-12);
        assert_eq!(f.pending_ns(100.0), 0.0);
        // queued mode: in-service residual + parked service demand
        let mut s = ClassedServer::new(ArbPolicy::strict_default());
        s.admit(0.0, 10.0, 64.0, CO, 0, 0); // starts, done at 10
        s.admit(1.0, 4.0, 64.0, GE, 1, 0); // queued
        assert!((s.pending_ns(2.0) - 12.0).abs() < 1e-12);
        let _ = s.depart(10.0); // generic starts, done at 14
        assert!((s.pending_ns(12.0) - 2.0).abs() < 1e-12);
        let _ = s.depart(14.0);
        assert_eq!(s.pending_ns(20.0), 0.0);
    }

    #[test]
    fn admit_batch_matches_sequential_admits() {
        // randomized same-timestamp batches, every policy: the batched
        // entry point must be equivalent admission-for-admission to the
        // serial admit chain (the epoch-batching parity contract)
        for policy in
            [ArbPolicy::FcfsShared, ArbPolicy::strict_default(), ArbPolicy::weighted_default()]
        {
            let mut rng = crate::util::Rng::new(0xBA7C);
            let mut serial = ClassedServer::new(policy);
            let mut batched = ClassedServer::new(policy);
            let mut out = Vec::new();
            let mut now = 0.0;
            for round in 0..60u32 {
                now += rng.f64() * 25.0;
                let batch: Vec<BatchAdmit> = (0..(1 + rng.below(6)))
                    .map(|j| BatchAdmit {
                        service: 0.5 + rng.f64() * 12.0,
                        bytes: 64.0 * (1.0 + rng.below(32) as f64),
                        class: TrafficClass::ALL[rng.below(4) as usize],
                        id: round * 16 + j as u32,
                        hop: j as u32,
                    })
                    .collect();
                let want: Vec<Admission> = batch
                    .iter()
                    .map(|b| serial.admit(now, b.service, b.bytes, b.class, b.id, b.hop))
                    .collect();
                out.clear();
                batched.admit_batch(now, &batch, &mut out);
                assert_eq!(out, want, "policy {} diverged at round {round}", policy.name());
                // drain queued-mode servers identically so later rounds
                // exercise both busy and idle admissions
                if round % 7 == 0 && !matches!(policy, ArbPolicy::FcfsShared) {
                    now += 40.0;
                    let (a, b) = (serial.depart(now), batched.depart(now));
                    assert_eq!(a, b);
                }
            }
            assert_eq!(serial.served(), batched.served());
            assert!((serial.busy_ns() - batched.busy_ns()).abs() < 1e-12);
            assert!((serial.mean_queue_delay() - batched.mean_queue_delay()).abs() < 1e-12);
            assert!((serial.pending_ns(now) - batched.pending_ns(now)).abs() < 1e-12);
        }
    }

    #[test]
    fn link_class_stats_helpers() {
        let s = LinkClassStats {
            link: 3,
            dir: 1,
            tier: LinkTier::CxlSpine,
            class: CO,
            served: 4,
            bytes: 4096.0,
            busy_ns: 50.0,
            queue_delay_ns: 20.0,
        };
        assert!((s.mean_queue_delay_ns() - 5.0).abs() < 1e-12);
        assert!((s.utilization(100.0) - 0.5).abs() < 1e-12);
        assert_eq!(s.utilization(0.0), 0.0);
        let idle = LinkClassStats { served: 0, queue_delay_ns: 0.0, ..s };
        assert_eq!(idle.mean_queue_delay_ns(), 0.0);
    }

    #[test]
    fn qos_policy_per_tier() {
        let mut p = QosPolicy::fcfs();
        assert_eq!(p.tier(LinkTier::Xlink), ArbPolicy::FcfsShared);
        p.set(LinkTier::CxlSpine, ArbPolicy::strict_default());
        assert_eq!(p.tier(LinkTier::CxlSpine).name(), "strict");
        assert_eq!(p.tier(LinkTier::Xlink).name(), "fcfs");
        let u = QosPolicy::uniform(ArbPolicy::weighted_default());
        for t in LinkTier::ALL {
            assert_eq!(u.tier(t).name(), "wfq");
        }
    }

    #[test]
    fn classify_links_on_a_scalepool_shape() {
        use crate::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
        use crate::fabric::TopologyKind;
        let sys = ScalePoolBuilder::new()
            .racks((0..2).map(|i| {
                Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 4).unwrap()
            }))
            .config(SystemConfig {
                inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
                mem_nodes: 2,
                ..Default::default()
            })
            .build();
        let tiers = classify_links(&sys.fabric.topo);
        assert_eq!(tiers.len(), sys.fabric.topo.links.len());
        for t in LinkTier::ALL {
            assert!(
                tiers.iter().any(|&x| x == t),
                "tier {} missing from a full ScalePool system",
                t.name()
            );
        }
        // every XLink-kind link classified as Xlink and vice versa
        for (li, l) in sys.fabric.topo.links.iter().enumerate() {
            assert_eq!(l.params.kind.is_xlink(), tiers[li] == LinkTier::Xlink);
        }
    }

    #[test]
    fn classify_links_pure_cxl_single_hop() {
        let t = Topology::single_hop(4, LinkKind::CxlCoherent, "c");
        let tiers = classify_links(&t);
        assert!(tiers.iter().all(|&x| x == LinkTier::CxlLeaf));
    }
}
