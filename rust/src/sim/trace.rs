//! The fabric flight recorder: opt-in hop-level transaction tracing and
//! time-bucketed telemetry for the streamed simulator.
//!
//! Every number the reports carry is an end-of-run aggregate; when a QoS
//! or rails sweep shows a p99 inflation, the aggregate cannot say *where
//! on the fabric or when in the run* the queueing happened. The flight
//! recorder answers that: with tracing enabled (`MemSim::set_trace`), the
//! run records per-transaction span events — inject, one span per hop
//! with link id / direction / rail / [`TrafficClass`] / queue delay, and
//! the completion — plus periodically sampled gauges (per-link-tier busy
//! time and queue depth, in-flight count) and, on the sharded backend,
//! per-shard epoch / checkpoint / rollback instants.
//!
//! # Cost discipline
//!
//! * **Disabled = free.** The simulator holds an `Option` checked once
//!   per event arm; the off path allocates nothing and records nothing.
//!   The `simscale` bench records `trace_overhead_ratio` so the disabled
//!   path stays pinned to the PR 8 baseline.
//! * **Enabled = bounded.** Spans land in a fixed-capacity ring that
//!   keeps the *latest* records and counts what it dropped
//!   (`TraceData::dropped_spans`); gauges decimate (drop every other
//!   sample and double the interval) when they hit their cap. Memory is
//!   O(capacity), never O(workload) — the same discipline as
//!   `peak_inflight`.
//! * **Inert.** Recording never changes a simulation byte: the property
//!   test `prop_tracing_is_inert` pins a traced run's `StreamReport`
//!   equal to the untraced run's, serial and sharded.
//! * **Fusion-transparent.** Express dispatch (ISSUE 10) admits quiet
//!   hops inline without dispatching their `Arrive` events, but every
//!   fused hop still emits its full span — same link, same rail, same
//!   queue delay, same timestamps, same order — so a trace cannot tell
//!   a fused chain from per-hop dispatch
//!   (`prop_fused_matches_unfused` pins the span chains identical).
//!   Gauges sample at dispatch granularity, so only their sample
//!   *instants* may differ between the two modes, never the hop record.
//!
//! # Exports
//!
//! [`chrome_trace`] renders Chrome `trace_event` JSON (open in
//! `chrome://tracing` or <https://ui.perfetto.dev>): one process per
//! shard, one track per link direction, spans named and colored by
//! traffic class, instants and counter tracks for the gauges.
//! [`time_series`] renders a compact per-link-direction busy/bytes
//! time series for plotting. The `scalepool trace` subcommand writes
//! both.

use super::qos::LinkTier;
use super::traffic::TrafficClass;
use crate::util::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// Flight-recorder knobs. `Default` is a bounded, always-safe setting.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Span-ring capacity (total across all shards of a sharded run).
    /// The ring keeps the latest `capacity` records and counts drops.
    pub capacity: usize,
    /// Gauge sampling interval in simulated ns. Samples decimate
    /// adaptively if the run outlives the gauge budget.
    pub gauge_interval_ns: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { capacity: 1 << 18, gauge_interval_ns: 10_000.0 }
    }
}

/// One recorded span event. All times are simulated ns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpanRecord {
    /// A transaction entered the fabric.
    Inject {
        at: f64,
        src: u32,
        dst: u32,
        bytes: f64,
        rail: u16,
        class: TrafficClass,
        source: u32,
        token: u64,
        shard: u16,
    },
    /// One hop's service on a link direction: arrived at `arrive`,
    /// started serving at `start` (`start - arrive` is the queue delay
    /// the arbitration policy imposed), finished at `done`.
    Hop {
        arrive: f64,
        start: f64,
        done: f64,
        link: u32,
        dir: u8,
        rail: u16,
        class: TrafficClass,
        source: u32,
        token: u64,
        bytes: f64,
        shard: u16,
    },
    /// End-to-end completion (after the destination device time).
    Complete {
        at: f64,
        latency_ns: f64,
        bytes: f64,
        class: TrafficClass,
        source: u32,
        token: u64,
        shard: u16,
    },
}

/// Kinds of backend instant events (sharded runs only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstantKind {
    /// A conservative epoch window opened on a shard.
    Epoch,
    /// The coordinator snapshotted spanning sources + worker state.
    Checkpoint,
    /// A speculated epoch was invalidated and replayed.
    Rollback,
}

impl InstantKind {
    pub fn name(self) -> &'static str {
        match self {
            InstantKind::Epoch => "epoch",
            InstantKind::Checkpoint => "checkpoint",
            InstantKind::Rollback => "rollback",
        }
    }
}

/// A backend instant event (epoch boundary, checkpoint, rollback).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstantEvent {
    pub at: f64,
    pub kind: InstantKind,
    /// Shard the instant belongs to; the coordinator stamps `nshards`.
    pub shard: u16,
}

/// One periodic telemetry sample. On the sharded backend each worker
/// samples only the link directions it owns, so per-shard samples sum to
/// the fabric-wide view.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GaugeSample {
    pub at: f64,
    pub shard: u16,
    /// Cumulative busy ns per [`LinkTier`] (sum over owned directions);
    /// utilization over a window is the delta between samples.
    pub tier_busy_ns: [f64; LinkTier::COUNT],
    /// Transactions queued (admitted but not yet serving) per tier.
    pub tier_queued: [u32; LinkTier::COUNT],
    /// Transactions in flight on this shard (or the whole serial run).
    pub inflight: u32,
}

/// Per-slot context the recorder carries so hot-path hooks only hand over
/// what they already have in registers (slot id + times + link).
#[derive(Clone, Copy, Debug, Default)]
struct SlotMeta {
    class_idx: u8,
    rail: u16,
    source: u32,
    token: u64,
    bytes: f64,
    /// Arrival time of a hop parked in a busy link's virtual channel;
    /// consumed by the matching Depart.
    pend_arrive: f64,
}

/// Cap on stored gauges per sink; hitting it halves resolution instead
/// of growing (the bounded-memory contract).
const MAX_GAUGES: usize = 1 << 14;
/// Cap on stored instants per sink; overflow counts as dropped records.
const MAX_INSTANTS: usize = 1 << 16;

/// The recording endpoint one backend (the serial loop, or one sharded
/// worker) writes into. Cheap to clone — the optimistic backend snapshots
/// it in `WorkerCkpt` so rolled-back epochs also roll back their records.
#[derive(Clone, Debug)]
pub struct TraceSink {
    shard: u16,
    cap: usize,
    ring: Vec<SpanRecord>,
    /// Next overwrite position once the ring is full.
    head: usize,
    pushed: u64,
    instants: Vec<InstantEvent>,
    dropped_instants: u64,
    gauges: Vec<GaugeSample>,
    gauge_every: f64,
    pub(crate) next_gauge: f64,
    /// `LinkTier::index()` per link id (for gauge bucketing + exports).
    link_tiers: Vec<u8>,
    slots: Vec<SlotMeta>,
    /// Calibrated wall cost of one ring push, for the overhead
    /// self-measurement (`StreamReport::trace_overhead_ns`).
    per_record_ns: f64,
    extra_overhead_ns: f64,
}

impl TraceSink {
    /// A sink for `shard` holding at most `cap` span records. `tiers` is
    /// the fabric's per-link tier classification.
    pub fn new(cfg: &TraceConfig, shard: u16, cap: usize, tiers: &[LinkTier]) -> TraceSink {
        let cap = cap.max(1);
        let mut sink = TraceSink {
            shard,
            cap,
            ring: Vec::with_capacity(cap.min(1 << 20)),
            head: 0,
            pushed: 0,
            instants: Vec::new(),
            dropped_instants: 0,
            gauges: Vec::new(),
            gauge_every: cfg.gauge_interval_ns.max(1.0),
            next_gauge: cfg.gauge_interval_ns.max(1.0),
            link_tiers: tiers.iter().map(|t| t.index() as u8).collect(),
            slots: Vec::new(),
            per_record_ns: 0.0,
            extra_overhead_ns: 0.0,
        };
        // calibrate the per-push cost once so the run can self-report its
        // recording overhead without timing the hot loop
        let probes = 2048.min(cap);
        let t0 = Instant::now();
        for i in 0..probes {
            sink.push(SpanRecord::Inject {
                at: i as f64,
                src: 0,
                dst: 0,
                bytes: 0.0,
                rail: 0,
                class: TrafficClass::Generic,
                source: 0,
                token: 0,
                shard: 0,
            });
        }
        sink.per_record_ns = t0.elapsed().as_nanos() as f64 / probes.max(1) as f64;
        sink.ring.clear();
        sink.head = 0;
        sink.pushed = 0;
        sink
    }

    #[inline]
    fn push(&mut self, r: SpanRecord) {
        self.pushed += 1;
        if self.ring.len() < self.cap {
            self.ring.push(r);
        } else {
            self.ring[self.head] = r;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
        }
    }

    #[inline]
    fn meta_mut(&mut self, slot: usize) -> &mut SlotMeta {
        if slot >= self.slots.len() {
            self.slots.resize(slot + 1, SlotMeta::default());
        }
        &mut self.slots[slot]
    }

    /// Register slot context without an inject record — the sharded
    /// backend uses this when a mid-path transaction hops into a shard.
    #[inline]
    pub(crate) fn adopt(
        &mut self,
        slot: usize,
        bytes: f64,
        rail: u16,
        class: TrafficClass,
        source: usize,
        token: u64,
    ) {
        let m = self.meta_mut(slot);
        m.class_idx = class.index() as u8;
        m.rail = rail;
        m.source = source as u32;
        m.token = token;
        m.bytes = bytes;
        m.pend_arrive = 0.0;
    }

    /// A transaction entered the fabric on `slot`.
    #[inline]
    pub(crate) fn inject(
        &mut self,
        slot: usize,
        at: f64,
        src: usize,
        dst: usize,
        bytes: f64,
        rail: u16,
        class: TrafficClass,
        source: usize,
        token: u64,
    ) {
        self.adopt(slot, bytes, rail, class, source, token);
        let shard = self.shard;
        self.push(SpanRecord::Inject {
            at,
            src: src as u32,
            dst: dst as u32,
            bytes,
            rail,
            class,
            source: source as u32,
            token,
            shard,
        });
    }

    /// A hop was admitted and its service window is fully known.
    #[inline]
    pub(crate) fn hop(
        &mut self,
        slot: usize,
        arrive: f64,
        start: f64,
        done: f64,
        link: usize,
        dir: usize,
    ) {
        let m = *self.meta_mut(slot);
        let shard = self.shard;
        self.push(SpanRecord::Hop {
            arrive,
            start,
            done,
            link: link as u32,
            dir: dir as u8,
            rail: m.rail,
            class: TrafficClass::ALL[m.class_idx as usize],
            source: m.source,
            token: m.token,
            bytes: m.bytes,
            shard,
        });
    }

    /// A hop was parked in a busy link's virtual channel at `at`; the
    /// span is emitted when the matching Depart launches it.
    #[inline]
    pub(crate) fn queued(&mut self, slot: usize, at: f64) {
        self.meta_mut(slot).pend_arrive = at;
    }

    /// The Depart chain launched a previously-queued hop.
    #[inline]
    pub(crate) fn departed(&mut self, slot: usize, start: f64, done: f64, link: usize, dir: usize) {
        let arrive = self.meta_mut(slot).pend_arrive;
        self.hop(slot, arrive, start, done, link, dir);
    }

    /// The transaction on `slot` completed end-to-end.
    #[inline]
    pub(crate) fn complete(&mut self, slot: usize, at: f64, latency_ns: f64) {
        let m = *self.meta_mut(slot);
        let shard = self.shard;
        self.push(SpanRecord::Complete {
            at,
            latency_ns,
            bytes: m.bytes,
            class: TrafficClass::ALL[m.class_idx as usize],
            source: m.source,
            token: m.token,
            shard,
        });
    }

    /// Record a backend instant event (epoch / checkpoint / rollback).
    pub(crate) fn instant(&mut self, at: f64, kind: InstantKind, shard: u16) {
        if self.instants.len() < MAX_INSTANTS {
            self.instants.push(InstantEvent { at, kind, shard });
        } else {
            self.dropped_instants += 1;
        }
    }

    /// True when the gauge interval elapsed and a sample is due.
    #[inline]
    pub(crate) fn gauge_due(&self, now: f64) -> bool {
        now >= self.next_gauge
    }

    /// Store a sample and schedule the next one; at the gauge cap the
    /// stored samples decimate and the interval doubles (bounded memory).
    pub(crate) fn gauge(&mut self, sample: GaugeSample) {
        if self.gauges.len() >= MAX_GAUGES {
            let mut keep = false;
            self.gauges.retain(|_| {
                keep = !keep;
                keep
            });
            self.gauge_every *= 2.0;
        }
        self.next_gauge = sample.at + self.gauge_every;
        self.gauges.push(sample);
    }

    /// Tier index of a link (for gauge accumulation at the backends).
    #[inline]
    pub(crate) fn tier_of(&self, link: usize) -> usize {
        self.link_tiers.get(link).copied().unwrap_or(0) as usize
    }

    /// Charge wall-clock ns spent off the span hot path (gauge sweeps).
    pub(crate) fn add_overhead(&mut self, ns: f64) {
        self.extra_overhead_ns += ns;
    }

    /// Span + instant records dropped at capacity so far.
    pub(crate) fn dropped(&self) -> u64 {
        self.pushed.saturating_sub(self.ring.len() as u64) + self.dropped_instants
    }

    /// Self-measured recording cost: calibrated per-push cost times the
    /// records attempted, plus the measured gauge sweeps.
    pub(crate) fn overhead_ns(&self) -> f64 {
        self.pushed as f64 * self.per_record_ns + self.extra_overhead_ns
    }

    /// Unroll the ring (oldest first) into an exportable [`TraceData`].
    pub(crate) fn into_data(self) -> TraceData {
        let dropped = self.dropped();
        let overhead = self.overhead_ns();
        let mut spans = self.ring;
        if self.pushed as usize > self.cap {
            spans.rotate_left(self.head);
        }
        TraceData {
            spans,
            instants: self.instants,
            gauges: self.gauges,
            link_tiers: self.link_tiers,
            dropped_spans: dropped,
            overhead_ns: overhead,
        }
    }
}

/// The collected output of a traced run: span records (oldest first per
/// backend), instant events, gauges, and the honesty counters. Sharded
/// runs merge per-shard sinks in shard order.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<SpanRecord>,
    pub instants: Vec<InstantEvent>,
    pub gauges: Vec<GaugeSample>,
    /// `LinkTier::index()` per link id.
    pub link_tiers: Vec<u8>,
    /// Span/instant records lost to the ring capacity.
    pub dropped_spans: u64,
    /// Self-measured recording cost (wall ns): what tracing added to the
    /// run that produced this data.
    pub overhead_ns: f64,
}

impl TraceData {
    /// Fold another backend's records in (per-shard merge).
    pub fn merge(&mut self, mut other: TraceData) {
        self.spans.append(&mut other.spans);
        self.instants.append(&mut other.instants);
        self.gauges.append(&mut other.gauges);
        if self.link_tiers.is_empty() {
            self.link_tiers = other.link_tiers;
        }
        self.dropped_spans += other.dropped_spans;
        self.overhead_ns += other.overhead_ns;
    }
}

fn tier_name(link_tiers: &[u8], link: usize) -> &'static str {
    link_tiers
        .get(link)
        .and_then(|&t| LinkTier::ALL.get(t as usize))
        .map(|t| t.name())
        .unwrap_or("?")
}

fn class_cname(class: TrafficClass) -> &'static str {
    // stable chrome://tracing palette names, one hue per class
    match class {
        TrafficClass::Coherence => "thread_state_running",
        TrafficClass::Tiering => "thread_state_iowait",
        TrafficClass::Collective => "thread_state_runnable",
        TrafficClass::Generic => "generic_work",
    }
}

/// Chrome trace-event tid of a link-direction track (tid 0 is the
/// lifecycle/instant track, tid 1 the counter track).
fn link_tid(link: u32, dir: u8) -> u64 {
    2 + (link as u64) * 2 + dir as u64
}

const US: f64 = 1e-3; // ns -> trace_event µs

/// Render Chrome `trace_event` JSON: one process per shard, one thread
/// track per link direction carrying B/E span pairs (named and colored
/// by [`TrafficClass`]), instant events for injects / completions /
/// epoch-checkpoint-rollback marks, and counter tracks from the gauges.
/// Load the output in `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn chrome_trace(d: &TraceData) -> Json {
    let mut events: Vec<Json> = Vec::new();
    // group hop spans per (shard, link, dir) track; lifecycle instants
    // per shard — BTreeMaps keep the output deterministic
    let mut tracks: BTreeMap<(u16, u32, u8), Vec<(f64, f64, f64, &SpanRecord)>> = BTreeMap::new();
    let mut life: BTreeMap<u16, Vec<&SpanRecord>> = BTreeMap::new();
    for s in &d.spans {
        match *s {
            SpanRecord::Hop { arrive, start, done, link, dir, shard, .. } => {
                tracks.entry((shard, link, dir)).or_default().push((start, done, arrive, s));
            }
            SpanRecord::Inject { shard, .. } | SpanRecord::Complete { shard, .. } => {
                life.entry(shard).or_default().push(s);
            }
        }
    }

    let mut shards: Vec<u16> = tracks.keys().map(|k| k.0).collect();
    shards.extend(life.keys().copied());
    shards.extend(d.instants.iter().map(|i| i.shard));
    shards.extend(d.gauges.iter().map(|g| g.shard));
    shards.sort_unstable();
    shards.dedup();

    for &p in &shards {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(p as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(&format!("shard{p}")))])),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(p as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("lifecycle"))])),
        ]));
    }

    for ((shard, link, dir), mut spans) in tracks {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(shard as f64)),
            ("tid", Json::num(link_tid(link, dir) as f64)),
            (
                "args",
                Json::obj(vec![(
                    "name",
                    Json::str(&format!(
                        "link{link} d{dir} [{}]",
                        tier_name(&d.link_tiers, link as usize)
                    )),
                )]),
            ),
        ]));
        // service on one link direction is serial, so sorting by start
        // yields non-overlapping spans -> clean alternating B/E pairs
        spans.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
        for (start, done, arrive, s) in spans {
            let (rail, class, source, token, bytes) = match *s {
                SpanRecord::Hop { rail, class, source, token, bytes, .. } => {
                    (rail, class, source, token, bytes)
                }
                _ => unreachable!("hop track holds only hop records"),
            };
            let args = Json::obj(vec![
                ("bytes", Json::num(bytes)),
                ("queue_ns", Json::num(start - arrive)),
                ("rail", Json::num(rail as f64)),
                ("source", Json::num(source as f64)),
                ("token", Json::num(token as f64)),
            ]);
            events.push(Json::obj(vec![
                ("name", Json::str(class.name())),
                ("cat", Json::str("hop")),
                ("ph", Json::str("B")),
                ("pid", Json::num(shard as f64)),
                ("tid", Json::num(link_tid(link, dir) as f64)),
                ("ts", Json::num(start * US)),
                ("cname", Json::str(class_cname(class))),
                ("args", args),
            ]));
            events.push(Json::obj(vec![
                ("name", Json::str(class.name())),
                ("cat", Json::str("hop")),
                ("ph", Json::str("E")),
                ("pid", Json::num(shard as f64)),
                ("tid", Json::num(link_tid(link, dir) as f64)),
                ("ts", Json::num(done * US)),
            ]));
        }
    }

    for (shard, mut marks) in life {
        marks.sort_by(|a, b| {
            let at = |s: &SpanRecord| match *s {
                SpanRecord::Inject { at, .. } | SpanRecord::Complete { at, .. } => at,
                SpanRecord::Hop { arrive, .. } => arrive,
            };
            at(a).partial_cmp(&at(b)).unwrap()
        });
        for s in marks {
            let (name, at, class, source, token, extra) = match *s {
                SpanRecord::Inject { at, class, source, token, src, dst, .. } => {
                    ("inject", at, class, source, token, ("dst", src as f64, dst as f64))
                }
                SpanRecord::Complete { at, class, source, token, latency_ns, .. } => {
                    ("complete", at, class, source, token, ("latency_ns", latency_ns, 0.0))
                }
                _ => unreachable!("lifecycle track holds no hop records"),
            };
            let mut args = vec![
                ("class", Json::str(class.name())),
                ("source", Json::num(source as f64)),
                ("token", Json::num(token as f64)),
            ];
            if name == "inject" {
                args.push(("src", Json::num(extra.1)));
                args.push(("dst", Json::num(extra.2)));
            } else {
                args.push(("latency_ns", Json::num(extra.1)));
            }
            events.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str("lifecycle")),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("pid", Json::num(shard as f64)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(at * US)),
                ("cname", Json::str(class_cname(class))),
                ("args", Json::obj(args)),
            ]));
        }
    }

    let mut instants: Vec<&InstantEvent> = d.instants.iter().collect();
    instants.sort_by(|a, b| (a.shard, a.at).partial_cmp(&(b.shard, b.at)).unwrap());
    for i in instants {
        events.push(Json::obj(vec![
            ("name", Json::str(i.kind.name())),
            ("cat", Json::str("backend")),
            ("ph", Json::str("i")),
            ("s", Json::str("p")),
            ("pid", Json::num(i.shard as f64)),
            ("tid", Json::num(0.0)),
            ("ts", Json::num(i.at * US)),
        ]));
    }

    let mut gauges: Vec<&GaugeSample> = d.gauges.iter().collect();
    gauges.sort_by(|a, b| (a.shard, a.at).partial_cmp(&(b.shard, b.at)).unwrap());
    let mut prev: BTreeMap<u16, (f64, [f64; LinkTier::COUNT])> = BTreeMap::new();
    for g in gauges {
        events.push(Json::obj(vec![
            ("name", Json::str("inflight")),
            ("ph", Json::str("C")),
            ("pid", Json::num(g.shard as f64)),
            ("tid", Json::num(1.0)),
            ("ts", Json::num(g.at * US)),
            ("args", Json::obj(vec![("inflight", Json::num(g.inflight as f64))])),
        ]));
        let queued: Vec<(&str, Json)> = LinkTier::ALL
            .iter()
            .map(|t| (t.name(), Json::num(g.tier_queued[t.index()] as f64)))
            .collect();
        events.push(Json::obj(vec![
            ("name", Json::str("queued")),
            ("ph", Json::str("C")),
            ("pid", Json::num(g.shard as f64)),
            ("tid", Json::num(1.0)),
            ("ts", Json::num(g.at * US)),
            ("args", Json::obj(queued)),
        ]));
        // utilization = delta busy over delta t since the previous sample
        let (t_prev, busy_prev) =
            prev.get(&g.shard).copied().unwrap_or((0.0, [0.0; LinkTier::COUNT]));
        let dt = (g.at - t_prev).max(1e-9);
        let util: Vec<(&str, Json)> = LinkTier::ALL
            .iter()
            .map(|t| {
                let d_busy = (g.tier_busy_ns[t.index()] - busy_prev[t.index()]).max(0.0);
                (t.name(), Json::num(d_busy / dt))
            })
            .collect();
        events.push(Json::obj(vec![
            ("name", Json::str("tier_util")),
            ("ph", Json::str("C")),
            ("pid", Json::num(g.shard as f64)),
            ("tid", Json::num(1.0)),
            ("ts", Json::num(g.at * US)),
            ("args", Json::obj(util)),
        ]));
        prev.insert(g.shard, (g.at, g.tier_busy_ns));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
        (
            "otherData",
            Json::obj(vec![
                ("generator", Json::str("scalepool flight recorder")),
                ("dropped_spans", Json::num(d.dropped_spans as f64)),
                ("trace_overhead_ns", Json::num(d.overhead_ns)),
            ]),
        ),
    ])
}

/// Render a compact per-link-direction time series: hop busy-ns and
/// delivered bytes bucketed over the traced span range, plus the raw
/// gauges and instants. `buckets` is the time resolution.
pub fn time_series(d: &TraceData, buckets: usize) -> Json {
    let buckets = buckets.max(1);
    let mut t0 = f64::INFINITY;
    let mut t1 = f64::NEG_INFINITY;
    for s in &d.spans {
        match *s {
            SpanRecord::Inject { at, .. } | SpanRecord::Complete { at, .. } => {
                t0 = t0.min(at);
                t1 = t1.max(at);
            }
            SpanRecord::Hop { arrive, done, .. } => {
                t0 = t0.min(arrive);
                t1 = t1.max(done);
            }
        }
    }
    if !t0.is_finite() {
        t0 = 0.0;
        t1 = 0.0;
    }
    let bucket_ns = ((t1 - t0) / buckets as f64).max(1e-9);

    let mut links: BTreeMap<(u32, u8), (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for s in &d.spans {
        if let SpanRecord::Hop { start, done, link, dir, bytes, .. } = *s {
            let (busy, by) = links
                .entry((link, dir))
                .or_insert_with(|| (vec![0.0; buckets], vec![0.0; buckets]));
            // busy time spreads proportionally over the buckets the
            // service window overlaps; bytes land at delivery time
            let b0 = (((start - t0) / bucket_ns) as usize).min(buckets - 1);
            let b1 = (((done - t0) / bucket_ns) as usize).min(buckets - 1);
            for (b, slot) in busy.iter_mut().enumerate().take(b1 + 1).skip(b0) {
                let lo = t0 + b as f64 * bucket_ns;
                let hi = lo + bucket_ns;
                *slot += (done.min(hi) - start.max(lo)).max(0.0);
            }
            by[(((done - t0) / bucket_ns) as usize).min(buckets - 1)] += bytes;
        }
    }

    let link_rows: Vec<Json> = links
        .into_iter()
        .map(|((link, dir), (busy, bytes))| {
            Json::obj(vec![
                ("link", Json::num(link as f64)),
                ("dir", Json::num(dir as f64)),
                ("tier", Json::str(tier_name(&d.link_tiers, link as usize))),
                ("busy_ns", Json::Arr(busy.into_iter().map(Json::num).collect())),
                ("bytes", Json::Arr(bytes.into_iter().map(Json::num).collect())),
            ])
        })
        .collect();

    let gauge_rows: Vec<Json> = d
        .gauges
        .iter()
        .map(|g| {
            let per_tier = |vals: &dyn Fn(usize) -> f64| {
                Json::obj(LinkTier::ALL.iter().map(|t| (t.name(), Json::num(vals(t.index())))).collect())
            };
            Json::obj(vec![
                ("at", Json::num(g.at)),
                ("shard", Json::num(g.shard as f64)),
                ("inflight", Json::num(g.inflight as f64)),
                ("tier_busy_ns", per_tier(&|i| g.tier_busy_ns[i])),
                ("tier_queued", per_tier(&|i| g.tier_queued[i] as f64)),
            ])
        })
        .collect();

    let instant_rows: Vec<Json> = d
        .instants
        .iter()
        .map(|i| {
            Json::obj(vec![
                ("at", Json::num(i.at)),
                ("kind", Json::str(i.kind.name())),
                ("shard", Json::num(i.shard as f64)),
            ])
        })
        .collect();

    Json::obj(vec![
        ("format", Json::str("scalepool-trace-series/v1")),
        ("t0_ns", Json::num(t0)),
        ("bucket_ns", Json::num(bucket_ns)),
        ("buckets", Json::num(buckets as f64)),
        ("spans", Json::num(d.spans.len() as f64)),
        ("dropped_spans", Json::num(d.dropped_spans as f64)),
        ("trace_overhead_ns", Json::num(d.overhead_ns)),
        ("links", Json::Arr(link_rows)),
        ("gauges", Json::Arr(gauge_rows)),
        ("instants", Json::Arr(instant_rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink(cap: usize) -> TraceSink {
        let cfg = TraceConfig { capacity: cap, gauge_interval_ns: 100.0 };
        TraceSink::new(&cfg, 0, cap, &[LinkTier::Xlink, LinkTier::CxlSpine])
    }

    #[test]
    fn ring_keeps_latest_and_counts_drops() {
        let mut s = sink(4);
        for i in 0..10usize {
            s.adopt(0, 64.0, 0, TrafficClass::Generic, 0, i as u64);
            s.hop(0, i as f64, i as f64, i as f64 + 1.0, 0, 0);
        }
        let d = s.into_data();
        assert_eq!(d.spans.len(), 4);
        assert_eq!(d.dropped_spans, 6);
        // oldest-first unroll: the last four pushes in push order
        for (k, span) in d.spans.iter().enumerate() {
            match span {
                SpanRecord::Hop { arrive, .. } => assert_eq!(*arrive, (6 + k) as f64),
                other => panic!("expected hop, got {other:?}"),
            }
        }
    }

    #[test]
    fn slot_meta_rides_from_inject_to_complete() {
        let mut s = sink(16);
        s.inject(3, 5.0, 10, 20, 4096.0, 2, TrafficClass::Coherence, 7, 99);
        s.hop(3, 5.0, 6.0, 8.0, 1, 1);
        s.queued(3, 9.0);
        s.departed(3, 11.0, 12.0, 0, 0);
        s.complete(3, 14.0, 9.0);
        let d = s.into_data();
        assert_eq!(d.spans.len(), 4);
        match d.spans[1] {
            SpanRecord::Hop { rail, class, source, token, bytes, .. } => {
                assert_eq!(rail, 2);
                assert_eq!(class, TrafficClass::Coherence);
                assert_eq!(source, 7);
                assert_eq!(token, 99);
                assert_eq!(bytes, 4096.0);
            }
            ref other => panic!("expected hop, got {other:?}"),
        }
        match d.spans[2] {
            SpanRecord::Hop { arrive, start, done, .. } => {
                assert_eq!(arrive, 9.0, "departed span must carry the queued arrival");
                assert_eq!(start, 11.0);
                assert_eq!(done, 12.0);
            }
            ref other => panic!("expected hop, got {other:?}"),
        }
        assert_eq!(d.dropped_spans, 0);
    }

    #[test]
    fn gauges_decimate_at_cap_instead_of_growing() {
        let mut s = sink(4);
        for i in 0..(MAX_GAUGES * 3) {
            s.gauge(GaugeSample {
                at: i as f64,
                shard: 0,
                tier_busy_ns: [0.0; LinkTier::COUNT],
                tier_queued: [0; LinkTier::COUNT],
                inflight: 0,
            });
        }
        assert!(s.gauges.len() <= MAX_GAUGES + 1);
        assert!(s.gauge_every > 100.0, "interval must back off at the cap");
    }

    #[test]
    fn merge_concatenates_and_sums() {
        let mut a = sink(8);
        a.inject(0, 1.0, 0, 1, 64.0, 0, TrafficClass::Generic, 0, 0);
        let mut b = sink(8);
        b.instant(2.0, InstantKind::Epoch, 1);
        b.inject(0, 3.0, 1, 0, 64.0, 0, TrafficClass::Generic, 1, 0);
        let mut d = a.into_data();
        d.merge(b.into_data());
        assert_eq!(d.spans.len(), 2);
        assert_eq!(d.instants.len(), 1);
        assert_eq!(d.dropped_spans, 0);
    }

    #[test]
    fn chrome_export_has_matched_monotonic_pairs() {
        let mut s = sink(64);
        s.inject(0, 0.0, 0, 3, 64.0, 0, TrafficClass::Collective, 0, 0);
        s.hop(0, 0.0, 0.0, 2.0, 0, 0);
        s.hop(0, 2.5, 2.5, 4.0, 1, 0);
        s.inject(1, 1.0, 3, 0, 64.0, 1, TrafficClass::Coherence, 1, 5);
        s.hop(1, 1.0, 4.0, 6.0, 1, 0);
        s.complete(0, 5.0, 5.0);
        s.instant(6.0, InstantKind::Checkpoint, 0);
        let j = chrome_trace(&s.into_data());
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        // per (pid, tid): B/E alternate starting with B, ts non-decreasing
        let mut open: BTreeMap<(u64, u64), usize> = BTreeMap::new();
        let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
        let mut b_count = 0;
        for e in events {
            let ph = e.get("ph").and_then(Json::as_str).unwrap();
            if ph == "M" {
                continue;
            }
            let key = (
                e.get("pid").and_then(Json::as_u64).unwrap(),
                e.get("tid").and_then(Json::as_u64).unwrap(),
            );
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            if ph == "B" || ph == "E" {
                let prev = last_ts.insert(key, ts).unwrap_or(f64::NEG_INFINITY);
                assert!(ts >= prev, "track {key:?} ts went backwards: {prev} -> {ts}");
            }
            match ph {
                "B" => {
                    let depth = open.entry(key).or_insert(0);
                    assert_eq!(*depth, 0, "overlapping spans on one link track");
                    *depth = 1;
                    b_count += 1;
                }
                "E" => {
                    let depth = open.entry(key).or_insert(0);
                    assert_eq!(*depth, 1, "E without a matching B");
                    *depth = 0;
                }
                "i" | "C" => {}
                other => panic!("unexpected phase {other}"),
            }
        }
        assert_eq!(b_count, 3);
        assert!(open.values().all(|&d| d == 0), "unclosed span at end of trace");
        // the json round-trips through the parser
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn time_series_conserves_busy_time() {
        let mut s = sink(64);
        s.adopt(0, 1000.0, 0, TrafficClass::Tiering, 0, 0);
        s.hop(0, 0.0, 0.0, 10.0, 0, 0);
        s.hop(0, 10.0, 12.0, 30.0, 1, 1);
        let j = time_series(&s.into_data(), 8);
        let links = j.get("links").and_then(Json::as_arr).unwrap();
        assert_eq!(links.len(), 2);
        let total: f64 = links
            .iter()
            .flat_map(|l| l.get("busy_ns").and_then(Json::as_arr).unwrap())
            .map(|v| v.as_f64().unwrap())
            .sum();
        let want = 10.0 + 18.0;
        assert!((total - want).abs() < 1e-6, "bucketed busy {total} != span busy {want}");
        let bytes: f64 = links
            .iter()
            .flat_map(|l| l.get("bytes").and_then(Json::as_arr).unwrap())
            .map(|v| v.as_f64().unwrap())
            .sum();
        assert_eq!(bytes, 2000.0);
    }
}
